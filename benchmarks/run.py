"""Benchmark harness entrypoint: one module per paper table/figure.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig7,fig8,...]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/README contract).
The quick mode (default) uses reduced rates/durations sized for a single-core
CPU container; --full uses paper-scale sweeps.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import CsvReporter


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", type=str, default="")
    ap.add_argument("--skip", type=str, default="")
    args = ap.parse_args()

    from benchmarks import (churn_scale, fig1_burst, fig7_coldstart,
                            fig8_warmstart, fig9_10_azure, fig11_failover,
                            registration, scalability)
    try:
        from benchmarks import kernel_bench
    except Exception:
        kernel_bench = None
    modules = {
        "fig1": fig1_burst,
        "fig7": fig7_coldstart,
        "fig8": fig8_warmstart,
        "azure": fig9_10_azure,
        "fig11": fig11_failover,
        "registration": registration,
        "scalability": scalability,
        "churn": churn_scale,
    }
    if kernel_bench is not None:
        modules["kernels"] = kernel_bench
    only = set(filter(None, args.only.split(",")))
    skip = set(filter(None, args.skip.split(",")))

    rep = CsvReporter()
    rep.header()
    for name, mod in modules.items():
        if only and name not in only:
            continue
        if name in skip:
            continue
        t0 = time.time()
        try:
            mod.run(rep, quick=not args.full)
            print(f"# {name} finished in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # keep the harness going; report the failure
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            import traceback
            traceback.print_exc()


if __name__ == "__main__":
    main()
