"""Fig 1 / Fig 2 — end-to-end latency of cold-start *bursts*.

A single function receives B concurrent invocations against an idle cluster;
we report the p50 E2E latency over the burst, plus the breakdown into
cluster-manager time vs sandbox creation vs init/probe time. Paper: Knative's
cluster-manager component grows to ~2 s at a 100-sandbox burst while the
worker-side times stay flat; Dirigent stays near-flat.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    SWEEP_SCALING, latency_stats, make_dirigent, make_knative,
    preload_functions,
)
from repro.simcore import Environment


def burst(system_kind: str, size: int, seed: int = 31):
    env = Environment(seed=seed)
    if system_kind.startswith("dirigent"):
        runtime = "containerd" if "ctd" in system_kind else "firecracker"
        sys_ = make_dirigent(env, runtime=runtime)
    else:
        sys_ = make_knative(env)
    preload_functions(sys_, ["burst"], dict(stable_window=60.0,
                                            scale_to_zero_grace=30.0,
                                            cpu_req_millis=100, mem_req_mb=128))
    invs = [sys_.invoke("burst", exec_time=0.1) for _ in range(size)]
    env.run(until=600.0)
    st = latency_stats(invs, "e2e_latency")
    sched = latency_stats(invs, "scheduling_latency")
    st["sched_p50"] = sched["p50"]
    return st


def run(reporter, quick: bool = True) -> dict:
    out = {}
    sizes = [1, 10, 100] if quick else [1, 10, 25, 50, 100, 200]
    for kind in ["dirigent-fc", "dirigent-ctd", "knative"]:
        for b in sizes:
            st = burst(kind, b)
            reporter.add(f"fig1/{kind}/burst={b}", st["p50"] * 1e6,
                         f"sched_p50_ms={st['sched_p50']*1e3:.1f};"
                         f"p99_ms={st['p99']*1e3:.1f}")
            out[f"{kind}_{b}"] = st
    return out


if __name__ == "__main__":
    from benchmarks.common import CsvReporter
    rep = CsvReporter()
    rep.header()
    run(rep, quick=True)
