"""Kernel microbenchmarks (CPU wall time): chunk-parallel matmul forms vs
naive recurrences, and blocked vs reference attention.

These measure the *algorithmic* win of the chunked forms (O(S·C·d) matmuls
vs S sequential steps) — on TPU the same forms run as the Pallas kernels.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.chunked import ssd_chunked, wkv6_chunked
from repro.kernels.ref import ssd_ref, wkv6_ref
from repro.models.layers import attention_reference, flash_attention_jnp


def _time(fn, *args, n=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run(reporter, quick: bool = True) -> dict:
    out = {}
    B, S, H, dk = 2, 1024, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r = jax.random.normal(ks[0], (B, S, H, dk)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, dk)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, dk)) * 0.5
    w = jnp.clip(jnp.exp(-jnp.exp(
        jax.random.normal(ks[3], (B, S, H, dk)) * 0.5 - 1.5)), 0.62, 0.9999)
    u = jax.random.normal(ks[4], (H, dk)) * 0.3

    ref_f = jax.jit(lambda *a: wkv6_ref(*a)[0])
    chk_f = jax.jit(lambda *a: wkv6_chunked(*a, chunk=64)[0])
    t_ref = _time(ref_f, r, k, v, w, u)
    t_chk = _time(chk_f, r, k, v, w, u)
    reporter.add("kernels/wkv6-naive-scan", t_ref * 1e6, f"S={S}")
    reporter.add("kernels/wkv6-chunked", t_chk * 1e6,
                 f"speedup={t_ref / t_chk:.1f}x")
    out["wkv6_speedup"] = t_ref / t_chk

    N, Pd = 32, 32
    x = jax.random.normal(ks[0], (B, S, H, Pd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, H, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, H, N)) * 0.5
    D = jax.random.normal(ks[5], (H,)) * 0.3
    ref_s = jax.jit(lambda *a: ssd_ref(*a)[0])
    chk_s = jax.jit(lambda *a: ssd_chunked(*a, chunk=64)[0])
    t_ref = _time(ref_s, x, dt, A, Bm, Cm, D)
    t_chk = _time(chk_s, x, dt, A, Bm, Cm, D)
    reporter.add("kernels/ssd-naive-scan", t_ref * 1e6, f"S={S}")
    reporter.add("kernels/ssd-chunked", t_chk * 1e6,
                 f"speedup={t_ref / t_chk:.1f}x")
    out["ssd_speedup"] = t_ref / t_chk

    # blocked attention vs O(S^2)-materializing reference
    S2 = 2048
    q = jax.random.normal(ks[0], (1, S2, 4, 64))
    kk = jax.random.normal(ks[1], (1, S2, 4, 64))
    vv = jax.random.normal(ks[2], (1, S2, 4, 64))
    f_ref = jax.jit(lambda *a: attention_reference(*a, causal=True))
    f_fla = jax.jit(lambda *a: flash_attention_jnp(*a, causal=True,
                                                   q_chunk=256, kv_chunk=256))
    t_ref = _time(f_ref, q, kk, vv)
    t_fla = _time(f_fla, q, kk, vv)
    reporter.add("kernels/attention-reference", t_ref * 1e6, f"S={S2}")
    reporter.add("kernels/attention-blocked", t_fla * 1e6,
                 f"ratio={t_ref / t_fla:.2f}x")
    return out


if __name__ == "__main__":
    from benchmarks.common import CsvReporter
    rep = CsvReporter()
    rep.header()
    print(run(rep))
