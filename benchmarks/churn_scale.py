"""Churn-at-scale sweep: scheduler throughput across (workers x creation rate).

The paper's headline numbers are churn numbers — 2500 sandbox creations/s on
93 nodes, graceful degradation at 5000 workers (C1/C9). This benchmark keeps
the perf trajectory honest on two axes at once:

  * simulated sandbox throughput / latency per grid cell (the modeled
    system), and
  * wall-clock simulator events/s per cell (is Python the bottleneck, or
    the model?);

plus a placer microbenchmark that pits the incremental score index against
the seed's brute-force full rescan at 5000 nodes — the asymptotic fix this
sweep exists to protect,

plus a control-plane shard sweep at the 5000-worker regime: the same churn
workload against ``cp_shards`` in {1, 2, 4, ...}. With one shard the modeled
scale lock caps creations at ~2700/s (C1) and 5000 workers' heartbeats eat
into that budget (C9); the sweep records how modeled creation throughput,
tail latency and accumulated lock-convoy time move as the CP is partitioned
(core/control_plane.py),

plus a skewed-popularity sweep at the same regime: function popularity is
Zipf (the Azure-trace shape, Shahrad et al.) and each function's traffic
arrives as periodic cold bursts, so sandbox-creation load concentrates on
the shards that own the popular functions. The static ``stable_hash % N``
partition convoys on the hot shard; the sweep records shards 1→8 with the
load-adaptive rebalancer + work-stealing spill off vs on
(``cp_rebalance_enabled``, core/control_plane.py),

plus a *single dominant function* sweep (``single_hot_fn``): one function
carries ~80% of the creation load — the irreducible hotspot whole-function
rebalancing cannot fix — recorded at shards 4/8 with per-function creation
sharding (``cp_fn_split_enabled``, fn→shard-set ownership) off vs on; the
split must cut the hot shard's lock wait and the post-warmup tail at equal
shard count with total creations unchanged,

plus a live-mode smoke cell (``--live-smoke`` runs it alone): the same churn
shape against workers whose ``create_hook`` builds a *real* replica payload,
so wall-clock creation throughput covers actual sandbox construction work,
not only DES bookkeeping (ROADMAP "live-mode churn bench"),

plus a multi-data-plane sweep (``multi_dp_sweep``, ``--multi-dp`` runs it
alone): the ``single_hot_fn`` workload pushed *past* the ~1400 conn/s
per-DP port ceiling (C5: 28k ephemeral ports / 20 s TIME_WAIT) that forced
the hot-fn sweep to stay at rate 1500. The above-ceiling cell is recorded
with the steering/connection knobs off (port exhaustion: the blowup PR 5
could not record), then with fn→DP-set spreading (``dp_spread_enabled``),
with invoke-path connection reuse (``dp_conn_reuse``), and with both +
the coalesced CP→DP endpoint flush (``cp_ep_flush_coalesce``) — the fixed
cells must land p99 back in the below-ceiling reference's regime.

Emits ``BENCH_churn.json`` (schema in docs/benchmarks.md): results, a
``meta.provenance`` block (git SHA, python/numpy/jax versions, CPU count,
timestamp) so wall-clock numbers are comparable across PRs, and a
``perf_trajectory`` list (preserved across re-runs) holding before/after
wall-clock records of deliberate perf changes. ``--smoke`` runs a
seconds-scale subset (CI).
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import time

import numpy as np

if __package__ in (None, ""):          # `python benchmarks/churn_scale.py`
    import os
    import sys
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)                        # the benchmarks package
    sys.path.insert(0, os.path.join(_root, "src"))   # repro itself

from benchmarks.common import (
    SWEEP_SCALING, latency_stats, make_dirigent, preload_functions,
    run_open_loop,
)
from repro.core.placement import Placer, make_placer
from repro.simcore import Environment

REQ_CPU, REQ_MEM = 100, 128         # SWEEP_SCALING request footprint


def bench_provenance() -> dict:
    """Run provenance for ``meta``: enough to judge whether two recorded
    wall-clock numbers are comparable (same tree? same machine class?)."""
    prov = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "python": platform.python_version(),
        "numpy": np.__version__,
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
    }
    try:
        prov["git_sha"] = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        prov["git_sha"] = None
    try:
        import jax
        prov["jax"] = jax.__version__
    except Exception:                 # noqa: BLE001 — jax is optional here
        prov["jax"] = None
    return prov


def placer_microbench(n_nodes: int, n_ops: int, use_index: bool,
                      policy: str = "balanced", churn: bool = True) -> dict:
    """Wall-clock placements/s on a steady-churn workload: fill a warm pool,
    then alternate release/place so every op hits a non-trivial index state."""
    if policy == "partitioned":
        placer = make_placer("partitioned", use_index=use_index)
    else:
        placer = Placer(policy, use_index=use_index)
    for wid in range(n_nodes):
        placer.add_node(wid, 4000, 8192)
    warm = min(n_nodes * 4, n_ops)
    placed = []
    for _ in range(warm):
        wid = placer.place(REQ_CPU, REQ_MEM)
        if wid is not None:
            placed.append(wid)
    t0 = time.perf_counter()
    for i in range(n_ops):
        if churn and placed:
            placer.release(placed[i % len(placed)], REQ_CPU, REQ_MEM)
        wid = placer.place(REQ_CPU, REQ_MEM)
        if churn and placed and wid is not None:
            placed[i % len(placed)] = wid
    wall = time.perf_counter() - t0
    return {"n_nodes": n_nodes, "n_ops": n_ops, "policy": policy,
            "use_index": use_index, "wall_s": round(wall, 4),
            "places_per_s": round(n_ops / wall, 1)}


def churn_point(n_workers: int, rate: float, duration: float,
                seed: int = 71, placement_policy: str = "balanced",
                cp_shards: int = 1, hb_cohort: bool = False,
                vector_windows: bool = False,
                group_commit: bool = False) -> dict:
    """One grid cell: the scalability.py cold-start churn workload, with
    wall-clock accounting alongside the simulated latency stats.

    ``hb_cohort`` turns on the cohort heartbeat wheel (same-deadline beats
    snap to a shared grid and pop as one event) and ``vector_windows`` the
    array-backed metric windows — the two decision-identical fast paths that
    make the 50k-worker cell wall-clock feasible (tests/test_vectorized.py
    pins both against their scalar references). ``group_commit`` turns on
    WAL group commit (``persist_group_commit``), without which the 100k-cell
    boot alone is O(n_workers) serialized fsyncs ≈ 2+ minutes of sim time."""
    env = Environment(seed=seed)
    kw = {}
    if hb_cohort:
        from repro.core.costmodel import DEFAULT_COSTS
        kw["hb_cohort_quantum"] = \
            DEFAULT_COSTS.dirigent.worker_hb_cohort_quantum
    cl = make_dirigent(env, n_workers=n_workers, runtime="firecracker",
                       placement_policy=placement_policy,
                       cp_shards=cp_shards,
                       cp_vector_windows=vector_windows,
                       persist_group_commit=group_commit, **kw)
    plan = [(i / rate, f"f{i}", 0.05) for i in range(int(rate * duration))]
    preload_functions(cl, [p[1] for p in plan], SWEEP_SCALING)
    ev0, t0 = env.events_processed, time.perf_counter()
    invs = run_open_loop(env, cl, plan, until_extra=30.0)
    wall = time.perf_counter() - t0
    events = env.events_processed - ev0
    stats = latency_stats(invs, "e2e_latency")
    leader = cl.control_plane_leader()
    return {
        "workers": n_workers, "rate": rate, "duration": duration,
        "policy": placement_policy, "cp_shards": cp_shards,
        "hb_cohort": hb_cohort, "vector_windows": vector_windows,
        "group_commit": group_commit,
        "group_commits": cl.store.group_commits,
        "events_per_creation": round(
            (env.events_processed - ev0)
            / max(cl.collector.sandbox_creations, 1), 1),
        "wall_s": round(wall, 3), "sim_s": round(env.now, 3),
        "events": events, "events_per_wall_s": round(events / wall, 1),
        "creations": cl.collector.sandbox_creations,
        "creations_per_wall_s": round(cl.collector.sandbox_creations / wall, 1),
        # wall-clock columns answer the separate "is Python the bottleneck"
        # question; this is the modeled ceiling
        "creations_per_sim_s": creations_per_sim_s(cl.collector),
        "reconciles": cl.collector.reconciles,
        "lock_wait_sim_s": (round(sum(s.lock_wait_s for s in leader.shards), 4)
                            if leader else None),
        "done": stats["done"], "total": stats["total"],
        "p50_ms": round(stats["p50"] * 1e3, 3),
        "p99_ms": round(stats["p99"] * 1e3, 3),
    }


def zipf_weights(n: int, s: float) -> np.ndarray:
    w = np.arange(1, n + 1, dtype=np.float64) ** (-s)
    return w / w.sum()


def creations_per_sim_s(collector):
    """Modeled creation throughput over the window creations actually
    happened in — the C1 ceiling the CP shards raise."""
    ts = [t for t, k, _ in collector.events if k == "sandbox-created"]
    span = (ts[-1] - ts[0]) if len(ts) > 1 else 0.0
    return round((len(ts) - 1) / span, 1) if span > 0 else None


def skew_point(n_workers: int, rate: float, duration: float,
               n_functions: int = 128, zipf_s: float = 1.2,
               burst_period: float = 4.0, seed: int = 91,
               cp_shards: int = 1, rebalance: bool = False,
               weights: "np.ndarray | None" = None,
               names_prefix: str = "z",
               fn_split: bool = False,
               fn_split_max_shards: "int | None" = None,
               n_data_planes: int = 3,
               dp_spread: bool = False,
               conn_reuse: bool = False,
               ep_coalesce: bool = False,
               costs=None) -> dict:
    """One skew cell: Zipf-popularity function mix, unison cold bursts.

    Function *i* owns a Zipf(s) share of the offered rate and receives it as
    one *instantaneous* burst per ``burst_period`` (the timer-triggered
    unison-burst shape of the Azure trace §5.3, all functions in phase).
    The period is long enough for every function to scale fully back to zero
    between waves (grace 0.2 s + the 2 s autoscale tick + drain), so each
    wave is a pure cold scale-up of burst size: per-shard sandbox-creation
    load is proportional to the popularity share the shard's functions hold
    — maximally skewed under static hashing — and the wave drains at the
    shard's scale-lock rate, which is exactly what couples the hot shard's
    lock convoy into request latency. Latency stats skip the first two waves
    (warm-up: the rebalancer needs a wave of signal before it reacts).
    Records the per-shard lock-convoy split plus the rebalancer /
    work-stealing counters next to the usual churn accounting.

    ``weights`` overrides the Zipf popularity vector (the ``single_hot_fn``
    cell passes one function ~80% of the load); ``fn_split`` enables the
    per-function creation sharding escalation (``cp_fn_split_enabled``)."""
    env = Environment(seed=seed)
    cl = make_dirigent(env, n_workers=n_workers, runtime="firecracker",
                       cp_shards=cp_shards, cp_rebalance_enabled=rebalance,
                       cp_fn_split_enabled=fn_split,
                       cp_fn_split_max_shards=fn_split_max_shards,
                       n_data_planes=n_data_planes,
                       dp_spread_enabled=dp_spread,
                       dp_conn_reuse=conn_reuse,
                       cp_ep_flush_coalesce=ep_coalesce,
                       costs=costs)
    if weights is None:
        weights = zipf_weights(n_functions, zipf_s)
    n_functions = len(weights)
    names = [f"{names_prefix}{i}" for i in range(n_functions)]
    per_period = rate * burst_period
    plan = []
    for i, name in enumerate(names):
        burst = int(round(weights[i] * per_period))
        if burst == 0:
            continue
        t = 0.05
        while t < duration:
            plan.extend((t, name, 0.1) for _ in range(burst))
            t += burst_period
    plan.sort()
    preload_functions(cl, names, SWEEP_SCALING)
    ev0, t0 = env.events_processed, time.perf_counter()
    # plan times are offsets from *traffic start*, which is env.now after
    # the O(n_workers)-fsyncs boot — the warmup cut must use the same origin
    # or it silently no-ops (or over-cuts) at large n_workers
    traffic_t0 = env.now
    invs = run_open_loop(env, cl, plan, until_extra=15.0)
    wall = time.perf_counter() - t0
    warmup = min(2 * burst_period, duration / 2)
    stats = latency_stats([i for i in invs
                           if i.arrival - traffic_t0 >= warmup],
                          "e2e_latency")
    leader = cl.control_plane_leader()
    lock_waits = sorted((s.lock_wait_s for s in leader.shards), reverse=True)
    return {
        "workers": n_workers, "rate": rate, "duration": duration,
        "n_functions": n_functions, "zipf_s": zipf_s,
        "burst_period": burst_period, "warmup": warmup,
        "cp_shards": cp_shards,
        "rebalance": rebalance, "fn_split": fn_split, "offered": len(plan),
        "wall_s": round(wall, 3), "sim_s": round(env.now, 3),
        "events": env.events_processed - ev0,
        "creations": cl.collector.sandbox_creations,
        "creations_per_sim_s": creations_per_sim_s(cl.collector),
        "fn_migrations": cl.collector.fn_migrations,
        "fn_splits": cl.collector.fn_splits,
        "fn_merges": cl.collector.fn_merges,
        "steals": cl.collector.steals,
        "steal_probes": cl.collector.steal_probes,
        "lock_wait_sim_s": round(sum(lock_waits), 4),
        "lock_wait_hottest_shard_s": round(lock_waits[0], 4),
        "n_data_planes": n_data_planes,
        "dp_spread": dp_spread, "dp_conn_reuse": conn_reuse,
        "ep_coalesce": ep_coalesce,
        "dp_spread_fns": len(cl.fn_dp_table),
        "conn_hits": sum(dp.conn_hits for dp in cl.data_planes),
        "conn_misses": sum(dp.conn_misses for dp in cl.data_planes),
        "done": stats["done"], "total": stats["total"],
        "p50_ms": round(stats["p50"] * 1e3, 3),
        "p99_ms": round(stats["p99"] * 1e3, 3),
        "mean_ms": round(stats["mean"] * 1e3, 3),
    }


def single_hot_fn_point(n_workers: int, rate: float, duration: float,
                        n_functions: int = 64, hot_share: float = 0.8,
                        burst_period: float = 4.0, seed: int = 93,
                        cp_shards: int = 4, rebalance: bool = True,
                        fn_split: bool = False,
                        fn_split_max_shards: "int | None" = None,
                        **dp_kw) -> dict:
    """One *dominant-function* cell: a single function carries ``hot_share``
    (~80%) of the offered creation load, the rest spread uniformly over the
    other functions — the irreducible-hotspot regime whole-function
    rebalancing cannot fix (moving the hot function just relocates its
    convoy). This is the cell per-function creation sharding
    (``cp_fn_split_enabled``) exists to improve: at equal shard count,
    splitting the hot function across a shard-set must cut the hot shard's
    lock wait and the post-warmup tail while total creations stay equal."""
    weights = np.full(n_functions, (1.0 - hot_share) / (n_functions - 1))
    weights[0] = hot_share
    cell = skew_point(n_workers, rate, duration, burst_period=burst_period,
                      seed=seed, cp_shards=cp_shards, rebalance=rebalance,
                      weights=weights, names_prefix="h", fn_split=fn_split,
                      fn_split_max_shards=fn_split_max_shards, **dp_kw)
    cell["hot_share"] = hot_share
    cell["fn_split_max_shards"] = fn_split_max_shards
    return cell


def _print_multi_dp(cell: dict) -> None:
    print(f"multi-dp workers={cell['workers']} rate={cell['rate']:.0f} "
          f"dps={cell['n_data_planes']} "
          f"spread={'on' if cell['dp_spread'] else 'off'} "
          f"reuse={'on' if cell['dp_conn_reuse'] else 'off'} "
          f"coalesce={'on' if cell['ep_coalesce'] else 'off'}: "
          f"spread_fns={cell['dp_spread_fns']} "
          f"conn_hits={cell['conn_hits']}, "
          f"p50={cell['p50_ms']:.1f}ms p99={cell['p99_ms']:.1f}ms "
          f"done={cell['done']}/{cell['total']}", flush=True)


def multi_dp_cells(smoke: bool = False) -> list:
    """The ``multi_dp_sweep`` cells: the single-hot-fn workload at and past
    the per-DP port ceiling, knobs off vs on.

    Full cells sit at rate 2500 (hot fn ≈ 2000 conn/s): each 4 s wave opens
    an 8000-connection burst whose ports ride 20 s of TIME_WAIT, so one DP's
    28k-port pool carries ~40k held ports by wave 5 — exhaustion mid-run
    (this is the cell the PR 5 sweep could not record). Spread across a
    width-3 DP-set the same load holds ~13k ports per DP; with connection
    reuse the ports held scale with *concurrent* requests, not request
    volume, and scale-to-zero teardown closes conns server-side (no
    TIME_WAIT accumulation). ``fn_split`` stays on so the CP's scale lock
    is not the binding constraint in any cell — what moves is the DP side.

    Smoke cells shrink the regime instead of the arithmetic: a 3k-port pool
    makes a 500-worker/rate-1000 cell (hot fn ≈ 3200-conn waves) exhaust a
    single DP just as surely, in seconds."""
    if smoke:
        import dataclasses
        from repro.core.costmodel import CostModel, DEFAULT_COSTS
        small = CostModel(dirigent=dataclasses.replace(
            DEFAULT_COSTS.dirigent, dp_port_pool=3000))
        base = dict(n_workers=500, rate=1000.0, duration=8.0, cp_shards=4,
                    rebalance=False, fn_split=True, costs=small)
        return [
            dict(base),
            dict(base, dp_spread=True, ep_coalesce=True),
            dict(base, conn_reuse=True),
        ]
    base = dict(n_workers=5000, duration=20.0, cp_shards=4,
                rebalance=False, fn_split=True)
    return [
        # below-ceiling reference: the regime PR 5 recorded
        dict(base, rate=1500.0),
        # above the ~1400 conn/s ceiling, knobs off: port exhaustion
        dict(base, rate=2500.0),
        # the two independent fixes, then everything on
        dict(base, rate=2500.0, dp_spread=True, ep_coalesce=True),
        dict(base, rate=2500.0, conn_reuse=True),
        dict(base, rate=2500.0, dp_spread=True, conn_reuse=True,
             ep_coalesce=True),
    ]


def run_multi_dp_sweep(smoke: bool = False) -> list:
    cells = []
    for kw in multi_dp_cells(smoke):
        cell = single_hot_fn_point(**kw)
        cells.append(cell)
        _print_multi_dp(cell)
    return cells


def run_multi_dp(out: str = "BENCH_churn.json", smoke: bool = False) -> dict:
    """``--multi-dp``: run only the multi-DP sweep and merge it into the
    existing out-file (preserving the recorded sweeps)."""
    cells = run_multi_dp_sweep(smoke)
    try:
        with open(out) as fh:
            result = json.load(fh)
    except (OSError, ValueError):
        result = {"meta": {"bench": "churn_scale"}}
    result["multi_dp_sweep"] = {"provenance": bench_provenance(),
                                "cells": cells}
    with open(out, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"wrote {out}", flush=True)
    return result


def failover_point(n_workers: int, cp_shards: int, rate: float = 1000.0,
                   duration: float = 8.0, kill_at: float = 4.0,
                   incremental: bool = True, seed: int = 77,
                   recovery_window: float = 2.0, n_hot: int = 16,
                   group_commit: bool = False, checkpoint: bool = False,
                   checkpoint_period: float = 1.5,
                   read_per_record: float = 0.0) -> dict:
    """One ``failover_scale`` cell: leader killed mid-churn, with a live
    fn→shard-set split and a whole-function migration in flight.

    Workload: a cold-churn plan (one never-seen function per arrival, so
    every post-recovery arrival is a creation demand) plus one capped hot
    function (``max_scale=n_hot``, no scale-to-zero) providing standing
    traffic whose replica count is pinned — its creation count cannot vary
    with recovery timing, so total creations must be EQUAL between the
    serial and incremental runs of a pair (the acceptance invariant).

    Churn arrivals pause for 0.3 s before the kill so no creation is in
    flight at the kill instant: the first ``sandbox-created`` event after
    the kill is then a creation *initiated* by the recovering leader, and
    time-to-first-creation cleanly decomposes into (election + replay-to-
    first-admission + sandbox boot). Both modes pay the same election and
    boot; what the sweep measures is the admission term — full-snapshot
    replay (serial) vs first-shard-unit completion (incremental).

    Pre-kill state the replay must handle: the hot function split across a
    shard-set (persisted override), one churn function migrated off its
    hash home (persisted override), and a second migration spawned 100 µs
    before the kill — mid-quiesce, never persisted, must roll back.

    The 100k extension: ``group_commit`` makes the boot feasible (O(batches)
    of fsync sim-time), ``read_per_record`` makes a full ``worker/`` prefix
    scan honestly record-count-proportional, and ``checkpoint`` gives the
    recovering leader a compacted snapshot + post-checkpoint delta instead
    of that scan — the off-vs-on pair at equal seed isolates what
    checkpointed recovery buys (creations must stay bit-equal)."""
    from repro.core.costmodel import DEFAULT_COSTS
    env = Environment(seed=seed)
    cl = make_dirigent(
        env, n_workers=n_workers, runtime="firecracker",
        cp_shards=cp_shards, enable_ha_sim=True,
        cp_incremental_recovery=incremental,
        cp_vector_windows=True,
        cp_rebalance_enabled=cp_shards > 1,
        cp_rebalance_period=1e9,          # handoffs driven explicitly below
        cp_fn_split_enabled=cp_shards > 1,
        hb_cohort_quantum=DEFAULT_COSTS.dirigent.worker_hb_cohort_quantum,
        persist_group_commit=group_commit,
        persist_read_per_record=read_per_record,
        cp_checkpoint_enabled=checkpoint,
        cp_checkpoint_period=checkpoint_period)
    gap = 0.3                              # pre-kill churn quiet period
    n_churn = int(rate * (duration - gap))
    churn_names = [f"c{i}" for i in range(n_churn)]
    preload_functions(cl, churn_names, SWEEP_SCALING, persist=True)
    preload_functions(cl, ["hot"],
                      dict(SWEEP_SCALING, stable_window=4.0,
                           scale_to_zero_grace=300.0, max_scale=n_hot),
                      persist=True)
    t0 = env.now
    invs = []
    hot_rate = 200.0
    plan = [(j / hot_rate, "hot", 0.1)
            for j in range(int(hot_rate * duration))]
    t, i = 0.0, 0
    while i < n_churn:
        if not (kill_at - gap <= t < kill_at):
            plan.append((t, churn_names[i], 0.05))
            i += 1
        t += 1.0 / rate
    plan.sort()

    def driver(env):
        t_prev = 0.0
        for t, fn, et in plan:
            if t > t_prev:
                yield env.timeout(t - t_prev)
                t_prev = t
            invs.append(cl.invoke(fn, exec_time=et))

    ev0, w0 = env.events_processed, time.perf_counter()
    env.process(driver(env), name="failover-driver")
    leader = cl.control_plane_leader()
    if cp_shards > 1:
        env.run(until=t0 + 2.0)
        # live split + one persisted migration for the replay to keep
        members = tuple(range(min(4, cp_shards)))
        env.process(leader._split_function("hot", members),
                    name="force-split")
        src = leader._fn_shard_id("c0")
        dst = (src + 1) % cp_shards
        env.process(leader._migrate_functions(
            leader.shards[src], leader.shards[dst], ["c0"]), name="force-mig")
        env.run(until=t0 + kill_at - 1e-4)
        # a second migration spawned mid-quiesce: in flight at the kill,
        # never persisted — replay must land c1 back on its hash home
        src2 = leader._fn_shard_id("c1")
        env.process(leader._migrate_functions(
            leader.shards[src2], leader.shards[(src2 + 1) % cp_shards],
            ["c1"]), name="inflight-mig")
    env.run(until=t0 + kill_at)
    t_kill = env.now
    pre_creations = cl.collector.sandbox_creations
    # what the recovering leader will actually see: snapshot epoch + the
    # post-checkpoint delta it replays per record instead of the full prefix
    ckpt_epoch_at_kill = cl.store.checkpoint_epoch
    ckpt_delta_at_kill = len(cl.store._ckpt_delta)
    cl.fail_control_plane_leader()
    env.run(until=t0 + duration + 30.0)
    wall = time.perf_counter() - w0

    col = cl.collector
    ttfc = col.first_event_at("sandbox-created", after=t_kill)
    recovered = col.first_event_at("cp-recovered", after=t_kill)
    shard_ts = col.event_times("cp-shard-recovered", after=t_kill)
    win = col.window_sched_latencies(t_kill, t_kill + recovery_window)
    stats = latency_stats(invs, "e2e_latency")
    return {
        "workers": n_workers, "cp_shards": cp_shards, "rate": rate,
        "duration": duration, "kill_at": kill_at,
        "mode": "incremental" if (incremental and cp_shards > 1)
                else "serial",
        "group_commit": group_commit, "checkpoint": checkpoint,
        "read_per_record": read_per_record,
        "checkpoint_epoch_at_kill": ckpt_epoch_at_kill,
        "checkpoint_delta_at_kill": ckpt_delta_at_kill,
        "wall_s": round(wall, 3),
        "events": env.events_processed - ev0,
        "creations": col.sandbox_creations,
        "creations_pre_kill": pre_creations,
        "fn_splits": col.fn_splits,
        "time_to_first_creation_s": (round(ttfc - t_kill, 6)
                                     if ttfc is not None else None),
        "recovered_s": (round(recovered - t_kill, 6)
                        if recovered is not None else None),
        "first_shard_admitted_s": (round(min(shard_ts) - t_kill, 6)
                                   if shard_ts else None),
        "shards_recovered": len(shard_ts),
        "recovery_window_s": recovery_window,
        "recovery_window_n": int(win.size),
        "recovery_window_p50_ms": (round(float(np.percentile(win, 50)) * 1e3,
                                         3) if win.size else None),
        "recovery_window_p99_ms": (round(float(np.percentile(win, 99)) * 1e3,
                                         3) if win.size else None),
        "done": stats["done"], "total": stats["total"],
        "p99_ms": round(stats["p99"] * 1e3, 3),
    }


def _print_failover(cell: dict) -> None:
    fs = cell["first_shard_admitted_s"]
    print(f"failover workers={cell['workers']} shards={cell['cp_shards']} "
          f"mode={cell['mode']} "
          f"ckpt={'on' if cell.get('checkpoint') else 'off'}: "
          f"ttfc={cell['time_to_first_creation_s']}s "
          f"recovered={cell['recovered_s']}s "
          f"first_shard={'-' if fs is None else f'{fs}s'} "
          f"win_p99={cell['recovery_window_p99_ms']}ms "
          f"creations={cell['creations']} "
          f"done={cell['done']}/{cell['total']}", flush=True)


def failover_cells(smoke: bool = False) -> list:
    """(workers, shards, incremental) rows. Shard count 1 has no per-shard
    units to parallelize — ``cp_incremental_recovery`` falls back to the
    serial path — so it is recorded once, as the serial anchor."""
    if smoke:
        return [(500, 4, False), (500, 4, True)]
    rows = []
    for w in (5000, 20_000, 50_000):
        rows.append((w, 1, False))
        for s in (4, 8):
            rows.append((w, s, False))
            rows.append((w, s, True))
    return rows


def run_failover_sweep(smoke: bool = False) -> list:
    cells = []
    for w, s, inc in failover_cells(smoke):
        cell = failover_point(w, s, incremental=inc)
        cells.append(cell)
        _print_failover(cell)
    if not smoke:
        for kw in failover_100k_cells():
            cell = failover_point(**kw)
            cells.append(cell)
            _print_failover(cell)
    return cells


def run_failover(out: str = "BENCH_churn.json", smoke: bool = False) -> dict:
    """``--failover``: run only the failover_scale sweep and merge it into
    the existing out-file (preserving the recorded sweeps)."""
    cells = run_failover_sweep(smoke)
    try:
        with open(out) as fh:
            result = json.load(fh)
    except (OSError, ValueError):
        result = {"meta": {"bench": "churn_scale"}}
    result["failover_scale"] = {"provenance": bench_provenance(),
                                "cells": cells}
    with open(out, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"wrote {out}", flush=True)
    return result


def boot_point(n_workers: int, group_commit: bool, cp_shards: int = 8,
               seed: int = 71, probe: bool = True) -> dict:
    """One ``boot_scale`` cell: cold cluster → every worker registered and
    heartbeating, the O(n_workers)-serialized-fsyncs path group commit
    exists to cut. ``store_crc`` digests the full store (keys AND values, in
    insertion order), so the off/on cells at equal worker count prove from
    the JSON alone that bulk registration landed the identical log; the
    probe cell is a small post-boot workload whose done/creation counts
    must match across the pair (equivalent post-boot behaviour)."""
    import zlib as _zlib
    from repro.core.costmodel import DEFAULT_COSTS
    env = Environment(seed=seed)
    w0 = time.perf_counter()
    cl = make_dirigent(
        env, n_workers=n_workers, runtime="firecracker",
        cp_shards=cp_shards, cp_vector_windows=True,
        hb_cohort_quantum=DEFAULT_COSTS.dirigent.worker_hb_cohort_quantum,
        persist_group_commit=group_commit)
    boot_sim, boot_wall = env.now, time.perf_counter() - w0
    store = cl.store
    crc = 0
    for k, v in store.data.items():
        crc = _zlib.crc32(v, _zlib.crc32(k.encode(), crc))
    cell = {
        "workers": n_workers, "cp_shards": cp_shards,
        "group_commit": group_commit,
        "boot_sim_s": round(boot_sim, 6),
        "boot_wall_s": round(boot_wall, 3),
        "write_count": store.write_count,
        "group_commits": store.group_commits,
        "group_commit_writes": store.group_commit_writes,
        "store_records": len(store.data),
        "store_crc": crc,
    }
    if probe:
        preload_functions(cl, ["probe"], SWEEP_SCALING)
        t0 = env.now
        invs = [cl.invoke("probe", exec_time=0.02) for _ in range(32)]
        env.run(until=t0 + 5.0)
        cell["probe_done"] = sum(1 for i in invs
                                 if i.t_done > 0 and not i.failed)
        cell["probe_creations"] = cl.collector.sandbox_creations
    return cell


def _print_boot(cell: dict) -> None:
    print(f"boot workers={cell['workers']} "
          f"gc={'on' if cell['group_commit'] else 'off'}: "
          f"sim={cell['boot_sim_s']:.3f}s wall={cell['boot_wall_s']:.1f}s "
          f"writes={cell['write_count']} commits={cell['group_commits']} "
          f"crc={cell['store_crc']} "
          f"probe={cell.get('probe_done')}/{cell.get('probe_creations')}",
          flush=True)


def run_boot_scale_sweep(smoke: bool = False) -> list:
    sizes = (2000,) if smoke else (20_000, 50_000, 100_000)
    cells = []
    for n in sizes:
        for gc in (False, True):
            cell = boot_point(n, group_commit=gc)
            cells.append(cell)
            _print_boot(cell)
    return cells


def run_boot_scale(out: str = "BENCH_churn.json",
                   smoke: bool = False) -> dict:
    """``--boot-scale``: run only the boot sweep (workers × group-commit
    off/on) and merge it into the existing out-file."""
    cells = run_boot_scale_sweep(smoke)
    try:
        with open(out) as fh:
            result = json.load(fh)
    except (OSError, ValueError):
        result = {"meta": {"bench": "churn_scale"}}
    result["boot_scale"] = {"provenance": bench_provenance(), "cells": cells}
    with open(out, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"wrote {out}", flush=True)
    return result


def failover_100k_cells() -> list:
    """The checkpointed-recovery pair: 100k workers, 8 shards, incremental
    recovery, group-commit boot, honest record-count-proportional prefix
    scans — identical except ``checkpoint``, so the delta-replay term is the
    only thing that moves (and creations must stay bit-equal)."""
    base = dict(n_workers=100_000, cp_shards=8, incremental=True,
                group_commit=True, read_per_record=1e-6)
    return [dict(base, checkpoint=False), dict(base, checkpoint=True)]


def run_failover_100k(out: str = "BENCH_churn.json") -> dict:
    """``--failover-100k``: run only the 100k checkpoint-off/on pair and
    append it to the recorded ``failover_scale`` cells (replacing any prior
    100k rows rather than re-running the whole sweep)."""
    cells = []
    for kw in failover_100k_cells():
        cell = failover_point(**kw)
        cells.append(cell)
        _print_failover(cell)
    try:
        with open(out) as fh:
            result = json.load(fh)
    except (OSError, ValueError):
        result = {"meta": {"bench": "churn_scale"}}
    section = result.setdefault("failover_scale", {"cells": []})
    section["cells"] = [c for c in section.get("cells", [])
                        if c["workers"] < 100_000] + cells
    section["provenance_100k"] = bench_provenance()
    with open(out, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"wrote {out}", flush=True)
    return result


def run_scale100k(out: str = "BENCH_churn.json") -> dict:
    """``--scale-100k``: the 100k-worker churn cells (cohort heartbeats +
    vector windows + group-commit boot, sharded CP), merged into the
    existing out-file."""
    cells = [
        churn_point(100_000, 1000, 4.0, cp_shards=8, hb_cohort=True,
                    vector_windows=True, group_commit=True),
        churn_point(100_000, 2500, 4.0, cp_shards=8, hb_cohort=True,
                    vector_windows=True, group_commit=True),
    ]
    for cell in cells:
        print(f"workers={cell['workers']} rate={cell['rate']} "
              f"gc={'on' if cell['group_commit'] else 'off'}: "
              f"{cell['events_per_wall_s']:.0f} ev/s wall, "
              f"{cell['events_per_creation']} events/creation, "
              f"p99={cell['p99_ms']:.1f}ms "
              f"done={cell['done']}/{cell['total']}", flush=True)
    try:
        with open(out) as fh:
            result = json.load(fh)
    except (OSError, ValueError):
        result = {"meta": {"bench": "churn_scale"}}
    result["scale_100k"] = {"provenance": bench_provenance(), "cells": cells}
    with open(out, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"wrote {out}", flush=True)
    return result


def run_scale50k(out: str = "BENCH_churn.json") -> dict:
    """``--scale-50k``: the 50k-worker churn cells (cohort heartbeats +
    vector windows on, plus a cohort-off companion at 20k for the
    events/creation comparison), merged into the existing out-file."""
    cells = [
        churn_point(20_000, 1000, 4.0, hb_cohort=True, vector_windows=True),
        churn_point(50_000, 1000, 4.0, hb_cohort=True, vector_windows=True),
    ]
    for cell in cells:
        print(f"workers={cell['workers']} rate={cell['rate']} "
              f"cohort={'on' if cell['hb_cohort'] else 'off'}: "
              f"{cell['events_per_wall_s']:.0f} ev/s wall, "
              f"{cell['events_per_creation']} events/creation, "
              f"p99={cell['p99_ms']:.1f}ms "
              f"done={cell['done']}/{cell['total']}", flush=True)
    try:
        with open(out) as fh:
            result = json.load(fh)
    except (OSError, ValueError):
        result = {"meta": {"bench": "churn_scale"}}
    result["scale_50k"] = {"provenance": bench_provenance(), "cells": cells}
    with open(out, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"wrote {out}", flush=True)
    return result


def live_smoke_point(n_workers: int = 8, n_functions: int = 16,
                     rate: float = 50.0, duration: float = 2.0,
                     seed: int = 7, replica_dim: int = 96) -> dict:
    """Live-mode churn smoke: a small workers×rate cell where every sandbox
    creation runs a *real* ``create_hook`` (allocate + warm a small replica —
    matmul standing in for snapshot-restore/model-load work), so wall-clock
    creation throughput includes genuine payload construction next to the
    DES numbers (ROADMAP "live-mode churn bench"). Teardown of a live
    sandbox reclaims its replica through the worker's ``teardown_hook``
    (symmetric with ``create_hook``), so churn exercises build *and*
    reclaim — the cell asserts zero leaked replicas."""
    env = Environment(seed=seed)
    replicas: dict = {}
    hook_wall = [0.0]

    def create_replica(sandbox):
        t0 = time.perf_counter()
        rng = np.random.default_rng(sandbox.sandbox_id)
        w = rng.standard_normal((replica_dim, replica_dim))
        w = w @ w.T                    # "warm-up" compute, like a real restore
        replicas[sandbox.sandbox_id] = w
        hook_wall[0] += time.perf_counter() - t0

    def teardown_replica(sandbox_id, drain=True):
        # the kill path owns the reclaim (no post-run sweep): kill_sandbox /
        # fail_node call this for every sandbox they dismantle
        replicas.pop(sandbox_id, None)

    cl = make_dirigent(env, n_workers=n_workers, runtime="firecracker",
                       create_hook=create_replica,
                       teardown_hook=teardown_replica)
    plan = [(i / rate, f"lf{i % n_functions}", 0.02)
            for i in range(int(rate * duration))]
    preload_functions(cl, [p[1] for p in plan], SWEEP_SCALING)
    ev0, t0 = env.events_processed, time.perf_counter()
    invs = run_open_loop(env, cl, plan, until_extra=10.0)
    wall = time.perf_counter() - t0
    # every replica still held must belong to a live sandbox: the teardown
    # hook reclaimed the rest as the autoscaler scaled down
    live_ids = {sid for w in cl.workers.values() for sid in w.sandboxes}
    leaked = [s for s in replicas if s not in live_ids]
    assert not leaked, f"teardown_hook leaked {len(leaked)} replicas"
    stats = latency_stats(invs, "e2e_latency")
    creations = cl.collector.sandbox_creations
    return {
        "workers": n_workers, "rate": rate, "duration": duration,
        "n_functions": n_functions, "replica_dim": replica_dim,
        "wall_s": round(wall, 3), "sim_s": round(env.now, 3),
        "events": env.events_processed - ev0,
        "creations": creations,
        "creations_per_wall_s": round(creations / wall, 1),
        "create_hook_wall_s": round(hook_wall[0], 4),
        "create_hook_ms_mean": round(1e3 * hook_wall[0] / max(creations, 1), 3),
        "live_replicas": len(replicas),
        "leaked_replicas": len(leaked),
        "done": stats["done"], "total": stats["total"],
        "p50_ms": round(stats["p50"] * 1e3, 3),
        "p99_ms": round(stats["p99"] * 1e3, 3),
    }


def _print_live_smoke(cell: dict) -> None:
    print(f"live-smoke workers={cell['workers']} rate={cell['rate']:.0f}: "
          f"{cell['creations_per_wall_s']:.0f} creations/s wall "
          f"(hook {cell['create_hook_ms_mean']:.2f} ms/creation), "
          f"p50={cell['p50_ms']:.1f}ms p99={cell['p99_ms']:.1f}ms "
          f"done={cell['done']}/{cell['total']}", flush=True)


def run_live_smoke(out: str = "BENCH_churn.json") -> dict:
    """``--live-smoke``: run the live-mode churn cell plus one real-invoke
    live cell (tiny truncated smollm; payload executed end-to-end through
    CP -> DP -> worker -> batcher) and merge both into the out-file. This
    is the CI leg: seconds-scale, wall-clock numbers recorded but never
    asserted on (timing is machine-dependent); the *functional* bits —
    zero leaked replicas, every completed invoke carrying real tokens —
    are asserted."""
    cell = live_smoke_point()
    _print_live_smoke(cell)
    real = live_grid_point(4, 20.0, 1.0, n_functions=2)
    real.pop("_start_log")
    real.pop("_invoke_walls")
    assert real["done"] > 0 and real["tokens"] > 0, \
        "live smoke executed no real payloads"
    cell["real_invoke"] = real
    print(f"live real-invoke: done={real['done']}/{real['total']} "
          f"tokens={real['tokens']} "
          f"(cold {real['cold_create_ms_median']}ms / warm "
          f"{real['warm_create_ms_median']}ms)", flush=True)
    try:
        with open(out) as fh:
            result = json.load(fh)
    except (OSError, ValueError):
        result = {"meta": {"bench": "churn_scale"}}
    # this cell's provenance rides inside the cell: the file-level
    # meta.provenance keeps describing the run that produced the sweeps
    cell["provenance"] = bench_provenance()
    result["live_smoke"] = cell
    with open(out, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"wrote {out}", flush=True)
    return cell


# -- live execution mode (real JAX payloads; ISSUE 10) ------------------------

def _live_spec(mode: str = "process", max_slots: int = 4,
               max_seq: int = 64, max_new: int = 8):
    """Tiny truncated smollm config every live cell shares (CPU-feasible:
    ~1-2 s cold compile, ~5 ms warm replica build)."""
    from repro.configs import get_config
    from repro.live import LiveFunctionSpec

    cfg = get_config("smollm-360m").reduced(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab=128)
    return LiveFunctionSpec(cfg=cfg, mode=mode, max_seq=max_seq,
                            max_slots=max_slots, default_max_new=max_new)


def live_cold_warm_point(mode: str = "process", n_warm: int = 4) -> dict:
    """Cold vs warm live sandbox creation — the shared-executable-cache
    headline (acceptance: warm >= 10x faster than cold).

    Process mode: a fresh ``ExecutableCache``; the first creation compiles
    (cold), the rest hit the cache (warm). Container mode: a fresh
    persistent-compile-cache dir; the first spawned worker compiles and
    populates it, the next deserializes instead (n_warm is clamped to 1 —
    workers cost seconds each)."""
    import shutil
    import tempfile

    from repro.core.abstractions import Sandbox
    from repro.live import LiveBackend
    from repro.serving.exec_cache import ExecutableCache

    cache_dir = tempfile.mkdtemp(prefix="live-xla-cache-") \
        if mode == "container" else None
    if mode == "container":
        n_warm = 1
    lb = LiveBackend(default_spec=_live_spec(mode),
                     exec_cache=ExecutableCache(),
                     compile_cache_dir=cache_dir)
    try:
        for i in range(1 + n_warm):
            sb = Sandbox(sandbox_id=i + 1, function_name="lf",
                         ip=(10, 0, 0, 1), port=80, worker_id=0)
            lb.create_hook(sb)
        rows = lb.start_log
        assert rows[0]["cold"] and not any(r["cold"] for r in rows[1:]), \
            "cold/warm split did not land where expected"
        cold = rows[0]["wall_s"]
        warm = float(np.median([r["wall_s"] for r in rows[1:]]))
        return {"mode": mode, "cold_create_s": round(cold, 4),
                "warm_create_s": round(warm, 4),
                "warm_speedup": round(cold / max(warm, 1e-9), 1),
                "n_warm": n_warm,
                "exec_cache": lb.cache_stats()}
    finally:
        lb.close()
        if cache_dir:
            shutil.rmtree(cache_dir, ignore_errors=True)


def live_grid_point(n_workers: int, rate: float, duration: float,
                    mode: str = "process", n_functions: int = 8,
                    seed: int = 11, max_slots: int = 4) -> dict:
    """One live workers x rate cell: every invocation carries a real
    ``LiveRequest`` executed in the dispatched sandbox's batcher."""
    from repro.core.request import LiveRequest
    from repro.live import LiveBackend
    from repro.serving.exec_cache import ExecutableCache

    env = Environment(seed=seed)
    lb = LiveBackend(default_spec=_live_spec(mode, max_slots=max_slots),
                     exec_cache=ExecutableCache())
    cl = make_dirigent(env, n_workers=n_workers, runtime="firecracker",
                       live_backend=lb, sandbox_concurrency=max_slots)
    plan = [(i / rate, f"lf{i % n_functions}", 0.02)
            for i in range(int(rate * duration))]
    preload_functions(cl, [p[1] for p in plan], SWEEP_SCALING)
    rng = np.random.default_rng(seed)
    prompts = rng.integers(1, 127, size=(len(plan), 4))

    def req_factory(i):
        return LiveRequest(prompt=[int(t) for t in prompts[i]],
                           max_new_tokens=8)

    ev0, t0 = env.events_processed, time.perf_counter()
    invs = run_open_loop(env, cl, plan, until_extra=10.0,
                         request_factory=req_factory)
    wall = time.perf_counter() - t0
    try:
        done = [i for i in invs if i.t_done > 0 and not i.failed]
        executed = [i for i in done if i.request.tokens is not None]
        assert len(executed) == len(done), \
            "completed invocation without real payload execution"
        stats = latency_stats(invs, "e2e_latency")
        inv_walls = sorted(i.request.wall_s for i in executed) or [0.0]
        creations = cl.collector.sandbox_creations
        cold = [r for r in lb.start_log if r["cold"]]
        warm = [r for r in lb.start_log if not r["cold"]]
        return {
            "workers": n_workers, "rate": rate, "duration": duration,
            "mode": mode, "n_functions": n_functions,
            "max_slots": max_slots,
            "wall_s": round(wall, 3), "sim_s": round(env.now, 3),
            "events": env.events_processed - ev0,
            "creations": creations,
            "creations_per_wall_s": round(creations / wall, 1),
            "cold_creates": len(cold), "warm_creates": len(warm),
            "cold_create_ms_median": round(
                1e3 * float(np.median([r["wall_s"] for r in cold])), 2)
            if cold else None,
            "warm_create_ms_median": round(
                1e3 * float(np.median([r["wall_s"] for r in warm])), 2)
            if warm else None,
            "done": stats["done"], "total": stats["total"],
            "p50_ms": round(stats["p50"] * 1e3, 3),
            "p99_ms": round(stats["p99"] * 1e3, 3),
            "invoke_wall_p50_ms": round(
                1e3 * inv_walls[len(inv_walls) // 2], 3),
            "invoke_wall_p99_ms": round(
                1e3 * inv_walls[int(len(inv_walls) * 0.99) - 1], 3),
            "tokens": lb.tokens_total,
            "tokens_per_wall_s": round(lb.tokens_total / wall, 1),
            "batched_invokes": lb.batched_invokes,
            "exec_cache": lb.cache_stats(),
            "_start_log": lb.start_log,
            "_invoke_walls": inv_walls,
        }
    finally:
        lb.close()


def live_azure_slice(n_functions: int = 10, duration: float = 6.0,
                     target_invocations: int = 150, n_workers: int = 16,
                     seed: int = 42) -> dict:
    """Azure-trace slice replayed end-to-end in live mode: the Shahrad-style
    workload shape (Zipf popularity, lognormal exec times, timer + Poisson
    arrivals) with a real ``LiveRequest`` on every invocation."""
    from benchmarks.azure_trace import generate_azure_like_trace
    from repro.core.request import LiveRequest
    from repro.live import LiveBackend
    from repro.serving.exec_cache import ExecutableCache

    trace = generate_azure_like_trace(
        n_functions=n_functions, duration=duration,
        target_invocations=target_invocations, seed=seed,
        timer_fraction=0.2, n_timer_groups=2)
    env = Environment(seed=seed)
    lb = LiveBackend(default_spec=_live_spec("process"),
                     exec_cache=ExecutableCache())
    cl = make_dirigent(env, n_workers=n_workers, runtime="firecracker",
                       live_backend=lb, sandbox_concurrency=4)
    preload_functions(cl, [f.name for f in trace.functions], SWEEP_SCALING)
    rng = np.random.default_rng(seed)
    prompts = rng.integers(1, 127, size=(len(trace.invocations), 4))

    def req_factory(i):
        return LiveRequest(prompt=[int(t) for t in prompts[i]],
                           max_new_tokens=8)

    t0 = time.perf_counter()
    invs = run_open_loop(env, cl, trace.invocations, until_extra=15.0,
                         request_factory=req_factory)
    wall = time.perf_counter() - t0
    try:
        done = [i for i in invs if i.t_done > 0 and not i.failed]
        executed = [i for i in done if i.request.tokens is not None]
        assert len(executed) == len(done), \
            "azure slice: completed invocation without payload execution"
        stats = latency_stats(invs, "e2e_latency")
        return {
            "n_functions": n_functions, "trace_duration": duration,
            "invocations": len(trace.invocations),
            "workers": n_workers,
            "wall_s": round(wall, 3), "sim_s": round(env.now, 3),
            "creations": cl.collector.sandbox_creations,
            "done": stats["done"], "total": stats["total"],
            "real_payloads_executed": len(executed),
            "p50_ms": round(stats["p50"] * 1e3, 3),
            "p99_ms": round(stats["p99"] * 1e3, 3),
            "tokens": lb.tokens_total,
            "batched_invokes": lb.batched_invokes,
            "exec_cache": lb.cache_stats(),
        }
    finally:
        lb.close()


def live_grid_section(smoke: bool = False) -> dict:
    """The live execution sweep — cold/warm creation for both modes, a
    workers x rate grid with real payloads on every invoke, and an
    Azure-trace slice. Per-phase wall measurements are folded into a
    calibrated ``DirigentCosts`` candidate (``costs_candidate``) for DES
    cross-checking."""
    from repro.core.costmodel import live_calibrated_candidate

    section: dict = {"provenance": bench_provenance()}
    section["cold_warm"] = [live_cold_warm_point("process")]
    if not smoke:
        section["cold_warm"].append(live_cold_warm_point("container"))
    for row in section["cold_warm"]:
        print(f"live cold/warm mode={row['mode']}: "
              f"cold={row['cold_create_s'] * 1e3:.0f}ms "
              f"warm={row['warm_create_s'] * 1e3:.1f}ms "
              f"-> {row['warm_speedup']:.0f}x", flush=True)

    grid = ([(8, 50.0, 2.0)] if smoke
            else [(8, 50.0, 2.0), (16, 100.0, 2.0), (32, 200.0, 2.0)])
    cells, start_log, invoke_walls = [], [], []
    for w, r, d in grid:
        cell = live_grid_point(w, r, d)
        start_log += cell.pop("_start_log")
        invoke_walls += cell.pop("_invoke_walls")
        cells.append(cell)
        print(f"live workers={w} rate={r:.0f}: "
              f"{cell['creations_per_wall_s']:.0f} creations/s wall "
              f"(cold {cell['cold_create_ms_median']}ms / warm "
              f"{cell['warm_create_ms_median']}ms), "
              f"invoke p50={cell['invoke_wall_p50_ms']}ms "
              f"p99={cell['invoke_wall_p99_ms']}ms, "
              f"{cell['tokens_per_wall_s']:.0f} tok/s, "
              f"batched={cell['batched_invokes']}, "
              f"done={cell['done']}/{cell['total']}", flush=True)
    section["grid"] = cells
    section["azure_slice"] = live_azure_slice()
    az = section["azure_slice"]
    print(f"live azure slice: {az['real_payloads_executed']}/{az['total']} "
          f"real invokes, {az['creations']} creations, "
          f"p99={az['p99_ms']:.1f}ms, {az['tokens']} tokens", flush=True)
    # container cold/warm rows feed the candidate too
    for row in section["cold_warm"]:
        start_log.append({"mode": row["mode"], "cold": True,
                          "wall_s": row["cold_create_s"]})
        start_log.append({"mode": row["mode"], "cold": False,
                          "wall_s": row["warm_create_s"]})
    section["costs_candidate"] = live_calibrated_candidate(
        start_log, invoke_walls)
    return section


def run_live_grid(out: str = "BENCH_churn.json",
                  smoke: bool = False) -> dict:
    """``--live-grid``: run the live execution sweep alone and merge it
    into the out-file."""
    section = live_grid_section(smoke=smoke)
    try:
        with open(out) as fh:
            result = json.load(fh)
    except (OSError, ValueError):
        result = {"meta": {"bench": "churn_scale"}}
    result["live_grid"] = section
    with open(out, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"wrote {out}", flush=True)
    return section


def run_bench(smoke: bool = False, out: str = "BENCH_churn.json") -> dict:
    with open(out, "a"):               # fail on an unwritable path up front,
        pass                           # not after minutes of sweep
    # perf_trajectory records deliberate before/after perf work; it must
    # survive re-runs of the sweep
    try:
        with open(out) as fh:
            trajectory = json.load(fh).get("perf_trajectory", [])
    except (OSError, ValueError):
        trajectory = []
    result = {"meta": {"bench": "churn_scale", "smoke": smoke,
                       "provenance": bench_provenance()},
              "placer_microbench": [], "grid": []}
    if trajectory:
        result["perf_trajectory"] = trajectory

    # -- placer microbench: incremental index vs seed brute-force rescan ----
    micro_nodes = 1000 if smoke else 5000
    micro_ops = 2000 if smoke else 20_000
    brute_ops = 500 if smoke else 2000   # brute is slow; scale its op count
    fast = placer_microbench(micro_nodes, micro_ops, use_index=True)
    brute = placer_microbench(micro_nodes, brute_ops, use_index=False)
    part = placer_microbench(micro_nodes, micro_ops, use_index=True,
                             policy="partitioned")
    speedup = fast["places_per_s"] / brute["places_per_s"]
    result["placer_microbench"] = [fast, brute, part]
    result["placer_index_speedup"] = round(speedup, 1)
    print(f"placer@{micro_nodes}: index {fast['places_per_s']:.0f}/s, "
          f"brute {brute['places_per_s']:.0f}/s, "
          f"partitioned {part['places_per_s']:.0f}/s "
          f"-> {speedup:.1f}x index speedup", flush=True)

    # -- churn grid ---------------------------------------------------------
    # 10k/20k workers joined the grid once the PR 4 event tax made them
    # wall-clock feasible: with heartbeats ~1 event/beat and netcfg timers
    # demand-driven, cost now scales with the *workload*, and these cells
    # record where the next bottleneck bites (see docs/benchmarks.md)
    if smoke:
        grid = [(93, 500, 1.0), (1000, 1000, 1.0)]
    else:
        grid = [(w, r, 4.0)
                for w in (93, 1000, 2500, 5000, 10_000, 20_000)
                for r in (1000, 2500)]
    for n_workers, rate, duration in grid:
        cell = churn_point(n_workers, rate, duration)
        result["grid"].append(cell)
        print(f"workers={n_workers} rate={rate}: "
              f"{cell['events_per_wall_s']:.0f} ev/s wall, "
              f"{cell['creations_per_wall_s']:.0f} creations/s wall, "
              f"p99={cell['p99_ms']:.1f}ms "
              f"done={cell['done']}/{cell['total']}", flush=True)

    # partitioned-placer spot check at the largest scale in the grid
    w, r, d = grid[-1]
    cell = churn_point(w, r, d, placement_policy="partitioned")
    result["grid"].append(cell)
    print(f"workers={w} rate={r} policy=partitioned: "
          f"{cell['events_per_wall_s']:.0f} ev/s wall, "
          f"p99={cell['p99_ms']:.1f}ms done={cell['done']}/{cell['total']}",
          flush=True)

    # -- control-plane shard sweep (the C1/C9 regime) -----------------------
    # one scale-lock's modeled budget is ~2700 creations/s minus the
    # heartbeat tax; drive at and beyond it and watch the shards divide it
    if smoke:
        shard_cells = [(1000, 2000.0, 1.0, s) for s in (1, 4)]
    else:
        # the 20k rows pair with the grid's cp_shards=1 cells: at that
        # worker count the C9 heartbeat tax alone (~53% of one lock)
        # saturates an unsharded CP — sharding is the fix, not an option
        shard_cells = ([(5000, 2500.0, 4.0, s) for s in (1, 2, 4, 8)]
                       + [(5000, 5000.0, 4.0, s) for s in (1, 2, 4)]
                       + [(20_000, 2500.0, 4.0, s) for s in (4, 8)])
    result["cp_shard_sweep"] = []
    for n_workers, rate, duration, s in shard_cells:
        cell = churn_point(n_workers, rate, duration, cp_shards=s)
        result["cp_shard_sweep"].append(cell)
        print(f"workers={n_workers} rate={rate:.0f} cp_shards={s}: "
              f"{cell['creations_per_sim_s']} creations/sim_s, "
              f"lock_wait={cell['lock_wait_sim_s']}s, "
              f"p50={cell['p50_ms']:.1f}ms p99={cell['p99_ms']:.1f}ms "
              f"done={cell['done']}/{cell['total']}", flush=True)

    # -- skewed-popularity sweep (hot-shard regime; rebalance off vs on) ----
    # Zipf mix: static hashing piles the popular functions' creation bursts
    # onto one shard's scale lock; the load-adaptive CP spreads them
    if smoke:
        skew_cells = [(500, 1000.0, 8.0, 1, False),
                      (500, 1000.0, 8.0, 4, False),
                      (500, 1000.0, 8.0, 4, True)]
    else:
        skew_cells = [(5000, 2500.0, 20.0, s, rb)
                      for s in (1, 2, 4, 8) for rb in (False, True)
                      if not (s == 1 and rb)]
    result["skew_sweep"] = []
    for n_workers, rate, duration, s, rb in skew_cells:
        cell = skew_point(n_workers, rate, duration,
                          cp_shards=s, rebalance=rb)
        result["skew_sweep"].append(cell)
        print(f"workers={n_workers} zipf rate={rate:.0f} cp_shards={s} "
              f"rebalance={'on' if rb else 'off'}: "
              f"{cell['creations_per_sim_s']} creations/sim_s, "
              f"hot_lock_wait={cell['lock_wait_hottest_shard_s']}s, "
              f"migrations={cell['fn_migrations']} steals={cell['steals']}, "
              f"p50={cell['p50_ms']:.1f}ms p99={cell['p99_ms']:.1f}ms "
              f"mean={cell['mean_ms']:.1f}ms "
              f"done={cell['done']}/{cell['total']}", flush=True)

    # -- single dominant function (the fn->shard-set regime) ----------------
    # one function carries ~80% of the creation load: whole-function
    # rebalancing cannot fix its shard (static and rebalance-on baselines),
    # per-function creation sharding can (fn_split on) — recorded at equal
    # shard counts so the improvement is attributable to the split alone
    if smoke:
        hot_cells = [(500, 1000.0, 8.0, 4, True, False, None),
                     (500, 1000.0, 8.0, 4, True, True, None)]
    else:
        # rate 1500 (hot fn = 1200 creations/s) keeps the cell in the regime
        # where the CP scale lock is the *binding* constraint: all of one
        # function's dispatches go through one DP (function-hash steering),
        # and a DP's port pool sustains ~1400 conn/s (28k ports / 20s
        # TIME_WAIT, the C5 ceiling) — at hot rates above it the cell would
        # measure port exhaustion, which no CP-side mechanism can fix
        hot_cells = [(5000, 1500.0, 20.0, s, rb, sp, mx)
                     for s, rb, sp, mx in (
                         # static baseline / rebalance-only (ping-pongs the
                         # hotspot) / split-only (the clean off-vs-on pair)
                         # / both escalations together
                         (4, False, False, None),
                         (4, True, False, None),
                         (4, False, True, None),
                         (4, True, True, None),
                         (8, False, False, None),
                         (8, True, False, None),
                         (8, False, True, 8),
                         (8, True, True, 8),
                     )]
    result["single_hot_fn"] = []
    for n_workers, rate, duration, s, rb, sp, mx in hot_cells:
        cell = single_hot_fn_point(n_workers, rate, duration, cp_shards=s,
                                   rebalance=rb, fn_split=sp,
                                   fn_split_max_shards=mx)
        result["single_hot_fn"].append(cell)
        print(f"workers={n_workers} hot80 rate={rate:.0f} cp_shards={s} "
              f"rebalance={'on' if rb else 'off'} "
              f"split={'on' if sp else 'off'}: "
              f"{cell['creations_per_sim_s']} creations/sim_s, "
              f"hot_lock_wait={cell['lock_wait_hottest_shard_s']}s, "
              f"splits={cell['fn_splits']} merges={cell['fn_merges']} "
              f"migrations={cell['fn_migrations']}, "
              f"p50={cell['p50_ms']:.1f}ms p99={cell['p99_ms']:.1f}ms "
              f"done={cell['done']}/{cell['total']}", flush=True)

    # -- multi-data-plane sweep (the C5 port-ceiling regime) ----------------
    result["multi_dp_sweep"] = {"provenance": result["meta"]["provenance"],
                                "cells": run_multi_dp_sweep(smoke)}

    # -- failover at scale (serial vs incremental leader recovery) ----------
    result["failover_scale"] = {"provenance": result["meta"]["provenance"],
                                "cells": run_failover_sweep(smoke)}

    # -- 50k-worker cells (cohort heartbeats + vector windows) --------------
    if not smoke:
        result["scale_50k"] = {
            "provenance": result["meta"]["provenance"],
            "cells": [churn_point(20_000, 1000, 4.0, hb_cohort=True,
                                  vector_windows=True),
                      churn_point(50_000, 1000, 4.0, hb_cohort=True,
                                  vector_windows=True)]}

    # -- live-mode smoke (real create_hook payloads; ROADMAP item) ----------
    result["live_smoke"] = cell = live_smoke_point()
    _print_live_smoke(cell)

    # -- live execution sweep (real JAX payloads on the invoke path) --------
    if not smoke:
        result["live_grid"] = live_grid_section(smoke=False)

    with open(out, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"wrote {out}", flush=True)
    return result


def run(reporter, quick: bool = True) -> dict:
    """benchmarks/run.py harness adapter (CSV reporter contract)."""
    result = run_bench(smoke=quick)
    for row in result["placer_microbench"]:
        tag = "partitioned" if row["policy"] == "partitioned" else (
            "index" if row["use_index"] else "brute")
        reporter.add(f"churn/placer-{tag}@{row['n_nodes']}",
                     1e6 / max(row["places_per_s"], 1e-9),
                     f"places_per_s={row['places_per_s']}")
    for cell in result["grid"]:
        reporter.add(
            f"churn/workers={cell['workers']}/rate={cell['rate']}"
            + ("" if cell["policy"] == "balanced" else f"/{cell['policy']}"),
            cell["p50_ms"] * 1e3,
            f"p99_ms={cell['p99_ms']};ev_per_wall_s={cell['events_per_wall_s']}")
    for cell in result.get("cp_shard_sweep", []):
        reporter.add(
            f"churn/shards={cell['cp_shards']}/workers={cell['workers']}"
            f"/rate={cell['rate']}",
            cell["p50_ms"] * 1e3,
            f"p99_ms={cell['p99_ms']};"
            f"creations_per_sim_s={cell['creations_per_sim_s']};"
            f"lock_wait_sim_s={cell['lock_wait_sim_s']}")
    for cell in result.get("skew_sweep", []):
        reporter.add(
            f"churn/skew/shards={cell['cp_shards']}"
            f"/rebalance={'on' if cell['rebalance'] else 'off'}",
            cell["p50_ms"] * 1e3,
            f"p99_ms={cell['p99_ms']};"
            f"creations_per_sim_s={cell['creations_per_sim_s']};"
            f"hot_lock_wait_s={cell['lock_wait_hottest_shard_s']};"
            f"migrations={cell['fn_migrations']};steals={cell['steals']}")
    for cell in result.get("single_hot_fn", []):
        reporter.add(
            f"churn/hotfn/shards={cell['cp_shards']}"
            f"/rebalance={'on' if cell['rebalance'] else 'off'}"
            f"/split={'on' if cell['fn_split'] else 'off'}",
            cell["p50_ms"] * 1e3,
            f"p99_ms={cell['p99_ms']};"
            f"hot_lock_wait_s={cell['lock_wait_hottest_shard_s']};"
            f"splits={cell['fn_splits']};merges={cell['fn_merges']}")
    for cell in result.get("failover_scale", {}).get("cells", []):
        ttfc = cell["time_to_first_creation_s"]
        reporter.add(
            f"churn/failover/workers={cell['workers']}"
            f"/shards={cell['cp_shards']}/{cell['mode']}",
            (ttfc or 0.0) * 1e6,
            f"recovered_s={cell['recovered_s']};"
            f"win_p99_ms={cell['recovery_window_p99_ms']};"
            f"creations={cell['creations']}")
    for cell in result.get("multi_dp_sweep", {}).get("cells", []):
        reporter.add(
            f"churn/multidp/rate={cell['rate']:.0f}"
            f"/spread={'on' if cell['dp_spread'] else 'off'}"
            f"/reuse={'on' if cell['dp_conn_reuse'] else 'off'}",
            cell["p50_ms"] * 1e3,
            f"p99_ms={cell['p99_ms']};done={cell['done']};"
            f"spread_fns={cell['dp_spread_fns']};"
            f"conn_hits={cell['conn_hits']}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset for CI")
    ap.add_argument("--live-smoke", action="store_true",
                    help="run only the live-mode (create_hook) churn cell "
                         "and merge it into --out")
    ap.add_argument("--live-grid", action="store_true",
                    help="run only the live execution sweep (real JAX "
                         "payloads: cold/warm creation, workers x rate, "
                         "Azure slice) and merge it into --out (honors "
                         "--smoke)")
    ap.add_argument("--multi-dp", action="store_true",
                    help="run only the multi-data-plane sweep and merge it "
                         "into --out (honors --smoke)")
    ap.add_argument("--failover", action="store_true",
                    help="run only the failover_scale sweep (leader killed "
                         "mid-churn; serial vs incremental recovery) and "
                         "merge it into --out (honors --smoke)")
    ap.add_argument("--scale-50k", action="store_true",
                    help="run only the 50k-worker churn cells (cohort "
                         "heartbeats + vector windows) and merge into --out")
    ap.add_argument("--scale-100k", action="store_true",
                    help="run only the 100k-worker churn cells (group-commit "
                         "boot, 8 CP shards) and merge into --out")
    ap.add_argument("--boot-scale", action="store_true",
                    help="run only the boot sweep (workers x group-commit "
                         "off/on) and merge into --out (honors --smoke)")
    ap.add_argument("--failover-100k", action="store_true",
                    help="run only the 100k checkpoint-off/on failover pair "
                         "and append it to the recorded failover_scale cells")
    ap.add_argument("--out", default="BENCH_churn.json")
    args = ap.parse_args()
    if args.live_smoke:
        run_live_smoke(out=args.out)
    elif args.live_grid:
        run_live_grid(out=args.out, smoke=args.smoke)
    elif args.multi_dp:
        run_multi_dp(out=args.out, smoke=args.smoke)
    elif args.failover:
        run_failover(out=args.out, smoke=args.smoke)
    elif args.failover_100k:
        run_failover_100k(out=args.out)
    elif args.scale_50k:
        run_scale50k(out=args.out)
    elif args.scale_100k:
        run_scale100k(out=args.out)
    elif args.boot_scale:
        run_boot_scale(out=args.out, smoke=args.smoke)
    else:
        run_bench(smoke=args.smoke, out=args.out)
