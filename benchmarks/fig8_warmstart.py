"""Fig 8 — warm-start performance: p50/p99 E2E latency vs request rate.

One pre-scaled function; every invocation finds a warm sandbox, so only the
data plane is exercised. Paper targets (C5): Dirigent sustains 4000/s at
p50 1.4 ms / p99 2.5 ms (port exhaustion beyond); Knative peaks ≈1200/s at
p50 7 ms (activator CPU); OpenWhisk adds Kafka+CouchDB latency.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    latency_stats, make_dirigent, make_knative, preload_functions,
    run_open_loop,
)
from repro.core.abstractions import Sandbox, SandboxState
from repro.simcore import Environment, stable_hash

EXEC_TIME = 0.3e-3   # hello-world
N_FUNCTIONS = 30   # spread across DP replicas by function-hash steering


def _prescale_dirigent(cl, fn: str, n_sandboxes: int) -> None:
    """Install ready sandboxes directly (the measured path is warm routing)."""
    leader = cl.control_plane_leader()
    st = leader.functions[fn]
    wids = list(cl.workers.keys())
    base = stable_hash(fn) % 10_000_000
    for i in range(n_sandboxes):
        wid = wids[(base + i) % len(wids)]
        sb = Sandbox(sandbox_id=100000 + base + i, function_name=fn,
                     ip=(10, 0, 0, 1), port=80, worker_id=wid,
                     state=SandboxState.READY)
        st.sandboxes[sb.sandbox_id] = sb
        cl.workers[wid].sandboxes[sb.sandbox_id] = __import__(
            "repro.core.worker", fromlist=["SandboxRuntime"]).SandboxRuntime(
                sandbox=sb, ready=True)
        for dp in cl.data_planes:
            dp.add_endpoint(fn, sb)
    # freeze autoscaling decisions during the measurement
    st.autoscaler.no_downscale_until = 1e18


def _prescale_knative(kn, fn: str, n_sandboxes: int) -> None:
    from repro.core.baseline_knative import PodEndpoint
    st = kn.functions[fn]
    wids = list(kn.workers.keys())
    base = stable_hash(fn) % 10_000_000
    for i in range(n_sandboxes):
        sb = Sandbox(sandbox_id=100000 + base + i, function_name=fn,
                     ip=(10, 0, 0, 1), port=80,
                     worker_id=wids[(base + i) % len(wids)],
                     state=SandboxState.READY)
        st.endpoints[sb.sandbox_id] = PodEndpoint(sandbox=sb)
    st.autoscaler.no_downscale_until = 1e18


def warm_sweep(system_kind: str, rate: float, duration: float = 8.0,
               seed: int = 21):
    # port-pool exhaustion (the paper's 4000/s ceiling) only manifests once
    # rate x duration exceeds the per-DP pool: stretch high-rate sweeps
    if rate > 3500:
        duration = max(duration, 30.0)
    env = Environment(seed=seed)
    names = [f"hot{i}" for i in range(N_FUNCTIONS)]
    n_sb = max(4, int(rate * 0.02 / N_FUNCTIONS))  # slots per function
    n = int(rate * duration)
    plan = [(i / rate, names[i % N_FUNCTIONS], EXEC_TIME) for i in range(n)]
    scaling = dict(stable_window=600.0, scale_to_zero_grace=600.0)
    if system_kind == "dirigent":
        cl = make_dirigent(env)
        preload_functions(cl, names, scaling)
        for nm in names:
            _prescale_dirigent(cl, nm, n_sb)
        invs = run_open_loop(env, cl, plan, until_extra=30.0)
    else:
        kn = make_knative(env, flavor=("openwhisk" if system_kind == "openwhisk"
                                       else "knative"))
        preload_functions(kn, names, scaling)
        for nm in names:
            _prescale_knative(kn, nm, n_sb)
        invs = run_open_loop(env, kn, plan, until_extra=30.0)
    return latency_stats(invs, "e2e_latency")


def run(reporter, quick: bool = True) -> dict:
    out = {}
    rates_d = [1000, 4000, 4600] if quick else [500, 1000, 2000, 3000, 4000,
                                                4500, 5000]
    for r in rates_d:
        st = warm_sweep("dirigent", r)
        reporter.add(f"fig8/dirigent/rate={r}", st["p50"] * 1e6,
                     f"p99_us={st['p99']*1e6:.0f};done={st['done']}/{st['total']}")
        out[f"d_{r}"] = st
    for r in ([800, 1200, 1600] if quick else [400, 800, 1200, 1400, 1600]):
        st = warm_sweep("knative", r)
        reporter.add(f"fig8/knative/rate={r}", st["p50"] * 1e6,
                     f"p99_us={st['p99']*1e6:.0f};done={st['done']}/{st['total']}")
        out[f"kn_{r}"] = st
    st = warm_sweep("openwhisk", 500)
    reporter.add("fig8/openwhisk/rate=500", st["p50"] * 1e6,
                 f"p99_us={st['p99']*1e6:.0f}")
    return out


if __name__ == "__main__":
    from benchmarks.common import CsvReporter
    rep = CsvReporter()
    rep.header()
    run(rep, quick=True)
