"""Fig 7 — cold-start performance: p50/p99 E2E latency vs creation rate.

Systems: Dirigent+Firecracker (peak ≈2500/s, C1), Dirigent+containerd
(≈1750/s kernel-lock-bound, C2), Dirigent persist-all ablation (≈1000/s, C3),
Knative (saturates ≈2/s), Knative-on-K3s fused ablation (marginal gain, C4),
OpenWhisk flavor. Each invocation hits a distinct single-shot function so
every invocation is a cold start (InVitro cold-start methodology).
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import (
    SWEEP_SCALING, latency_stats, make_dirigent, make_knative,
    preload_functions, run_open_loop,
)
from repro.core import CostModel
from repro.simcore import Environment

EXEC_TIME = 0.1


def _plan(rate: float, duration: float) -> List[tuple]:
    n = int(rate * duration)
    return [(i / rate, f"f{i}", EXEC_TIME) for i in range(n)]


def cold_sweep_dirigent(rate: float, duration: float = 5.0,
                        runtime: str = "firecracker",
                        persist_sandbox_state: bool = False,
                        n_workers: int = 93, seed: int = 11):
    env = Environment(seed=seed)
    cl = make_dirigent(env, n_workers=n_workers, runtime=runtime,
                       persist_sandbox_state=persist_sandbox_state)
    plan = _plan(rate, duration)
    preload_functions(cl, [p[1] for p in plan], SWEEP_SCALING)
    invs = run_open_loop(env, cl, plan, until_extra=90.0)
    return latency_stats(invs, "e2e_latency")


def cold_sweep_knative(rate: float, duration: float = 20.0,
                       fused: bool = False, flavor: str = "knative",
                       n_workers: int = 93, seed: int = 12):
    env = Environment(seed=seed)
    kn = make_knative(env, n_workers=n_workers, fused=fused, flavor=flavor)
    plan = _plan(rate, duration)
    preload_functions(kn, [p[1] for p in plan], SWEEP_SCALING)
    invs = run_open_loop(env, kn, plan, until_extra=240.0)
    return latency_stats(invs, "e2e_latency")


def find_peak(sweep_fn, rates, p99_limit: float = 1.0) -> float:
    """Peak sustainable rate: largest rate whose p99 E2E stays under limit."""
    peak = 0.0
    for r in rates:
        st = sweep_fn(r)
        if st["done"] >= 0.97 * st["total"] and st["p99"] <= p99_limit:
            peak = r
        else:
            break
    return peak


def run(reporter, quick: bool = True) -> dict:
    out = {}
    rates_fc = [100, 1000, 2000, 2500] if quick else [1, 10, 100, 500, 1000,
                                                      1500, 2000, 2500, 3000]
    for r in rates_fc:
        st = cold_sweep_dirigent(r, runtime="firecracker")
        reporter.add(f"fig7/dirigent-fc/rate={r}", st["p50"] * 1e6,
                     f"p99_ms={st['p99']*1e3:.1f};done={st['done']}/{st['total']}")
        out[f"fc_{r}"] = st
    for r in ([1000, 1750, 2000] if quick else [100, 500, 1000, 1500, 1750, 2000]):
        st = cold_sweep_dirigent(r, runtime="containerd")
        reporter.add(f"fig7/dirigent-containerd/rate={r}", st["p50"] * 1e6,
                     f"p99_ms={st['p99']*1e3:.1f};done={st['done']}/{st['total']}")
        out[f"ctd_{r}"] = st
    for r in ([500, 1000, 1500] if quick else [100, 500, 750, 1000, 1250, 1500]):
        st = cold_sweep_dirigent(r, runtime="firecracker",
                                 persist_sandbox_state=True)
        reporter.add(f"fig7/dirigent-persist-all/rate={r}", st["p50"] * 1e6,
                     f"p99_ms={st['p99']*1e3:.1f};done={st['done']}/{st['total']}")
        out[f"persist_{r}"] = st
    for r in ([1, 2, 3] if quick else [0.5, 1, 2, 3, 4]):
        st = cold_sweep_knative(r)
        reporter.add(f"fig7/knative/rate={r}", st["p50"] * 1e6,
                     f"p99_ms={st['p99']*1e3:.1f};done={st['done']}/{st['total']}")
        out[f"kn_{r}"] = st
        st = cold_sweep_knative(r, fused=True)
        reporter.add(f"fig7/knative-k3s-fused/rate={r}", st["p50"] * 1e6,
                     f"p99_ms={st['p99']*1e3:.1f};done={st['done']}/{st['total']}")
        out[f"k3s_{r}"] = st
    for r in [1, 2]:
        st = cold_sweep_knative(r, flavor="openwhisk")
        reporter.add(f"fig7/openwhisk/rate={r}", st["p50"] * 1e6,
                     f"p99_ms={st['p99']*1e3:.1f};done={st['done']}/{st['total']}")
    return out


if __name__ == "__main__":
    from benchmarks.common import CsvReporter
    rep = CsvReporter()
    rep.header()
    run(rep, quick=True)
