"""Shared benchmark harness utilities."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import Cluster, Function, ScalingConfig
from repro.core.abstractions import Function as Fn
from repro.core.autoscaler import FunctionAutoscalerState
from repro.core.baseline_knative import KnativeCluster, KnFunctionState
from repro.core.control_plane import FunctionState
from repro.simcore import Environment


# Scaling config for cold-start sweep microbenchmarks: hello-world functions
# with aggressive teardown so the 93-node cluster sustains thousands of
# creations/s (the paper's Fig 7 regime).
SWEEP_SCALING = dict(stable_window=1.0, panic_window=1.0,
                     scale_to_zero_grace=0.2, cpu_req_millis=100,
                     mem_req_mb=128)


def make_dirigent(env: Environment, n_workers: int = 93,
                  runtime: str = "firecracker", **kw) -> Cluster:
    cl = Cluster(env, n_workers=n_workers, runtime=runtime, **kw)
    cl.start()
    return cl


def make_knative(env: Environment, n_workers: int = 93, **kw) -> KnativeCluster:
    return KnativeCluster(env, n_workers=n_workers, **kw)


def preload_functions(system, names: List[str],
                      scaling_kw: Optional[dict] = None,
                      persist: bool = False) -> None:
    """Install functions directly (bypassing registration cost) for
    microbenchmarks where registration is not the measured quantity.

    ``persist=True`` additionally writes the ``function/<name>`` records to
    the durable store (draining the write log before returning) — required
    by failover benchmarks: ``recover_as_leader`` rebuilds the registry from
    those records, so functions preloaded without them would silently vanish
    on the first leader kill."""
    scaling_kw = scaling_kw or {}
    if isinstance(system, Cluster):
        leader = system.control_plane_leader()
        fns = []
        for name in names:
            fn = Fn(name=name, image_url="img://bench", port=80,
                    scaling=ScalingConfig(**scaling_kw))
            # install_function routes the record to its owning CP shard too
            leader.install_function(fn)
            fns.append(fn)
            for dp in system.data_planes:
                dp.sync_functions([name])
        if persist:
            env = system.env
            done = env.event()

            def persist_all(env):
                for fn in fns:
                    yield from system.store.write(f"function/{fn.name}",
                                                  fn.persisted_record())
                done.succeed(None)

            env.process(persist_all(env), name="preload-persist")
            env.run_until_event(done)
    else:
        for name in names:
            fn = Fn(name=name, image_url="img://bench", port=80,
                    scaling=ScalingConfig(**scaling_kw))
            system.functions[name] = KnFunctionState(
                function=fn, autoscaler=FunctionAutoscalerState(fn.scaling))


def run_open_loop(env: Environment, system, plan: List[tuple],
                  until_extra: float = 120.0,
                  request_factory: Optional[Callable] = None) -> List:
    """Submit (t, fn, exec_time) invocations open-loop; returns Invocations.

    Plan times are offsets from *traffic start* (``env.now`` at call time),
    and so is the run horizon: boot work already on the clock — at 20k
    workers the O(n_workers)-fsyncs registration alone is ~30 s of sim time
    — must not eat the measurement window, or large-worker cells silently
    truncate mid-submission.

    ``request_factory(i)`` (live mode) builds the ``LiveRequest`` riding
    invocation ``i``; every dispatch then executes real payload work."""
    invs = []

    def driver(env):
        t_prev = 0.0
        for i, (t, fn, et) in enumerate(plan):
            if t > t_prev:
                yield env.timeout(t - t_prev)
                t_prev = t
            if request_factory is not None:
                invs.append(system.invoke(fn, exec_time=et,
                                          request=request_factory(i)))
            else:
                invs.append(system.invoke(fn, exec_time=et))

    env.process(driver(env), name="bench-driver")
    horizon = env.now + (plan[-1][0] if plan else 0.0) + until_extra
    env.run(until=horizon)
    return invs


def latency_stats(invs, field: str = "scheduling_latency") -> Dict[str, float]:
    vals = np.array([getattr(i, field) for i in invs
                     if i.t_done > 0 and not i.failed], dtype=np.float64)
    done = int(vals.size)
    total = len(invs)
    if done == 0:
        return {"done": 0, "total": total, "p50": float("nan"),
                "p99": float("nan"), "mean": float("nan")}
    return {
        "done": done, "total": total,
        "p50": float(np.percentile(vals, 50)),
        "p99": float(np.percentile(vals, 99)),
        "mean": float(vals.mean()),
    }


class CsvReporter:
    """Accumulates ``name,us_per_call,derived`` rows (benchmarks/run.py)."""

    def __init__(self):
        self.rows: List[tuple] = []

    def add(self, name: str, us_per_call: float, derived: str = "") -> None:
        self.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.3f},{derived}", flush=True)

    def header(self) -> None:
        print("name,us_per_call,derived", flush=True)
