"""§5.2.4 — function registration performance.

Paper (C8): registering 500 functions takes ~1 s on Dirigent (2 ms each) vs
~18 minutes on Knative (~770 ms for the first, growing with cluster size due
to ingress/route resync).
"""
from __future__ import annotations

from repro.core import Cluster, Function
from repro.core.baseline_knative import KnativeCluster
from repro.simcore import Environment


def register_many(kind: str, n: int = 500, seed: int = 61):
    env = Environment(seed=seed)
    if kind == "dirigent":
        sys_ = Cluster(env, n_workers=8)
        sys_.start()
    else:
        sys_ = KnativeCluster(env, n_workers=8)
    t0 = env.now
    lat_first = lat_last = 0.0
    for i in range(n):
        t_before = env.now
        fn = Function(name=f"app{i:04d}", image_url="img://x", port=80)
        sys_.register_sync(fn)
        if i == 0:
            lat_first = env.now - t_before
        lat_last = env.now - t_before
    total = env.now - t0
    return {"total_s": total, "mean_ms": total / n * 1e3,
            "first_ms": lat_first * 1e3, "last_ms": lat_last * 1e3}


def run(reporter, quick: bool = True) -> dict:
    n = 100 if quick else 500
    out = {}
    for kind in ["dirigent", "knative"]:
        r = register_many(kind, n=n)
        reporter.add(f"registration/{kind}/n={n}", r["mean_ms"] * 1e3,
                     f"total_s={r['total_s']:.2f};first_ms={r['first_ms']:.1f};"
                     f"last_ms={r['last_ms']:.1f}")
        out[kind] = r
    return out


if __name__ == "__main__":
    from benchmarks.common import CsvReporter
    rep = CsvReporter()
    rep.header()
    print(run(rep, quick=True))
