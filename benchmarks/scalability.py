"""§5.2.3 — cold-start throughput vs worker-node count.

Methodology follows the paper exactly: worker daemons model sandbox creation
as the p50 Firecracker snapshot-restore time (40 ms), heartbeat to the CP,
and we sweep the cluster size. Paper (C9): latency/throughput match the
93-node results up to 2500 workers; at 5000 workers peak degrades to
~2000/s due to contention on the shared health-monitoring structures.
"""
from __future__ import annotations

from benchmarks.common import (
    SWEEP_SCALING, latency_stats, make_dirigent, preload_functions,
    run_open_loop,
)
from repro.simcore import Environment


def scalability_point(n_workers: int, rate: float, duration: float = 4.0,
                      seed: int = 71):
    env = Environment(seed=seed)
    cl = make_dirigent(env, n_workers=n_workers, runtime="firecracker")
    plan = [(i / rate, f"f{i}", 0.05) for i in range(int(rate * duration))]
    preload_functions(cl, [p[1] for p in plan], SWEEP_SCALING)
    invs = run_open_loop(env, cl, plan, until_extra=60.0)
    return latency_stats(invs, "e2e_latency")


def run(reporter, quick: bool = True) -> dict:
    out = {}
    worker_counts = [93, 1000, 2500, 5000] if quick else [93, 500, 1000,
                                                          2500, 5000]
    rates = [2000, 2500] if quick else [1500, 2000, 2250, 2500, 2750]
    for nw in worker_counts:
        peak = 0
        for r in rates:
            st = scalability_point(nw, r)
            ok = st["done"] >= 0.97 * st["total"] and st["p99"] <= 1.0
            reporter.add(f"scalability/workers={nw}/rate={r}",
                         st["p50"] * 1e6,
                         f"p99_ms={st['p99']*1e3:.1f};ok={ok}")
            if ok:
                peak = r
        out[nw] = peak
        reporter.add(f"scalability/workers={nw}/peak", peak, "creations_per_s")
    return out


if __name__ == "__main__":
    from benchmarks.common import CsvReporter
    rep = CsvReporter()
    rep.header()
    print(run(rep, quick=True))
