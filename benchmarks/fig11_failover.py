"""Fig 11 + §5.4 — fault-tolerance experiments.

  * Control-plane leader failure during the Azure trace: slowdown-over-time
    around the failure instant; Dirigent recovers in ~10 ms (C10), Knative in
    seconds.
  * Data-plane replica failure: time until the invocation failure rate
    returns to zero — ~2 s for Dirigent vs ~15 s for Knative (C11).
  * Worker-daemon failure of 47/93 nodes: peak slowdown of invocations during
    the outage (C12: Dirigent ≈2.7, ~10x lower than Knative).
"""
from __future__ import annotations

import numpy as np

from benchmarks.azure_trace import generate_azure_like_trace
from benchmarks.common import make_dirigent, make_knative, preload_functions
from repro.simcore import Environment


def _drive(env, sys_, trace):
    invs = []

    def driver(env):
        t_prev = 0.0
        for t, fn, et in trace.invocations:
            if t > t_prev:
                yield env.timeout(t - t_prev)
                t_prev = t
            invs.append(sys_.invoke(fn, exec_time=et))

    env.process(driver(env), name="trace-driver")
    return invs


def _slowdown_timeline(invs, t0: float, t1: float, bucket: float = 5.0):
    buckets = {}
    for i in invs:
        if i.t_done > 0 and not i.failed and t0 <= i.arrival < t1:
            b = int((i.arrival - t0) / bucket)
            buckets.setdefault(b, []).append(i.slowdown)
    return {b * bucket + t0: float(np.mean(v)) for b, v in sorted(buckets.items())}


def control_plane_failure(kind: str, fail_at: float = 300.0, seed: int = 51):
    trace = generate_azure_like_trace(n_functions=300, duration=600.0,
                                      target_invocations=50_000, seed=seed)
    env = Environment(seed=seed)
    if kind == "dirigent":
        sys_ = make_dirigent(env, enable_ha_sim=True)
    else:
        sys_ = make_knative(env)
    preload_functions(sys_, [f.name for f in trace.functions])
    invs = _drive(env, sys_, trace)
    env.run(until=fail_at)
    if kind == "dirigent":
        sys_.fail_control_plane_leader()
    else:
        sys_.fail_control_plane()
    env.run(until=trace.duration + 120.0)
    # recovery time: from the failure event to the leader-elected/recovered event
    ev = {k: t for t, k, _ in sys_.collector.events
          if k in ("leader-elected", "cp-recovered")}
    rec_t = min((t for k, t in ev.items()), default=float("nan"))
    timeline = _slowdown_timeline(invs, fail_at - 60, fail_at + 120)
    pre = np.mean([v for t, v in timeline.items() if t < fail_at]) if timeline else float("nan")
    post = max((v for t, v in timeline.items()
                if fail_at <= t < fail_at + 60), default=float("nan"))
    return {"recovery_s": rec_t - fail_at, "pre_slowdown": float(pre),
            "peak_post_slowdown": float(post), "timeline": timeline}


def data_plane_failure(kind: str, fail_at: float = 120.0, seed: int = 52):
    """Steady warm traffic; fail one DP replica; measure time to zero failures."""
    env = Environment(seed=seed)
    rate, dur = 300.0, 240.0
    if kind == "dirigent":
        sys_ = make_dirigent(env)
    else:
        sys_ = make_knative(env)
    preload_functions(sys_, [f"f{i}" for i in range(30)],
                      dict(stable_window=600.0, scale_to_zero_grace=600.0))
    invs = []

    def driver(env):
        i = 0
        while env.now < dur:
            invs.append(sys_.invoke(f"f{i % 30}", exec_time=0.05))
            i += 1
            yield env.timeout(1.0 / rate)

    env.process(driver(env), name="driver")
    env.run(until=fail_at)
    if kind == "dirigent":
        sys_.fail_data_plane(0)
        env.run(until=dur + 60)
    else:
        env.process(sys_.fail_data_plane(), name="kn-dp-fail")
        env.run(until=dur + 60)
    # failure rate per second after the failure
    fail_ts = sorted(i.arrival for i in invs if i.failed)
    last_fail = max(fail_ts, default=fail_at)
    return {"recovery_s": last_fail - fail_at,
            "n_failed": len(fail_ts)}


def worker_failures(kind: str, n_fail: int = 47, fail_at: float = 240.0,
                    seed: int = 53):
    trace = generate_azure_like_trace(n_functions=200, duration=480.0,
                                      target_invocations=40_000, seed=seed)
    env = Environment(seed=seed)
    sys_ = (make_dirigent(env) if kind == "dirigent" else make_knative(env))
    preload_functions(sys_, [f.name for f in trace.functions])
    invs = _drive(env, sys_, trace)
    env.run(until=fail_at)
    if kind == "dirigent":
        for wid in range(n_fail):
            sys_.fail_worker_daemon(wid)
    else:
        # baseline has no explicit daemon model: mark nodes unschedulable and
        # evict endpoints after the k8s eviction timeout
        def evict(env):
            yield env.timeout(sys_.costs.worker_eviction_timeout)
            for wid in range(n_fail):
                sys_.placer.set_schedulable(wid, False)
            for st in sys_.functions.values():
                for sid in [sid for sid, ep in st.endpoints.items()
                            if ep.sandbox.worker_id < n_fail]:
                    st.endpoints.pop(sid, None)
        env.process(evict(env), name="evict")
    env.run(until=trace.duration + 120.0)
    timeline = _slowdown_timeline(invs, fail_at - 60, fail_at + 180, bucket=10.0)
    peak = max((v for t, v in timeline.items() if t >= fail_at),
               default=float("nan"))
    return {"peak_slowdown": float(peak), "timeline": timeline}


def run(reporter, quick: bool = True) -> dict:
    out = {}
    for kind in ["dirigent", "knative"]:
        r = control_plane_failure(kind)
        reporter.add(f"fig11/{kind}/cp-failover", r["recovery_s"] * 1e6,
                     f"peak_slowdown={r['peak_post_slowdown']:.2f};"
                     f"pre={r['pre_slowdown']:.2f}")
        out[f"cp_{kind}"] = r
        r = data_plane_failure(kind)
        reporter.add(f"fig11/{kind}/dp-failover", r["recovery_s"] * 1e6,
                     f"n_failed={r['n_failed']}")
        out[f"dp_{kind}"] = r
        r = worker_failures(kind)
        reporter.add(f"fig11/{kind}/worker-47of93", r["peak_slowdown"] * 1e6,
                     f"peak_slowdown={r['peak_slowdown']:.2f}")
        out[f"wk_{kind}"] = r
    return out


if __name__ == "__main__":
    from benchmarks.common import CsvReporter
    rep = CsvReporter()
    rep.header()
    print(run(rep, quick=True))
