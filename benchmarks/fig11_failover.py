"""Fig 11 + §5.4 — fault-tolerance experiments.

  * Control-plane leader failure during the Azure trace: slowdown-over-time
    around the failure instant; Dirigent recovers in ~10 ms (C10), Knative in
    seconds.
  * Data-plane replica failure: time until the invocation failure rate
    returns to zero — ~2 s for Dirigent vs ~15 s for Knative (C11).
  * Worker-daemon failure of 47/93 nodes: peak slowdown of invocations during
    the outage (C12: Dirigent ≈2.7, ~10x lower than Knative).
"""
from __future__ import annotations

import numpy as np

from benchmarks.azure_trace import generate_azure_like_trace
from benchmarks.common import make_dirigent, make_knative, preload_functions
from repro.simcore import Environment


def _drive(env, sys_, trace):
    invs = []

    def driver(env):
        t_prev = 0.0
        for t, fn, et in trace.invocations:
            if t > t_prev:
                yield env.timeout(t - t_prev)
                t_prev = t
            invs.append(sys_.invoke(fn, exec_time=et))

    env.process(driver(env), name="trace-driver")
    return invs


def _slowdown_timeline(invs, t0: float, t1: float, bucket: float = 5.0):
    buckets = {}
    for i in invs:
        if i.t_done > 0 and not i.failed and t0 <= i.arrival < t1:
            b = int((i.arrival - t0) / bucket)
            buckets.setdefault(b, []).append(i.slowdown)
    return {b * bucket + t0: float(np.mean(v)) for b, v in sorted(buckets.items())}


def control_plane_failure(kind: str, fail_at: float = 300.0, seed: int = 51):
    trace = generate_azure_like_trace(n_functions=300, duration=600.0,
                                      target_invocations=50_000, seed=seed)
    env = Environment(seed=seed)
    if kind == "dirigent":
        sys_ = make_dirigent(env, enable_ha_sim=True)
    else:
        sys_ = make_knative(env)
    preload_functions(sys_, [f.name for f in trace.functions])
    # all horizons are relative to the instant traffic starts, not to t=0:
    # anything that advances the clock before the driver is spawned (setup,
    # registration) must not shift the kill relative to the trace
    t0 = env.now
    invs = _drive(env, sys_, trace)
    env.run(until=t0 + fail_at)
    t_kill = env.now
    if kind == "dirigent":
        sys_.fail_control_plane_leader()
    else:
        sys_.fail_control_plane()
    env.run(until=t0 + trace.duration + 120.0)
    # recovery time: failure instant -> the new leader finishing replay
    # ("cp-recovered" is emitted once recovery completes; the boot-time
    # election emits "leader-elected" too, so filter on the kill instant)
    rec_t = sys_.collector.first_event_at("cp-recovered", after=t_kill) \
        if kind == "dirigent" else None
    if rec_t is None:
        ev = [t for t, k, _ in sys_.collector.events
              if k in ("leader-elected", "cp-recovered") and t >= t_kill]
        rec_t = min(ev, default=float("nan"))
    timeline = _slowdown_timeline(invs, t_kill - 60, t_kill + 120)
    pre = np.mean([v for t, v in timeline.items() if t < t_kill]) if timeline else float("nan")
    post = max((v for t, v in timeline.items()
                if t_kill <= t < t_kill + 60), default=float("nan"))
    # recovery-window view: scheduling latency of requests that arrived
    # between the kill and recovery completion (plus a wider 60 s window —
    # the narrow one can be empty at low rates)
    if kind == "dirigent" and not np.isnan(rec_t):
        win = sys_.collector.window_sched_latencies(t_kill, rec_t)
    else:
        win = np.array([])
    win60 = np.array([i.scheduling_latency for i in invs
                      if i.t_done > 0 and not i.failed
                      and t_kill <= i.arrival < t_kill + 60.0])
    def _p(a, q):
        return float(np.percentile(a, q)) if a.size else float("nan")
    return {"recovery_s": rec_t - t_kill, "pre_slowdown": float(pre),
            "peak_post_slowdown": float(post),
            "recovery_window_sched_p50_ms": _p(win, 50) * 1e3,
            "recovery_window_sched_p99_ms": _p(win, 99) * 1e3,
            "post_60s_sched_p99_ms": _p(win60, 99) * 1e3,
            "timeline": timeline}


def data_plane_failure(kind: str, fail_at: float = 120.0, seed: int = 52):
    """Steady warm traffic; fail one DP replica; measure time to zero failures."""
    env = Environment(seed=seed)
    rate, dur = 300.0, 240.0
    if kind == "dirigent":
        sys_ = make_dirigent(env)
    else:
        sys_ = make_knative(env)
    preload_functions(sys_, [f"f{i}" for i in range(30)],
                      dict(stable_window=600.0, scale_to_zero_grace=600.0))
    t0 = env.now
    invs = []

    def driver(env):
        i = 0
        while env.now < t0 + dur:
            invs.append(sys_.invoke(f"f{i % 30}", exec_time=0.05))
            i += 1
            yield env.timeout(1.0 / rate)

    env.process(driver(env), name="driver")
    env.run(until=t0 + fail_at)
    t_kill = env.now
    if kind == "dirigent":
        sys_.fail_data_plane(0)
        env.run(until=t0 + dur + 60)
    else:
        env.process(sys_.fail_data_plane(), name="kn-dp-fail")
        env.run(until=t0 + dur + 60)
    # failure rate per second after the failure
    fail_ts = sorted(i.arrival for i in invs if i.failed)
    last_fail = max(fail_ts, default=t_kill)
    return {"recovery_s": last_fail - t_kill,
            "n_failed": len(fail_ts)}


def worker_failures(kind: str, n_fail: int = 47, fail_at: float = 240.0,
                    seed: int = 53):
    trace = generate_azure_like_trace(n_functions=200, duration=480.0,
                                      target_invocations=40_000, seed=seed)
    env = Environment(seed=seed)
    sys_ = (make_dirigent(env) if kind == "dirigent" else make_knative(env))
    preload_functions(sys_, [f.name for f in trace.functions])
    t0 = env.now
    invs = _drive(env, sys_, trace)
    env.run(until=t0 + fail_at)
    fail_at = env.now
    if kind == "dirigent":
        for wid in range(n_fail):
            sys_.fail_worker_daemon(wid)
    else:
        # baseline has no explicit daemon model: mark nodes unschedulable and
        # evict endpoints after the k8s eviction timeout
        def evict(env):
            yield env.timeout(sys_.costs.worker_eviction_timeout)
            for wid in range(n_fail):
                sys_.placer.set_schedulable(wid, False)
            for st in sys_.functions.values():
                for sid in [sid for sid, ep in st.endpoints.items()
                            if ep.sandbox.worker_id < n_fail]:
                    st.endpoints.pop(sid, None)
        env.process(evict(env), name="evict")
    env.run(until=t0 + trace.duration + 120.0)
    timeline = _slowdown_timeline(invs, fail_at - 60, fail_at + 180, bucket=10.0)
    peak = max((v for t, v in timeline.items() if t >= fail_at),
               default=float("nan"))
    return {"peak_slowdown": float(peak), "timeline": timeline}


def run(reporter, quick: bool = True) -> dict:
    out = {}
    for kind in ["dirigent", "knative"]:
        r = control_plane_failure(kind)
        reporter.add(f"fig11/{kind}/cp-failover", r["recovery_s"] * 1e6,
                     f"peak_slowdown={r['peak_post_slowdown']:.2f};"
                     f"pre={r['pre_slowdown']:.2f};"
                     f"win_p99_ms={r['recovery_window_sched_p99_ms']:.3f}")
        out[f"cp_{kind}"] = r
        r = data_plane_failure(kind)
        reporter.add(f"fig11/{kind}/dp-failover", r["recovery_s"] * 1e6,
                     f"n_failed={r['n_failed']}")
        out[f"dp_{kind}"] = r
        r = worker_failures(kind)
        reporter.add(f"fig11/{kind}/worker-47of93", r["peak_slowdown"] * 1e6,
                     f"peak_slowdown={r['peak_slowdown']:.2f}")
        out[f"wk_{kind}"] = r
    return out


if __name__ == "__main__":
    from benchmarks.common import CsvReporter
    rep = CsvReporter()
    rep.header()
    print(run(rep, quick=True))
