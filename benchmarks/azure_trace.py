"""Azure Functions-like workload trace generator (Shahrad et al. [75]).

We cannot ship the original trace, so we synthesize a statistically similar
one (seeded, deterministic), following the characterization in [75] and the
paper's InVitro sampling methodology [84]:

  * per-function mean invocation rates are heavy-tailed (lognormal): most
    functions are invoked sporadically, a few are hot;
  * ~15% of functions are timer-triggered; timers in the same period group
    fire in unison, which produces the cluster-wide cold-start bursts the
    paper highlights in §5.3 ("functions invoked in unison due to timer
    triggers ... resulting in large cold start bursts");
  * execution times are lognormal with ~50% of functions executing under 1 s
    (paper §2.1), clipped to [1 ms, 60 s];
  * the 500-function sample targets ≈168 K invocations over 30 minutes
    (≈93 req/s average), matching the paper's experiment scale.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass
class TraceFunction:
    name: str
    mean_rate: float          # Poisson invocations/s (0 for pure-timer fns)
    exec_median: float        # per-function median execution time
    timer_period: float = 0.0  # >0 for timer-triggered functions
    timer_phase: float = 0.0


@dataclass
class Trace:
    functions: List[TraceFunction]
    invocations: List[Tuple[float, str, float]]   # (t, fn, exec_time) sorted
    duration: float

    @property
    def n_invocations(self) -> int:
        return len(self.invocations)


def generate_azure_like_trace(
    n_functions: int = 500,
    duration: float = 1800.0,
    target_invocations: int = 168_000,
    seed: int = 42,
    timer_fraction: float = 0.15,
    n_timer_groups: int = 6,
) -> Trace:
    rng = np.random.default_rng(seed)

    # -- per-function execution-time medians: lognormal, 50% under ~0.6 s ----
    exec_medians = np.exp(rng.normal(np.log(0.35), 1.6, size=n_functions))
    exec_medians = np.clip(exec_medians, 1e-3, 30.0)

    # -- split functions into timer-triggered and Poisson ---------------------
    n_timer = int(n_functions * timer_fraction)
    timer_periods = rng.choice([60.0, 300.0, 600.0, 900.0], size=n_timer_groups)
    timer_group = rng.integers(0, n_timer_groups, size=n_timer)
    group_phase = rng.uniform(0, 1, size=n_timer_groups)

    functions: List[TraceFunction] = []
    for i in range(n_timer):
        g = timer_group[i]
        period = float(timer_periods[g])
        functions.append(TraceFunction(
            name=f"fn{i:04d}", mean_rate=0.0,
            exec_median=float(exec_medians[i]),
            timer_period=period, timer_phase=float(group_phase[g] * period)))

    # -- Poisson functions: heavy-tailed rates normalized to the target -------
    n_poisson = n_functions - n_timer
    raw = np.exp(rng.normal(np.log(0.004), 2.4, size=n_poisson))
    raw = np.clip(raw, 1.0 / duration, 25.0)
    timer_invocations = sum(int(duration / f.timer_period) for f in functions)
    target_poisson = max(target_invocations - timer_invocations, 0)
    raw *= target_poisson / (raw.sum() * duration)
    for j in range(n_poisson):
        i = n_timer + j
        functions.append(TraceFunction(
            name=f"fn{i:04d}", mean_rate=float(raw[j]),
            exec_median=float(exec_medians[i])))

    # -- materialize invocations ------------------------------------------------
    inv: List[Tuple[float, str, float]] = []
    for f in functions:
        if f.timer_period > 0:
            t = f.timer_phase
            while t < duration:
                et = float(np.exp(rng.normal(np.log(f.exec_median), 0.3)))
                inv.append((t, f.name, max(et, 1e-3)))
                t += f.timer_period
        if f.mean_rate > 0:
            t = float(rng.exponential(1.0 / f.mean_rate))
            while t < duration:
                et = float(np.exp(rng.normal(np.log(f.exec_median), 0.3)))
                inv.append((t, f.name, max(et, 1e-3)))
                t += float(rng.exponential(1.0 / f.mean_rate))
    inv.sort(key=lambda x: x[0])
    return Trace(functions=functions, invocations=inv, duration=duration)
