"""Fig 9 + Fig 10 — end-to-end performance on the Azure production workload.

Runs the (synthesized) Azure 500-function / 30-minute trace on Dirigent and
on the Knative baseline, with a 10-minute warm-up discarded, and reports:

  * per-function geomean slowdown CDF stats (Fig 9; paper C7: median 1.38 for
    Dirigent vs 13.2 for Knative; Dirigent ~713 sandboxes vs Knative ~2930);
  * per-invocation and per-function scheduling-latency stats (Fig 10; paper
    C6: Dirigent p50 1.74 ms / p99 1.13 s; Knative p50 4.67 ms / p99 59.6 s).

The larger 4K-function trace (paper §5.3 "Larger trace") runs on Dirigent
only — Knative cannot sustain it, which is itself one of the paper's claims.

Scale-out regime (ROADMAP): the large trace is additionally replayed on a
5000-worker cluster (1000 in quick mode) with a control-plane shard sweep
(``cp_shards`` ∈ {1, 4}) — the C9 regime where worker heartbeats contend the
same shared structures as autoscaling and the sharded CP keeps scheduling
latency flat.
"""
from __future__ import annotations

import numpy as np

from benchmarks.azure_trace import generate_azure_like_trace
from benchmarks.common import make_dirigent, make_knative, preload_functions
from repro.core import percentile, geomean
from repro.simcore import Environment

WARMUP = 600.0


def _run_trace(system_kind: str, trace, n_workers: int = 93, seed: int = 41,
               extra: float = 120.0, **sys_kw):
    env = Environment(seed=seed)
    if system_kind == "dirigent":
        sys_ = make_dirigent(env, n_workers=n_workers, **sys_kw)
    else:
        sys_ = make_knative(env, n_workers=n_workers, **sys_kw)
    preload_functions(sys_, [f.name for f in trace.functions])
    invs = []

    def driver(env):
        t_prev = 0.0
        for t, fn, et in trace.invocations:
            if t > t_prev:
                yield env.timeout(t - t_prev)
                t_prev = t
            invs.append(sys_.invoke(fn, exec_time=et))

    env.process(driver(env), name="trace-driver")
    env.run(until=trace.duration + extra)
    return sys_, invs


def analyze(invs, warmup: float = WARMUP):
    ok = [i for i in invs if i.t_done > 0 and not i.failed and i.arrival >= warmup]
    nfail = sum(1 for i in invs if i.failed and i.arrival >= warmup)
    sched = np.array([i.scheduling_latency for i in ok])
    slow = np.array([i.slowdown for i in ok])
    per_fn_sched, per_fn_slow = {}, {}
    for i in ok:
        per_fn_sched.setdefault(i.function_name, []).append(i.scheduling_latency)
        per_fn_slow.setdefault(i.function_name, []).append(i.slowdown)
    pf_sched = [float(np.mean(v)) for v in per_fn_sched.values()]
    pf_slow = [geomean(v) for v in per_fn_slow.values()]
    return {
        "n": len(ok), "n_failed": nfail,
        "sched_p50_ms": percentile(sched, 50) * 1e3,
        "sched_p99_ms": percentile(sched, 99) * 1e3,
        "perfn_sched_p50_ms": percentile(pf_sched, 50) * 1e3,
        "perfn_sched_p99_ms": percentile(pf_sched, 99) * 1e3,
        "perfn_slowdown_p50": percentile(pf_slow, 50),
        "perfn_slowdown_p99": percentile(pf_slow, 99),
    }


def run(reporter, quick: bool = True) -> dict:
    out = {}
    if quick:
        trace = generate_azure_like_trace(n_functions=500, duration=900.0,
                                          target_invocations=84_000)
        warmup = 300.0
    else:
        trace = generate_azure_like_trace()
        warmup = WARMUP
    for kind in ["dirigent", "knative"]:
        sys_, invs = _run_trace(kind, trace)
        a = analyze(invs, warmup)
        # Fig 3 analogue: sandbox-creation rate over the trace (10 s buckets)
        ts = [t for t, k, _ in sys_.collector.events if k == "sandbox-created"]
        if ts:
            import collections
            buckets = collections.Counter(int(t // 10) for t in ts)
            rates = [v / 10.0 for v in buckets.values()]
            reporter.add(f"fig3/{kind}/creation-rate-mean",
                         float(np.mean(rates)) * 1e6,
                         f"p99_per_s={np.percentile(rates, 99):.1f};"
                         f"max_per_s={max(rates):.1f};total={len(ts)}")
        reporter.add(f"fig10/{kind}/azure500-sched-p50",
                     a["sched_p50_ms"] * 1e3,
                     f"p99_ms={a['sched_p99_ms']:.1f};"
                     f"perfn_p99_ms={a['perfn_sched_p99_ms']:.1f};n={a['n']}")
        reporter.add(f"fig9/{kind}/azure500-slowdown-p50",
                     a["perfn_slowdown_p50"] * 1e6,
                     f"perfn_slowdown_p99={a['perfn_slowdown_p99']:.1f};"
                     f"sandboxes={sys_.collector.sandbox_creations}")
        out[kind] = a
        out[f"{kind}_sandboxes"] = sys_.collector.sandbox_creations

    # larger trace (Dirigent only, scaled to quick mode)
    if quick:
        big = generate_azure_like_trace(n_functions=1000, duration=600.0,
                                        target_invocations=150_000, seed=43)
        bwarm = 200.0
    else:
        big = generate_azure_like_trace(n_functions=4000, duration=1800.0,
                                        target_invocations=1_500_000, seed=43)
        bwarm = WARMUP
    sys_, invs = _run_trace("dirigent", big)
    a = analyze(invs, bwarm)
    reporter.add("fig9/dirigent/azure-large-slowdown-p50",
                 a["perfn_slowdown_p50"] * 1e6,
                 f"p99={a['perfn_slowdown_p99']:.1f};n={a['n']};"
                 f"failed={a['n_failed']}")
    out["large"] = a

    # scale-out regime: same large trace, thousands of workers, CP shard
    # sweep (heartbeat volume now contends whatever the autoscaler locks)
    so_workers = 1000 if quick else 5000
    out["scaleout"] = {}
    for cp_shards in (1, 4):
        sys_, invs = _run_trace("dirigent", big, n_workers=so_workers,
                                seed=47, cp_shards=cp_shards)
        a = analyze(invs, bwarm)
        lock_wait = sum(
            s.lock_wait_s
            for s in sys_.control_plane_leader().shards)
        reporter.add(
            f"scaleout/dirigent/workers={so_workers}/cp_shards={cp_shards}",
            a["sched_p50_ms"] * 1e3,
            f"sched_p99_ms={a['sched_p99_ms']:.1f};n={a['n']};"
            f"lock_wait_sim_s={lock_wait:.3f};"
            f"sandboxes={sys_.collector.sandbox_creations}")
        a["lock_wait_sim_s"] = lock_wait
        a["sandboxes"] = sys_.collector.sandbox_creations
        out["scaleout"][f"cp_shards={cp_shards}"] = a
    return out


if __name__ == "__main__":
    from benchmarks.common import CsvReporter
    rep = CsvReporter()
    rep.header()
    out = run(rep, quick=True)
    for k, v in out.items():
        print(k, v)
