"""kimi-k2-1t-a32b — trillion-param MoE, 61L, d_model 7168, 64H GQA(kv=8),
expert d_ff 2048, vocab 163840, 384 experts top-8. [arXiv:2501.kimi2;
unverified paper-table]. Approximation: every layer is MoE (the real model
has a dense first layer + 1 shared expert)."""
from repro.configs import register
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163840, head_dim=128,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048),
    source="arXiv:2501.kimi2; unverified",
))
