"""rwkv6-7b (Finch) — attention-free RNN with data-dependent decay, 32L,
d_model 4096, d_ff 14336, vocab 65536. [arXiv:2404.05892; hf]"""
from repro.configs import register
from repro.configs.base import ArchConfig

CONFIG = register(ArchConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_ff=14336,
    vocab=65536, head_dim=64, attention_free=True, sub_quadratic=True,
    norm="layernorm", mlp="rwkv",
    source="arXiv:2404.05892; hf",
))
