"""whisper-small — encoder-decoder audio backbone, 12L enc + 12L dec,
d_model 768, 12H, d_ff 3072, vocab 51865. The conv/mel frontend is a STUB:
input_specs() supplies precomputed 1500-frame encoder embeddings.
[arXiv:2212.04356; unverified]"""
from repro.configs import register
from repro.configs.base import ArchConfig, EncDecConfig

CONFIG = register(ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, head_dim=64, norm="layernorm", mlp="gelu",
    enc_dec=EncDecConfig(n_encoder_layers=12, encoder_seq=1500),
    source="arXiv:2212.04356; unverified",
))
