"""arctic-480b — MoE 128e top-2 with a parallel dense residual branch, 35L,
d_model 7168, 56H GQA(kv=8), d_ff 4864, vocab 32000.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs import register
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = register(ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, head_dim=128,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual_ff=4864),
    source="hf:Snowflake/snowflake-arctic-base; hf",
))
