"""qwen3-32b — dense, 64L, d_model 5120, 64H GQA(kv=8), d_ff 25600,
vocab 151936, qk_norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs import register
from repro.configs.base import ArchConfig

CONFIG = register(ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_ff=25600,
    vocab=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B; hf",
))
