"""zamba2-2.7b — hybrid: Mamba2 backbone + shared attention block, 54L,
d_model 2560, 32H GQA(kv=32), d_ff 10240, ssm_state 64, vocab 32000.
[arXiv:2411.15242; hf]"""
from repro.configs import register
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = register(ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, head_dim=80, sub_quadratic=True,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, attn_every=6),
    source="arXiv:2411.15242; hf",
))
