"""granite-34b — dense llama-arch (code), 88L, d_model 6144, 48H MQA(kv=1),
d_ff 24576, vocab 49152. [arXiv:2405.04324; hf]"""
from repro.configs import register
from repro.configs.base import ArchConfig

CONFIG = register(ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab=49152, head_dim=128, tie_embeddings=True, mlp="gelu",
    source="arXiv:2405.04324; hf",
))
