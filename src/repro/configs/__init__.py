"""Assigned-architecture registry: get_config("<arch-id>")."""
from __future__ import annotations

from repro.configs.base import (
    ArchConfig, EncDecConfig, MoEConfig, SSMConfig, SHAPES, ShapeSpec,
    applicable_shapes,
)

_REGISTRY = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    from repro.configs import (qwen3_32b, granite_34b, smollm_360m, glm4_9b,  # noqa
                               kimi_k2, arctic_480b, rwkv6_7b, zamba2_2p7b,
                               whisper_small, qwen2_vl_72b)
    return _REGISTRY[name]


def all_arch_names() -> list[str]:
    from repro.configs import (qwen3_32b, granite_34b, smollm_360m, glm4_9b,  # noqa
                               kimi_k2, arctic_480b, rwkv6_7b, zamba2_2p7b,
                               whisper_small, qwen2_vl_72b)
    return sorted(_REGISTRY.keys())


__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "EncDecConfig", "SHAPES",
           "ShapeSpec", "applicable_shapes", "get_config", "all_arch_names",
           "register"]
