"""qwen2-vl-72b — VLM transformer backbone with M-RoPE, 80L, d_model 8192,
64H GQA(kv=8), d_ff 29568, vocab 152064. The vision frontend is a STUB:
input_specs() supplies precomputed patch embeddings merged into the token
stream; M-RoPE carries (t, h, w) position streams. [arXiv:2409.12191; hf]"""
from repro.configs import register
from repro.configs.base import ArchConfig

CONFIG = register(ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, head_dim=128, mrope_sections=(16, 24, 24), rope_theta=1e6,
    source="arXiv:2409.12191; hf",
))
