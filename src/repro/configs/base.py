"""Architecture config schema + the four assigned input-shape classes."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    dense_residual_ff: int = 0     # arctic: parallel dense MLP branch
    capacity_factor: float = 2.0   # per-EP-shard token budget multiplier


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    attn_every: int = 6            # zamba2: shared attn block cadence


@dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 12
    encoder_seq: int = 1500        # whisper: 30 s of audio at 50 Hz


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope_sections: Optional[Tuple[int, int, int]] = None   # qwen2-vl
    tie_embeddings: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    mlp: str = "swiglu"            # swiglu | gelu
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    enc_dec: Optional[EncDecConfig] = None
    attention_free: bool = False   # rwkv6
    sub_quadratic: bool = False    # supports long_500k decode
    dtype: str = "bfloat16"
    source: str = ""               # provenance tag from the assignment table

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.mlp == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        per_layer = attn + mlp
        if self.moe:
            e = self.moe
            mult = 3 if self.mlp == "swiglu" else 2
            per_layer = attn + e.n_experts * mult * d * e.d_ff_expert \
                + d * e.n_experts \
                + (mult * d * e.dense_residual_ff if e.dense_residual_ff else 0)
        if self.family == "ssm":      # rwkv6: time-mix + channel-mix
            per_layer = 4 * d * d + 2 * d * self.d_ff_or(f)
        if self.family == "hybrid" and self.ssm:
            d_in = self.ssm.expand * d
            mamba = d * 2 * d_in + d_in * d + d_in * (2 * self.ssm.d_state)
            per_layer = mamba + mlp // self.ssm.attn_every  # amortized shared
        return emb + L * per_layer

    def d_ff_or(self, f: int) -> int:
        return f

    @property
    def n_active_params(self) -> int:
        """Active params per token (= n_params for dense)."""
        if not self.moe:
            return self.n_params
        d, L = self.d_model, self.n_layers
        e = self.moe
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        mult = 3 if self.mlp == "swiglu" else 2
        active_mlp = e.top_k * mult * d * e.d_ff_expert \
            + (mult * d * e.dense_residual_ff if e.dense_residual_ff else 0)
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return emb + L * (attn + active_mlp)

    def reduced(self, n_layers: int = 2, d_model: int = 64, n_heads: int = 4,
                n_kv_heads: Optional[int] = None, d_ff: int = 128,
                vocab: int = 512) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kv = n_kv_heads if n_kv_heads is not None else max(
            1, n_heads * self.n_kv_heads // max(self.n_heads, 1))
        kw = dict(n_layers=n_layers, d_model=d_model, n_heads=n_heads,
                  n_kv_heads=kv, d_ff=d_ff, vocab=vocab,
                  head_dim=d_model // n_heads, dtype="float32")
        if self.moe:
            kw["moe"] = replace(self.moe, n_experts=8, top_k=min(self.moe.top_k, 2),
                                d_ff_expert=d_ff,
                                dense_residual_ff=(d_ff if self.moe.dense_residual_ff
                                                   else 0))
        if self.ssm:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=16, attn_every=2)
        if self.enc_dec:
            kw["enc_dec"] = replace(self.enc_dec, n_encoder_layers=2,
                                    encoder_seq=24)
        if self.mrope_sections is not None:
            hd = d_model // n_heads
            hw = max(hd // 8, 1)
            kw["mrope_sections"] = (hd // 2 - 2 * hw, hw, hw)
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out
