"""LiveBackend: dual-mode sandbox payloads behind the DES orchestrator.

Two execution modes, selected per function by ``LiveFunctionSpec.mode``:

  * ``process``   — the sandbox is an in-process ``Replica`` +
    ``ContinuousBatcher``. Creation cost is model-state construction only
    (params + KV cache, ~ms) because the XLA executables come from the
    process-global ``ExecutableCache`` — the live analogue of a snapshot
    restore against pre-created state.
  * ``container`` — the sandbox is an isolated subprocess worker
    (repro/live/container.py): spawn + import + replica build, hundreds of
    ms to seconds, the containerd analogue. Its executables cannot be
    shared in-process; the JAX *persistent* compilation cache directory
    plays the shared-cache role across worker processes instead.

Wiring into the DES (all hooks are no-ops unless a backend is installed —
the default path stays bit-identical):

  * ``create_hook(sandbox)``   — called by ``WorkerDaemon.create_sandbox``
    after the modeled boot; builds the replica, logs cold/warm wall time.
  * ``teardown_hook(sid, drain=True)`` — called by
    ``WorkerDaemon.kill_sandbox`` (drain: in-slot requests finish first,
    matching the DES ``teardown_drain_grace`` semantics) and by
    ``fail_node`` (drain=False: in-slot requests fail).
  * ``admit``/``collect``      — the invoke path. ``WorkerDaemon.execute``
    admits the invocation's ``LiveRequest`` into the target sandbox's
    batcher *before* yielding its dispatch-overhead timeout, so requests
    that are concurrent in sim time land in slots together and share
    decode steps; ``collect`` then pumps the batcher until the request's
    slot finishes, billing only the wall time this request actually spent
    pumping (work done while pumping for a neighbour is the batching win).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.request import LiveRequest
from repro.models.api import RunConfig


@dataclass(frozen=True)
class LiveFunctionSpec:
    """Per-function live-execution config (the ``live_mode`` knobs)."""

    cfg: ArchConfig
    mode: str = "process"            # "process" | "container"
    run_cfg: Optional[RunConfig] = None
    max_seq: int = 64                # replica KV-cache length
    max_slots: int = 4               # batcher slots == DP concurrency
    default_max_new: int = 8         # when a LiveRequest leaves it unset


@dataclass
class LiveTicket:
    """Handle returned by ``admit``; redeemed by ``collect``."""

    sandbox_id: int
    rid: int                         # batcher request id
    request: LiveRequest
    admit_peers: int = 0             # active slots present at admission


class _ProcessSandbox:
    """In-process replica + batcher (mode="process")."""

    def __init__(self, spec: LiveFunctionSpec, exec_cache, seed: int):
        from repro.serving.engine import ContinuousBatcher, Replica

        self.spec = spec
        self.replica = Replica(spec.cfg, rng_seed=seed, max_seq=spec.max_seq,
                               run_cfg=spec.run_cfg, exec_cache=exec_cache)
        self.batcher = ContinuousBatcher(self.replica,
                                         max_slots=spec.max_slots)

    def admit(self, req: LiveRequest) -> Tuple[int, int]:
        """Admit into a free slot; returns (rid, co-resident active slots)."""
        peers = sum(1 for s in self.batcher.slots if s.active)
        rid = self.batcher.add_request(
            list(req.prompt), req.max_new_tokens or self.spec.default_max_new)
        return rid, peers

    def pump(self, rid: int) -> Tuple[Optional[List[int]], int]:
        """Step the shared batcher until ``rid`` finishes; returns (tokens,
        max co-resident active slots seen while pumping)."""
        peers = 0
        while rid not in self.batcher.finished:
            active = sum(1 for s in self.batcher.slots if s.active)
            if active == 0:
                break                # aborted out from under us
            peers = max(peers, active)
            self.batcher.step()
        return self.batcher.finished.get(rid), peers

    def drain(self) -> Dict[int, List[int]]:
        return self.batcher.run_until_done()

    def abort(self) -> List[int]:
        return self.batcher.abort()

    def close(self) -> None:
        pass


class LiveBackend:
    """Owns every live sandbox runtime; plugs into Cluster via hooks."""

    def __init__(self, specs: Optional[Dict[str, LiveFunctionSpec]] = None,
                 default_spec: Optional[LiveFunctionSpec] = None,
                 exec_cache=None, compile_cache_dir: Optional[str] = None):
        from repro.serving.exec_cache import default_cache

        self.specs = dict(specs or {})
        self.default_spec = default_spec
        self.exec_cache = exec_cache if exec_cache is not None \
            else default_cache()
        # container-mode persistent XLA cache dir (shared across workers)
        self.compile_cache_dir = compile_cache_dir
        self.sandboxes: Dict[int, object] = {}       # sid -> runtime
        # results that outlive their runtime (graceful teardown drains
        # in-slot requests; their tickets must still collect)
        self._orphaned: Dict[Tuple[int, int], List[int]] = {}
        self._failed_sids: set = set()               # torn down drain=False
        # -- observability (monitoring.render_metrics) ----------------------
        self.start_log: List[dict] = []              # one row per creation
        self.teardowns = 0
        self.invokes = 0
        self.invoke_seconds_total = 0.0
        self.tokens_total = 0
        self.batched_invokes = 0                     # shared >=1 decode step

    # -- config ------------------------------------------------------------
    def spec_for(self, function_name: str) -> LiveFunctionSpec:
        spec = self.specs.get(function_name, self.default_spec)
        if spec is None:
            raise KeyError(f"no LiveFunctionSpec for {function_name!r} "
                           "and no default_spec")
        return spec

    @property
    def replicas_live(self) -> int:
        return len(self.sandboxes)

    def cache_stats(self) -> dict:
        return self.exec_cache.stats()

    # -- WorkerDaemon hooks --------------------------------------------------
    def create_hook(self, sandbox) -> None:
        """Build the real payload for a freshly booted sandbox. Wall time
        (and whether the executable cache was cold) lands in start_log —
        the measured per-phase costs the bench turns into a calibrated
        DirigentCosts candidate."""
        spec = self.spec_for(sandbox.function_name)
        t0 = time.perf_counter()
        misses0 = self.exec_cache.misses
        if spec.mode == "container":
            from repro.live.container import ContainerSandbox

            rt = ContainerSandbox(spec, cache_dir=self.compile_cache_dir,
                                  seed=sandbox.sandbox_id)
            cold = rt.cold
        else:
            rt = _ProcessSandbox(spec, self.exec_cache,
                                 seed=sandbox.sandbox_id)
            # bill the executable trace to creation (not the first invoke):
            # a cold cache compiles here; a warm one returns instantly
            shape = ShapeSpec("live", spec.max_seq, spec.max_slots, "decode")
            compile_s = self.exec_cache.warm(spec.cfg, shape,
                                             run_cfg=rt.replica.run_cfg,
                                             params=rt.replica.params)
            # cold = this creation built the entry OR traced a new shape
            cold = self.exec_cache.misses > misses0 or compile_s > 0.0
        self.sandboxes[sandbox.sandbox_id] = rt
        self._failed_sids.discard(sandbox.sandbox_id)
        self.start_log.append({
            "sandbox_id": sandbox.sandbox_id,
            "function": sandbox.function_name,
            "mode": spec.mode,
            "cold": cold,
            "wall_s": round(time.perf_counter() - t0, 6),
        })

    def teardown_hook(self, sandbox_id: int, drain: bool = True) -> None:
        """Reclaim a sandbox's replica. drain=True finishes in-slot
        requests first (the DES drain-grace analogue); drain=False fails
        them (node death)."""
        rt = self.sandboxes.pop(sandbox_id, None)
        if rt is None:
            return
        self.teardowns += 1
        if drain:
            for rid, toks in rt.drain().items():
                self._orphaned[(sandbox_id, rid)] = toks
        else:
            rt.abort()
            self._failed_sids.add(sandbox_id)
        rt.close()

    # -- invoke path ---------------------------------------------------------
    def admit(self, sandbox_id: int, req: LiveRequest) -> LiveTicket:
        rt = self.sandboxes.get(sandbox_id)
        if rt is None:
            raise RuntimeError(f"live sandbox {sandbox_id} gone")
        rid, peers = rt.admit(req)
        return LiveTicket(sandbox_id=sandbox_id, rid=rid, request=req,
                          admit_peers=peers)

    def collect(self, ticket: LiveTicket) -> LiveRequest:
        """Run the ticket's request to completion; fills the LiveRequest
        in place and returns it. Wall time spent *here* is what the worker
        bills to the sim clock."""
        req = ticket.request
        t0 = time.perf_counter()
        key = (ticket.sandbox_id, ticket.rid)
        rt = self.sandboxes.get(ticket.sandbox_id)
        toks: Optional[List[int]] = None
        peers = 0
        if key in self._orphaned:                # finished during teardown
            toks = self._orphaned.pop(key)
        elif rt is not None:
            toks, peers = rt.pump(ticket.rid)
        if toks is None:
            req.failed = True
            req.failure_reason = (
                "sandbox failed with request in slot"
                if ticket.sandbox_id in self._failed_sids
                else "request aborted")
        else:
            req.tokens = toks
            # shared decode steps with: slots present when we were admitted
            # (we free-rode on their pump) or co-active while we pumped
            req.batched_with = max(ticket.admit_peers, peers - 1, 0)
            self.tokens_total += len(toks)
            if req.batched_with:
                self.batched_invokes += 1
        req.wall_s = time.perf_counter() - t0
        self.invokes += 1
        self.invoke_seconds_total += req.wall_s
        return req

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Tear down every remaining runtime (bench/test cleanup)."""
        for sid in list(self.sandboxes):
            self.teardown_hook(sid, drain=False)
