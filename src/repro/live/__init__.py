"""Live execution mode: real JAX payloads behind the Dirigent orchestrator.

The DES stays the source of truth for orchestration latency; this package
supplies the *payload* side — per-sandbox replicas (in-process or
subprocess) executing real inference on the DP invoke path. See
docs/architecture.md "Live execution mode".
"""
from repro.live.backend import LiveBackend, LiveFunctionSpec, LiveTicket

__all__ = ["LiveBackend", "LiveFunctionSpec", "LiveTicket"]
