"""Container-mode live sandbox: an isolated subprocess replica worker.

The containerd analogue of the live backend's process mode: the sandbox is
a real OS process with its own JAX runtime, started with the ``spawn``
method (fork deadlocks under JAX's thread pools) and driven over a pipe
with a tiny admit/collect protocol mirroring ``_ProcessSandbox``.

An in-process ``ExecutableCache`` cannot help across process boundaries,
so the shared-executable story here is the JAX *persistent compilation
cache*: the parent passes ``cache_dir`` and every child points
``jax_compilation_cache_dir`` at it. The first worker of a config pays the
XLA compile and populates the directory; later workers (the "warm
container" path) deserialize the executable instead of recompiling — the
same cold/warm split the in-process cache gives, at container granularity.

Protocol (parent -> child):
    ("admit", prompt, max_new)   -> ("rid", rid, peers)
    ("collect", rid)             -> ("done", tokens_or_None, peers)
    ("shutdown", drain)          -> ("bye", finished_dict)
"""
from __future__ import annotations

import multiprocessing as mp
import time
from typing import Dict, List, Optional, Tuple

# ready-ack budget: tiny-config CPU compile is ~2-4 s; a hung child should
# fail the creation, not the whole bench
_READY_TIMEOUT_S = 120.0


def _child_main(conn, spec, cache_dir: Optional[str], seed: int) -> None:
    """Subprocess entry point (module-level: spawn must import it)."""
    import jax

    if cache_dir:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update(
            "jax_persistent_cache_min_entry_size_bytes", -1)
    from repro.serving.engine import ContinuousBatcher, Replica
    from repro.serving.exec_cache import ExecutableCache

    # fresh per-process cache: isolation is the point of container mode
    replica = Replica(spec.cfg, rng_seed=seed, max_seq=spec.max_seq,
                      run_cfg=spec.run_cfg,
                      exec_cache=ExecutableCache())
    batcher = ContinuousBatcher(replica, max_slots=spec.max_slots)
    # warm the batcher's decode signature before acking ready, so creation
    # wall time includes the compile (cold) or persistent-cache load (warm)
    warm_rid = batcher.add_request([1], 1)
    batcher.run_until_done()
    batcher.finished.pop(warm_rid, None)
    conn.send(("ready",))
    while True:
        msg = conn.recv()
        if msg[0] == "admit":
            _, prompt, max_new = msg
            peers = sum(1 for s in batcher.slots if s.active)
            rid = batcher.add_request(list(prompt), max_new)
            conn.send(("rid", rid, peers))
        elif msg[0] == "collect":
            rid = msg[1]
            peers = 0
            while rid not in batcher.finished:
                active = sum(1 for s in batcher.slots if s.active)
                if active == 0:
                    break
                peers = max(peers, active)
                batcher.step()
            conn.send(("done", batcher.finished.get(rid), peers))
        elif msg[0] == "shutdown":
            if msg[1]:
                batcher.run_until_done()
            else:
                batcher.abort()
            conn.send(("bye", dict(batcher.finished)))
            break
    conn.close()


class ContainerSandbox:
    """Parent-side handle; API mirrors ``_ProcessSandbox``."""

    def __init__(self, spec, cache_dir: Optional[str] = None, seed: int = 0):
        import os

        self.spec = spec
        # cold = nothing persisted yet for any config (first worker pays
        # the compile and populates the directory)
        self.cold = (not cache_dir) or not os.path.isdir(cache_dir) \
            or not os.listdir(cache_dir)
        ctx = mp.get_context("spawn")
        self._conn, child_conn = ctx.Pipe()
        self.proc = ctx.Process(target=_child_main,
                                args=(child_conn, spec, cache_dir, seed),
                                daemon=True)
        t0 = time.perf_counter()
        self.proc.start()
        child_conn.close()
        if not self._conn.poll(_READY_TIMEOUT_S):
            self.proc.kill()
            raise RuntimeError("container worker never became ready")
        assert self._conn.recv()[0] == "ready"
        self.start_wall_s = time.perf_counter() - t0
        self._finished: Dict[int, List[int]] = {}

    def admit(self, req) -> Tuple[int, int]:
        self._conn.send(("admit", list(req.prompt),
                         req.max_new_tokens or self.spec.default_max_new))
        _, rid, peers = self._conn.recv()
        return rid, peers

    def pump(self, rid: int) -> Tuple[Optional[List[int]], int]:
        if rid in self._finished:
            return self._finished.pop(rid), 0
        self._conn.send(("collect", rid))
        _, toks, peers = self._conn.recv()
        return toks, peers

    def drain(self) -> Dict[int, List[int]]:
        return self._shutdown(drain=True)

    def abort(self) -> List[int]:
        self._shutdown(drain=False)
        return []

    def _shutdown(self, drain: bool) -> Dict[int, List[int]]:
        finished: Dict[int, List[int]] = {}
        try:
            self._conn.send(("shutdown", drain))
            if self._conn.poll(_READY_TIMEOUT_S):
                msg = self._conn.recv()
                if msg[0] == "bye":
                    finished = msg[1]
        except (BrokenPipeError, EOFError, OSError):
            pass
        self.proc.join(timeout=10.0)
        if self.proc.is_alive():
            self.proc.kill()
        return finished

    def close(self) -> None:
        if self.proc.is_alive():
            self._shutdown(drain=False)
        self._conn.close()
