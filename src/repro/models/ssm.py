"""Zamba2 — hybrid Mamba-2 backbone with a *shared* attention block.

Structure (arXiv:2411.15242, simplified): ``n_layers`` Mamba-2 mixer blocks;
after every ``attn_every`` blocks, one shared full-attention transformer
block (GQA kv=n_heads here) is applied — the SAME weights at every
invocation point (the per-invocation LoRA adapters of the real model are
omitted; noted in DESIGN.md). With n_layers=54 and attn_every=6 there are 9
invocation points, each with its own KV cache.

The Mamba-2 mixer uses the chunk-parallel SSD form (kernels/chunked.ssd_*).
State for decode is O(1) in context (conv tail + SSD state); only the shared
attention block carries a KV cache, which for ``long_500k`` is sharded along
the *sequence* axis over the ``data`` mesh dimension (sequence-parallel
cache) since batch=1 cannot use the data axis.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import layers as L
from repro.models.api import RunConfig
from repro.models.sharding import constrain
from repro.kernels.chunked import ssd_chunked, ssd_decode


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


class Zamba2Model:
    def __init__(self, cfg: ArchConfig, run_cfg: RunConfig):
        self.cfg = cfg
        self.run = run_cfg
        s = cfg.ssm
        assert s is not None
        self.d_inner = s.expand * cfg.d_model
        assert self.d_inner % s.head_dim == 0
        self.n_ssm_heads = self.d_inner // s.head_dim
        assert cfg.n_layers % s.attn_every == 0
        self.n_super = cfg.n_layers // s.attn_every
        self.per_super = s.attn_every

    # ------------------------------------------------------------------ params
    def _mamba_shapes(self):
        cfg = self.cfg
        s = cfg.ssm
        d, din, N, H = cfg.d_model, self.d_inner, s.d_state, self.n_ssm_heads
        dt = _dt(cfg)
        conv_ch = din + 2 * N
        return {
            "ln": ((d,), jnp.float32),
            "in_proj": ((d, 2 * din + 2 * N + H), dt),
            "conv_w": ((s.conv_width, conv_ch), jnp.float32),
            "conv_b": ((conv_ch,), jnp.float32),
            "A_log": ((H,), jnp.float32),
            "D": ((H,), jnp.float32),
            "dt_bias": ((H,), jnp.float32),
            "out_proj": ((din, d), dt),
        }

    def _shared_shapes(self):
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.resolved_head_dim
        hq, hkv, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
        dt = _dt(cfg)
        return {
            "ln1": ((d,), jnp.float32),
            "wq": ((d, hq * hd), dt), "wk": ((d, hkv * hd), dt),
            "wv": ((d, hkv * hd), dt), "wo": ((hq * hd, d), dt),
            "ln2": ((d,), jnp.float32),
            "w_gate": ((d, f), dt), "w_up": ((d, f), dt),
            "w_down": ((f, d), dt),
        }

    def param_specs(self):
        cfg = self.cfg
        ns, ps = self.n_super, self.per_super
        mamba = {k: jax.ShapeDtypeStruct((ns, ps) + s, d)
                 for k, (s, d) in self._mamba_shapes().items()}
        shared = {k: jax.ShapeDtypeStruct(s, d)
                  for k, (s, d) in self._shared_shapes().items()}
        return {
            "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), _dt(cfg)),
            "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32),
            "lm_head": jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), _dt(cfg)),
            "mamba": mamba,
            "shared": shared,
        }

    def param_pspecs(self):
        m = self.run.model_axis
        mamba = {
            "ln": P(None, None, None),
            "in_proj": P(None, None, None, m),
            "conv_w": P(None, None, None, m),
            "conv_b": P(None, None, m),
            "A_log": P(None, None, m), "D": P(None, None, m),
            "dt_bias": P(None, None, m),
            "out_proj": P(None, None, m, None),
        }
        shared = {
            "ln1": P(None), "wq": P(None, m), "wk": P(None, m),
            "wv": P(None, m), "wo": P(m, None), "ln2": P(None),
            "w_gate": P(None, m), "w_up": P(None, m), "w_down": P(m, None),
        }
        return {"embed": P(m, None), "final_norm": P(None),
                "lm_head": P(None, m), "mamba": mamba, "shared": shared}

    def init_params(self, rng):
        cfg = self.cfg
        ns, ps = self.n_super, self.per_super
        mamba, shared = {}, {}
        for i, (k, (shape, d)) in enumerate(self._mamba_shapes().items()):
            key = jax.random.fold_in(rng, i)
            if k == "ln":
                mamba[k] = jnp.ones((ns, ps) + shape, d)
            elif k == "A_log":
                mamba[k] = jnp.log(jnp.broadcast_to(
                    jnp.linspace(1.0, 8.0, shape[0]), (ns, ps) + shape)
                ).astype(d)
            elif k in ("D", "dt_bias", "conv_b"):
                mamba[k] = jnp.zeros((ns, ps) + shape, d)
            elif k == "conv_w":
                mamba[k] = (jax.random.normal(key, (ns, ps) + shape) * 0.2
                            ).astype(d)
            else:
                mamba[k] = L.dense_init(key, (ns, ps) + shape, d)
        for i, (k, (shape, d)) in enumerate(self._shared_shapes().items()):
            key = jax.random.fold_in(rng, 100 + i)
            shared[k] = (jnp.ones(shape, d) if k.startswith("ln")
                         else L.dense_init(key, shape, d))
        return {
            "embed": L.dense_init(jax.random.fold_in(rng, 998),
                                  (cfg.vocab, cfg.d_model), _dt(cfg), scale=0.02),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "lm_head": L.dense_init(jax.random.fold_in(rng, 999),
                                    (cfg.d_model, cfg.vocab), _dt(cfg)),
            "mamba": mamba, "shared": shared,
        }

    # ------------------------------------------------------------------ inputs
    def input_specs(self, shape: ShapeSpec):
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}

    def input_pspecs(self, shape: ShapeSpec):
        dax = self.run.data_axes if shape.global_batch > 1 else None
        if shape.kind == "train":
            return {"tokens": P(dax, None), "labels": P(dax, None)}
        if shape.kind == "prefill":
            return {"tokens": P(dax, None)}
        return {"tokens": P(dax, None), "cache_len": P()}

    def cache_specs(self, shape: ShapeSpec):
        cfg = self.cfg
        s = cfg.ssm
        b, smax = shape.global_batch, shape.seq_len
        H, Pd, N = self.n_ssm_heads, s.head_dim, s.d_state
        hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        conv_ch = self.d_inner + 2 * N
        ns, ps = self.n_super, self.per_super
        return {
            "ssd": jax.ShapeDtypeStruct((ns, ps, b, H, Pd, N), jnp.float32),
            "conv": jax.ShapeDtypeStruct((ns, ps, b, s.conv_width - 1, conv_ch),
                                         jnp.float32),
            "k": jax.ShapeDtypeStruct((ns, b, smax, hkv, hd), _dt(cfg)),
            "v": jax.ShapeDtypeStruct((ns, b, smax, hkv, hd), _dt(cfg)),
        }

    def cache_pspecs(self, shape: ShapeSpec):
        dax = self.run.data_axes
        m = self.run.model_axis
        if shape.global_batch == 1:
            # long-context single-stream decode: sequence-parallel KV cache
            # (+ KV heads over the model axis); batch dim unshardable
            kv = P(None, None, dax, m, None)
            bax = None
        else:
            kv = P(None, dax, None, None, None)
            bax = dax
        return {"ssd": P(None, None, bax, m, None, None),
                "conv": P(None, None, bax, None, m),
                "k": kv, "v": kv}

    def init_cache(self, shape: ShapeSpec, batch: Optional[int] = None):
        specs = self.cache_specs(shape)
        b = batch or shape.global_batch
        out = {}
        for k, sp in specs.items():
            shp = list(sp.shape)
            bdim = 2 if k in ("ssd", "conv") else 1
            shp[bdim] = b
            out[k] = jnp.zeros(shp, sp.dtype)
        return out

    # ------------------------------------------------------------------ mamba block
    def _conv(self, w, xBC, conv_state, decode: bool):
        """Causal depthwise conv width-4 via shifted adds.
        xBC: (B,S,CH); conv_state: (B,width-1,CH) tail of previous tokens."""
        width = self.cfg.ssm.conv_width
        full = jnp.concatenate([conv_state, xBC], axis=1)   # (B, S+w-1, CH)
        out = jnp.zeros_like(xBC)
        for i in range(width):
            out = out + full[:, i:i + xBC.shape[1], :] * w["conv_w"][i][None, None]
        out = out + w["conv_b"][None, None]
        new_state = full[:, -(width - 1):, :] if width > 1 else conv_state
        return jax.nn.silu(out), new_state

    def _mamba_block(self, w, x, state, decode: bool):
        cfg = self.cfg
        s = cfg.ssm
        B, S, D = x.shape
        din, N, H, Pd = self.d_inner, s.d_state, self.n_ssm_heads, s.head_dim
        ssd_state, conv_state = state
        h = L.rms_norm(x, w["ln"])
        proj = (h.astype(_dt(cfg)) @ w["in_proj"]).astype(jnp.float32)
        z, xin, Bm, Cm, dt_raw = jnp.split(
            proj, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)
        xBC = jnp.concatenate([xin, Bm, Cm], axis=-1)
        xBC, conv_new = self._conv(w, xBC, conv_state, decode)
        xin, Bm, Cm = jnp.split(xBC, [din, din + N], axis=-1)
        dt = jax.nn.softplus(dt_raw + w["dt_bias"][None, None])   # (B,S,H)
        A = -jnp.exp(w["A_log"])
        xh = xin.reshape(B, S, H, Pd)
        Bh = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, N))
        Ch = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))
        if decode:
            y, ssd_new = ssd_decode(xh, dt, A, Bh, Ch, w["D"], ssd_state)
        else:
            y, ssd_new = ssd_chunked(xh, dt, A, Bh, Ch, w["D"], ssd_state,
                                     chunk=self.run.seq_chunk,
                                     unroll=self.run.layer_mode == "unroll")
        y = y.reshape(B, S, din) * jax.nn.silu(z)
        out = y.astype(_dt(cfg)) @ w["out_proj"]
        return x + out, (ssd_new, conv_new)

    # ------------------------------------------------------------------ shared attention
    def _shared_block(self, w, x, pos, cache_kv=None, cache_len=None):
        cfg, run = self.cfg, self.run
        B, S, D = x.shape
        hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        h = L.rms_norm(x, w["ln1"]).astype(_dt(cfg))
        q = (h @ w["wq"]).reshape(B, S, hq, hd)
        k = (h @ w["wk"]).reshape(B, S, hkv, hd)
        v = (h @ w["wv"]).reshape(B, S, hkv, hd)
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
        if cache_kv is None:
            o = L.flash_attention_jnp(q, k, v, causal=True,
                                      q_chunk=run.q_chunk,
                                      kv_chunk=run.kv_chunk,
                                      unroll=run.attn_unroll)
            new_kv = None
        else:
            ck, cv = cache_kv
            ck = lax.dynamic_update_slice(ck, k, (0, cache_len, 0, 0))
            cv = lax.dynamic_update_slice(cv, v, (0, cache_len, 0, 0))
            o = L.decode_attention_jnp(q, ck, cv, cache_len + 1)
            new_kv = (ck, cv)
        x = x + (o.reshape(B, S, hq * hd) @ w["wo"])
        h = L.rms_norm(x, w["ln2"]).astype(_dt(cfg))
        x = x + L.swiglu(h, w["w_gate"], w["w_up"], w["w_down"])
        x = constrain(x, P(self.run.data_axes, None, None))
        return x, new_kv

    # ------------------------------------------------------------------ stack
    def _stack(self, params, x, pos, cache, decode: bool):
        cfg = self.cfg
        B = x.shape[0]
        ns, ps = self.n_super, self.per_super
        if cache is None:
            cache = self.init_cache(
                ShapeSpec("tmp", 1, B, "decode"), batch=B)
        shared_w = params["shared"]

        mamba_block = self._mamba_block
        shared_block = self._shared_block
        if self.run.remat and not decode:
            mamba_block = jax.checkpoint(mamba_block, static_argnums=(3,))
            shared_block = jax.checkpoint(shared_block)

        def super_block(x, idx, wsup, ssd_s, conv_s, kc, vc):
            # inner mamba layers
            def inner(carry, wl_state):
                x = carry
                wl, (ss, cs) = wl_state
                x, (ss2, cs2) = mamba_block(wl, x, (ss, cs), decode)
                return x, (ss2, cs2)

            if self.run.layer_mode == "scan":
                x, (ssd_new, conv_new) = lax.scan(
                    inner, x, (wsup, (ssd_s, conv_s)))
            else:
                s_list, c_list = [], []
                for j in range(ps):
                    wl = jax.tree.map(lambda a: a[j], wsup)
                    x, (s2, c2) = inner(x, (wl, (ssd_s[j], conv_s[j])))
                    s_list.append(s2); c_list.append(c2)
                ssd_new, conv_new = jnp.stack(s_list), jnp.stack(c_list)
            # shared attention block
            if decode:
                cl = cache["cache_len_scalar"]
                x, (kc, vc) = shared_block(shared_w, x, pos, (kc, vc), cl)
            else:
                x, _ = shared_block(shared_w, x, pos)
            return x, ssd_new, conv_new, kc, vc

        ssd_all, conv_all = cache["ssd"], cache["conv"]
        k_all, v_all = cache["k"], cache["v"]
        ssd_out, conv_out, k_out, v_out = [], [], [], []
        for i in range(ns):
            wsup = jax.tree.map(lambda a: a[i], params["mamba"])
            x, s2, c2, k2, v2 = super_block(
                x, i, wsup, ssd_all[i], conv_all[i], k_all[i], v_all[i])
            ssd_out.append(s2); conv_out.append(c2)
            k_out.append(k2); v_out.append(v2)
        new_cache = {"ssd": jnp.stack(ssd_out), "conv": jnp.stack(conv_out),
                     "k": jnp.stack(k_out), "v": jnp.stack(v_out)}
        return x, new_cache

    # ------------------------------------------------------------------ steps
    def forward(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(_dt(cfg))
        x = constrain(x, P(self.run.data_axes, None, None))
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, _ = self._stack(params, x, pos, None, decode=False)
        x = L.rms_norm(x, params["final_norm"])
        return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])

    def loss_fn(self, params, batch):
        logits = self.forward(params, batch).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                                   axis=-1)[..., 0]
        return jnp.mean(lse - gold)

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        tokens, cache_len = batch["tokens"], batch["cache_len"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(_dt(cfg))
        B = tokens.shape[0]
        pos = jnp.broadcast_to(cache_len[None, None].astype(jnp.int32),
                               (B, 1))
        cache = dict(cache)
        cache["cache_len_scalar"] = cache_len
        x, new_cache = self._stack(params, x, pos, cache, decode=True)
        x = L.rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, -1]
        return logits, new_cache
