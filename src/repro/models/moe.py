"""Mixture-of-Experts FFN with expert parallelism (kimi-k2, arctic).

Strategy ("sorted EP"): experts are sharded over the ``model`` mesh axis;
token activations are sharded over the data axes and *replicated* across
``model`` (as in tensor-parallel FFN). Each model-shard:

  1. computes router top-k locally (router weights are replicated);
  2. selects the assignments that target ITS experts, packs them into a
     fixed-capacity buffer by sorting (capacity = local_tokens * top_k /
     n_shards * capacity_factor — overflow drops, standard for capacity-based
     MoE);
  3. runs the packed tokens through its local experts with
     ``jax.lax.ragged_dot`` (grouped GEMM, MXU-friendly);
  4. scatter-adds weighted outputs back to token positions;
  5. a ``psum`` over ``model`` combines expert outputs across shards (this
     doubles as the top-k combine) — the same all-reduce a TP FFN needs.

This avoids the O(tokens x experts x capacity) one-hot dispatch tensors that
make dense-dispatch MoE infeasible at 384 experts / 1 M tokens, and keeps
every shape static for the 512-device dry-run.

Implemented with ``shard_map`` over (data-axes x model); inside, plain jnp.
The dense residual branch (arctic) runs as ordinary tensor-parallel swiglu
*outside* the shard_map.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.sharding import compat_shard_map, get_abstract_mesh


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def moe_param_specs(cfg: ArchConfig, Lx: int) -> Dict[str, Any]:
    e = cfg.moe
    d, fe = cfg.d_model, e.d_ff_expert
    dt = _dt(cfg)
    out = {
        "router": jax.ShapeDtypeStruct((Lx, d, e.n_experts), jnp.float32),
        "e_gate": jax.ShapeDtypeStruct((Lx, e.n_experts, d, fe), dt),
        "e_up": jax.ShapeDtypeStruct((Lx, e.n_experts, d, fe), dt),
        "e_down": jax.ShapeDtypeStruct((Lx, e.n_experts, fe, d), dt),
    }
    if e.dense_residual_ff:
        fr = e.dense_residual_ff
        out.update({
            "r_gate": jax.ShapeDtypeStruct((Lx, d, fr), dt),
            "r_up": jax.ShapeDtypeStruct((Lx, d, fr), dt),
            "r_down": jax.ShapeDtypeStruct((Lx, fr, d), dt),
        })
    return out


def moe_param_pspecs(cfg: ArchConfig, m: str,
                     fsdp_axes=None) -> Dict[str, Any]:
    """Experts sharded over `m`; with fsdp_axes, the expert FF dim is
    additionally sharded over the data axes (ZeRO-3-style for the 97% of
    kimi-k2's parameters that are experts) and gathered per layer."""
    fa = fsdp_axes
    out = {
        "router": P(None, None, None),
        "e_gate": P(None, m, None, fa),
        "e_up": P(None, m, None, fa),
        "e_down": P(None, m, fa, None),
    }
    if cfg.moe.dense_residual_ff:
        out.update({"r_gate": P(None, None, m), "r_up": P(None, None, m),
                    "r_down": P(None, m, None)})
    return out


def init_moe_params(cfg: ArchConfig, rng, Lx: int) -> Dict[str, Any]:
    specs = moe_param_specs(cfg, Lx)
    out = {}
    for i, (k, s) in enumerate(specs.items()):
        key = jax.random.fold_in(rng, i)
        scale = 0.02 if k == "router" else 1.0 / (s.shape[-2] ** 0.5)
        out[k] = (jax.random.normal(key, s.shape) * scale).astype(s.dtype)
    return out


def _local_moe(cfg: ArchConfig, run_cfg, w, x, *, n_shards: int, shard_id):
    """Per-device MoE computation (runs inside shard_map).

    x: (T, D) local tokens (replicated across the model axis).
    w experts: (E_local, D, Fe). Returns the *partial* output (T, D) which the
    caller psums over the model axis.
    """
    e = cfg.moe
    T, D = x.shape
    E_local = w["e_gate"].shape[0]
    k = e.top_k

    # 1) routing (replicated math — identical on every model shard)
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), w["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, k)                    # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # 2) my assignments: flatten (T*k,) and pack those targeting my experts
    flat_e = top_e.reshape(-1)                            # (T*k,)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    first = shard_id * E_local
    mine = (flat_e >= first) & (flat_e < first + E_local)
    local_e = jnp.where(mine, flat_e - first, E_local)    # E_local = overflow
    C = max(int(T * k / n_shards * run_cfg.moe_capacity_factor), k)
    C = min(C, T * k)
    # sort by (local_e) so my assignments come first, grouped by expert
    order = jnp.argsort(local_e)                          # stable
    sel = order[:C]                                       # (C,)
    sel_e = local_e[sel]                                  # (C,) in [0, E_local]
    sel_tok = flat_tok[sel]
    sel_p = jnp.where(sel_e < E_local, flat_p[sel], 0.0)
    group_sizes = jnp.bincount(sel_e, length=E_local + 1)[:E_local]

    xin = x[sel_tok].astype(_dt(cfg))                     # (C, D)
    g = lax.ragged_dot(xin, w["e_gate"], group_sizes)
    u = lax.ragged_dot(xin, w["e_up"], group_sizes)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(_dt(cfg))
    out = lax.ragged_dot(h, w["e_down"], group_sizes)     # (C, D)
    out = out.astype(jnp.float32) * sel_p[:, None]

    # 4) scatter-add back to token positions
    y = jnp.zeros((T, D), jnp.float32).at[sel_tok].add(out)
    return y


def moe_ffn(cfg: ArchConfig, run_cfg, w, x) -> jax.Array:
    """x: (B, S, D) sharded (data, None, None). Returns same shape/sharding."""
    e = cfg.moe
    m = run_cfg.model_axis
    dax = run_cfg.data_axes
    mesh = get_abstract_mesh()
    axis_sizes = dict(zip(mesh.axis_names, mesh.axis_sizes)) if mesh.axis_names else {}
    n_shards = axis_sizes.get(m, 1)
    B, S, D = x.shape

    moe_w = {k: w[k] for k in ("router", "e_gate", "e_up", "e_down")}

    if m not in axis_sizes:
        # no mesh context (single-device smoke tests): run the local path
        y = _local_moe(cfg, run_cfg, moe_w, x.reshape(B * S, D),
                       n_shards=1, shard_id=0)
        y = y.reshape(B, S, D).astype(x.dtype)
    else:
        def per_shard(xl, wl):
            # xl: (B_local, S, D); wl experts: (E_local, ...)
            shard_id = lax.axis_index(m) if n_shards > 1 else 0
            if run_cfg.fsdp_experts and dax_present:
                # FSDP: gather the FF-dim weight shards just-in-time
                wl = dict(wl)
                wl["e_gate"] = lax.all_gather(wl["e_gate"], dax_present,
                                              axis=2, tiled=True)
                wl["e_up"] = lax.all_gather(wl["e_up"], dax_present,
                                            axis=2, tiled=True)
                wl["e_down"] = lax.all_gather(wl["e_down"], dax_present,
                                              axis=1, tiled=True)
            T = xl.shape[0] * xl.shape[1]
            y = _local_moe(cfg, run_cfg, wl, xl.reshape(T, D),
                           n_shards=n_shards, shard_id=shard_id)
            y = lax.psum(y, m) if n_shards > 1 else y
            return y.reshape(xl.shape).astype(xl.dtype)

        dax_present = tuple(a for a in dax if a in axis_sizes)
        fsdp = run_cfg.fsdp_experts and dax_present
        fa = dax_present if fsdp else None
        in_specs = (P(dax_present, None, None),
                    {"router": P(None, None), "e_gate": P(m, None, fa),
                     "e_up": P(m, None, fa), "e_down": P(m, fa, None)})
        y = compat_shard_map(per_shard, mesh=mesh, in_specs=in_specs,
                             out_specs=P(dax_present, None, None))(x, moe_w)

    if e.dense_residual_ff:
        from repro.models.layers import swiglu
        y = y + swiglu(x, w["r_gate"], w["r_up"], w["r_down"])
    return y
