"""Mesh-aware sharding helpers.

``constrain(x, spec)`` = with_sharding_constraint that degrades gracefully:
no-op without a mesh context, and silently drops mesh axes that don't exist
in the current mesh (so the same model code runs on 1 CPU device in smoke
tests and on the 512-chip production mesh in the dry-run).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax import lax
from jax.sharding import PartitionSpec as P


def mesh_axis_sizes() -> dict:
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return {}
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def filter_spec(spec: P) -> Optional[P]:
    """Drop axes that aren't in the current mesh; None if no mesh at all."""
    sizes = mesh_axis_sizes()
    if not sizes:
        return None
    dims = []
    for entry in spec:
        if entry is None:
            dims.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in sizes)
            dims.append(kept if kept else None)
        else:
            dims.append(entry if entry in sizes else None)
    return P(*dims)


def constrain(x, spec: P):
    fs = filter_spec(spec)
    if fs is None:
        return x
    return lax.with_sharding_constraint(x, fs)
