"""Mesh-aware sharding helpers.

``constrain(x, spec)`` = with_sharding_constraint that degrades gracefully:
no-op without a mesh context, and silently drops mesh axes that don't exist
in the current mesh (so the same model code runs on 1 CPU device in smoke
tests and on the 512-chip production mesh in the dry-run).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax import lax
from jax.sharding import PartitionSpec as P


def compat_make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` across versions: newer JAX wants explicit
    ``axis_types`` (Auto) for the models' mixed auto/explicit sharding; older
    JAX (<= 0.4.x) has no ``axis_types`` kwarg and no ``AxisType`` enum."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def use_mesh(mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` when available,
    else the mesh's own (legacy) context-manager protocol."""
    fn = getattr(jax, "set_mesh", None)
    return fn(mesh) if fn is not None else mesh


def compat_shard_map(f, mesh, in_specs, out_specs):
    """``shard_map`` across versions: newer JAX exposes ``jax.shard_map``
    with ``check_vma``; 0.4.x has ``jax.experimental.shard_map.shard_map``
    with ``check_rep``. Replication checking is off either way — the models
    rely on manual psum merges the checker can't see through."""
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    try:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def get_abstract_mesh():
    """``jax.sharding.get_abstract_mesh`` with a fallback for JAX versions
    that predate it: returns the ambient physical mesh entered via ``with
    mesh:`` (an empty ``Mesh`` — ``axis_names == ()`` — when there is none).
    Both return types expose ``axis_names`` / ``axis_sizes``, which is all
    the model code reads."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax.interpreters import pxla
    return pxla.thread_resources.env.physical_mesh


def mesh_axis_sizes() -> dict:
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return {}
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def filter_spec(spec: P) -> Optional[P]:
    """Drop axes that aren't in the current mesh; None if no mesh at all."""
    sizes = mesh_axis_sizes()
    if not sizes:
        return None
    dims = []
    for entry in spec:
        if entry is None:
            dims.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in sizes)
            dims.append(kept if kept else None)
        else:
            dims.append(entry if entry in sizes else None)
    return P(*dims)


def constrain(x, spec: P):
    fs = filter_spec(spec)
    if fs is None:
        return x
    return lax.with_sharding_constraint(x, fs)
