"""Shared model components: norms, rotary embeddings, attention, MLPs.

All functions are pure JAX (jnp + lax) so they lower for any backend; the
attention entry points can route to Pallas TPU kernels (repro.kernels) when
``impl="pallas"`` — the default ``impl="jnp"`` uses the same blocked online-
softmax algorithm written with ``lax.scan`` so the dry-run HLO is portable
and memory-bounded (no S×S score materialization at 32 K context).

Sharding is expressed with ``jax.lax.with_sharding_constraint`` using
PartitionSpecs built from logical axis names; see models/api.py.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


# -- initializers -------------------------------------------------------------

def dense_init(key, shape, dtype=jnp.float32, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# -- norms ---------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * lax.rsqrt(var + eps)
    return (out * weight).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * lax.rsqrt(var + eps)
    return (out * weight + bias).astype(dt)


# -- rotary embeddings -----------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, sections: Tuple[int, ...],
                theta: float = 10000.0) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): head_dim/2 split into (t, h, w) sections,
    each rotated by its own position stream. positions: (B, S, 3)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    # build a per-frequency position by selecting the section's stream
    sec_id = []
    for i, s in enumerate(sections):
        sec_id += [i] * s
    sec_id = jnp.array(sec_id, dtype=jnp.int32)       # (D/2,)
    pos = positions.astype(jnp.float32)[..., sec_id]  # (B,S,D/2)
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- attention --------------------------------------------------------------------

NEG_INF = -1e30


def repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, Hkv*groups, D) for GQA."""
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, groups, d)
                            ).reshape(b, s, h * groups, d)


def attention_reference(q, k, v, causal: bool = True,
                        q_offset: int = 0) -> jax.Array:
    """O(S^2)-memory oracle. q: (B,Sq,H,D), k/v: (B,Skv,H,D)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(skv)[None, :]
        scores = jnp.where((ki <= qi)[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_attention_jnp(q, k, v, causal: bool = True, q_chunk: int = 512,
                        kv_chunk: int = 1024, q_offset: int = 0,
                        unroll: bool = False) -> jax.Array:
    """Blocked online-softmax attention in pure jnp (FlashAttention algorithm).

    GQA-native: q has Hq heads, k/v have Hkv heads with Hq = G*Hkv; KV is
    never materialized at Hq width. Memory is O(Sq*D + q_chunk*kv_chunk)
    instead of O(Sq*Skv). ``unroll=True`` inlines the chunk loops so XLA
    ``cost_analysis`` counts every iteration (dry-run exactness; see
    EXPERIMENTS.md §Roofline).
    q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D).
    """
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, k.shape[1])
    skv = k.shape[1]
    n_q = -(-sq // q_chunk)
    n_kv = -(-skv // kv_chunk)
    pq = n_q * q_chunk - sq
    pk = n_kv * kv_chunk - skv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))

    # (nq, B, Hkv, G, cq, D) / (nkv, B, Hkv, ck, D)
    qc = q.reshape(b, n_q, q_chunk, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)
    kc = k.reshape(b, n_kv, kv_chunk, hkv, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, n_kv, kv_chunk, hkv, d).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        qi32 = qi.astype(jnp.float32)

        def kv_step(carry, kv_and_idx):
            m, l, acc = carry
            (ki, vi), ik = kv_and_idx
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qi32,
                           ki.astype(jnp.float32)) * scale
            if causal:
                qpos = iq * q_chunk + jnp.arange(q_chunk)[:, None] + q_offset
                kpos = ik * kv_chunk + jnp.arange(kv_chunk)[None, :]
                s = jnp.where((kpos <= qpos)[None, None, None], s, NEG_INF)
            if pk:
                kpos2 = ik * kv_chunk + jnp.arange(kv_chunk)
                s = jnp.where((kpos2 < skv)[None, None, None, None], s,
                              NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
        idx = jnp.arange(n_kv)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), ((kc, vc), idx),
                                  unroll=unroll)
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, out

    iqs = jnp.arange(n_q)
    _, outs = lax.scan(q_step, None, (qc, iqs), unroll=unroll)
    # (nq, B, Hkv, G, cq, D) -> (B, S, Hq, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, n_q * q_chunk, hq, d)
    return out[:, :sq].astype(q.dtype)


def decode_attention_jnp(q, k_cache, v_cache, cache_len) -> jax.Array:
    """Single-token decode against a KV cache with a length mask. GQA-native.

    q: (B, 1, Hq, D); caches: (B, Smax, Hkv, D); cache_len: int32 scalar.
    """
    b, _, hq, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, 1, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(smax)
    mask = pos[None, :] < jnp.reshape(cache_len, (-1, 1))      # (B, Smax)
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)


# -- MLPs --------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x, w_up, b_up, w_down, b_down):
    h = jnp.einsum("bsd,df->bsf", x, w_up) + b_up
    out = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(h).astype(x.dtype), w_down)
    return (out + b_down).astype(x.dtype)
