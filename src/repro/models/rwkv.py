"""RWKV6 (Finch) — attention-free LM with data-dependent per-channel decay.

Faithful-core implementation: token-shift mixing, time-mix block with the
WKV6 recurrence (chunk-parallel for train/prefill, O(1)-state for decode),
squared-ReLU channel-mix. The dynamic decay LoRA is included; the per-token
log-decay is clamped to [-0.5, -1e-4] (see kernels/chunked.py stability
contract).

State for decode: per layer (wkv state (B,H,dk,dv), shift_att (B,D),
shift_ffn (B,D)) — O(1) in context length, which is why rwkv6 runs the
``long_500k`` shape.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import layers as L
from repro.models.api import RunConfig
from repro.models.sharding import constrain
from repro.kernels.chunked import wkv6_chunked, wkv6_decode

LORA_R = 64
LOGW_MIN, LOGW_MAX = -0.5, -1e-4


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


class RWKV6Model:
    def __init__(self, cfg: ArchConfig, run_cfg: RunConfig):
        self.cfg = cfg
        self.run = run_cfg
        assert cfg.d_model % cfg.n_heads == 0
        self.head_dim = cfg.d_model // cfg.n_heads

    # ------------------------------------------------------------------ params
    def _layer_shapes(self):
        cfg = self.cfg
        d, f = cfg.d_model, cfg.d_ff
        dt = _dt(cfg)
        return {
            "ln1": ((d,), jnp.float32), "ln1b": ((d,), jnp.float32),
            "ln2": ((d,), jnp.float32), "ln2b": ((d,), jnp.float32),
            # time-mix
            "mu_r": ((d,), jnp.float32), "mu_k": ((d,), jnp.float32),
            "mu_v": ((d,), jnp.float32), "mu_g": ((d,), jnp.float32),
            "mu_w": ((d,), jnp.float32),
            "w_r": ((d, d), dt), "w_k": ((d, d), dt), "w_v": ((d, d), dt),
            "w_g": ((d, d), dt), "w_o": ((d, d), dt),
            "decay_base": ((d,), jnp.float32),
            "decay_A": ((d, LORA_R), dt), "decay_B": ((LORA_R, d), dt),
            "bonus_u": ((cfg.n_heads, self.head_dim), jnp.float32),
            "gn": ((d,), jnp.float32), "gnb": ((d,), jnp.float32),
            # channel-mix
            "mu_ck": ((d,), jnp.float32), "mu_cr": ((d,), jnp.float32),
            "c_k": ((d, f), dt), "c_v": ((f, d), dt), "c_r": ((d, d), dt),
        }

    def param_specs(self):
        cfg = self.cfg
        Lx = cfg.n_layers
        layers = {k: jax.ShapeDtypeStruct((Lx,) + s, d)
                  for k, (s, d) in self._layer_shapes().items()}
        return {
            "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), _dt(cfg)),
            "ln_in": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32),
            "ln_inb": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32),
            "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32),
            "final_normb": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32),
            "lm_head": jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), _dt(cfg)),
            "layers": layers,
        }

    def param_pspecs(self):
        m = self.run.model_axis
        vec = P(None, None)
        layers = {}
        for k, (shape, _) in self._layer_shapes().items():
            if len(shape) == 1:
                layers[k] = vec
            elif k in ("w_r", "w_k", "w_v", "w_g", "c_k", "c_r"):
                layers[k] = P(None, None, m)     # column-parallel
            elif k in ("w_o", "c_v"):
                layers[k] = P(None, m, None)     # row-parallel
            elif k == "decay_A":
                layers[k] = P(None, None, None)
            elif k == "decay_B":
                layers[k] = P(None, None, m)
            elif k == "bonus_u":
                layers[k] = P(None, m, None)
            else:
                layers[k] = P(*((None,) * (len(shape) + 1)))
        return {
            "embed": P(m, None), "ln_in": P(None), "ln_inb": P(None),
            "final_norm": P(None), "final_normb": P(None),
            "lm_head": P(None, m), "layers": layers,
        }

    def init_params(self, rng):
        cfg = self.cfg
        dt = _dt(cfg)
        Lx = cfg.n_layers
        layers = {}
        for i, (k, (shape, d)) in enumerate(self._layer_shapes().items()):
            key = jax.random.fold_in(rng, i)
            if k.startswith(("ln", "gn")):
                layers[k] = (jnp.ones if not k.endswith("b") else jnp.zeros)(
                    (Lx,) + shape, d)
            elif k.startswith("mu_"):
                layers[k] = jnp.full((Lx,) + shape, 0.5, d)
            elif k == "decay_base":
                layers[k] = jnp.full((Lx,) + shape, -2.0, d)
            elif k == "bonus_u":
                layers[k] = (jax.random.normal(key, (Lx,) + shape) * 0.3
                             ).astype(d)
            else:
                layers[k] = L.dense_init(key, (Lx,) + shape, d)
        key2 = jax.random.fold_in(rng, 999)
        return {
            "embed": L.dense_init(key2, (cfg.vocab, cfg.d_model), dt, scale=0.02),
            "ln_in": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_inb": jnp.zeros((cfg.d_model,), jnp.float32),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "final_normb": jnp.zeros((cfg.d_model,), jnp.float32),
            "lm_head": L.dense_init(jax.random.fold_in(rng, 1000),
                                    (cfg.d_model, cfg.vocab), dt),
            "layers": layers,
        }

    # ------------------------------------------------------------------ inputs
    def input_specs(self, shape: ShapeSpec):
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}

    def input_pspecs(self, shape: ShapeSpec):
        dax = self.run.data_axes if shape.global_batch > 1 else None
        if shape.kind == "train":
            return {"tokens": P(dax, None), "labels": P(dax, None)}
        if shape.kind == "prefill":
            return {"tokens": P(dax, None)}
        return {"tokens": P(dax, None), "cache_len": P()}

    def cache_specs(self, shape: ShapeSpec):
        cfg = self.cfg
        b = shape.global_batch
        H, hd = cfg.n_heads, self.head_dim
        return {
            "wkv": jax.ShapeDtypeStruct((cfg.n_layers, b, H, hd, hd),
                                        jnp.float32),
            "shift_att": jax.ShapeDtypeStruct((cfg.n_layers, b, cfg.d_model),
                                              jnp.float32),
            "shift_ffn": jax.ShapeDtypeStruct((cfg.n_layers, b, cfg.d_model),
                                              jnp.float32),
        }

    def cache_pspecs(self, shape: ShapeSpec):
        dax = self.run.data_axes if shape.global_batch > 1 else None
        m = self.run.model_axis
        return {"wkv": P(None, dax, m, None, None),
                "shift_att": P(None, dax, None),
                "shift_ffn": P(None, dax, None)}

    def init_cache(self, shape: ShapeSpec, batch: Optional[int] = None):
        specs = self.cache_specs(shape)
        b = batch or shape.global_batch
        return {k: jnp.zeros((s.shape[0], b) + s.shape[2:], s.dtype)
                for k, s in specs.items()}

    # ------------------------------------------------------------------ blocks
    def _shift(self, x, prev):
        """Token shift: x_{t-1} with prev feeding position 0."""
        return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)

    def _decay(self, w, xw):
        logw = w["decay_base"][None, None, :] + jnp.tanh(
            xw.astype(jnp.float32) @ w["decay_A"].astype(jnp.float32)
        ) @ w["decay_B"].astype(jnp.float32)
        logw = -jnp.exp(logw)     # strictly negative
        return jnp.clip(logw, LOGW_MIN, LOGW_MAX)

    def _time_mix(self, w, x, prev_shift, wkv_state, decode: bool):
        cfg = self.cfg
        B, S, D = x.shape
        H, hd = cfg.n_heads, self.head_dim
        xs = self._shift(x, prev_shift) if not decode else \
            jnp.broadcast_to(prev_shift[:, None, :], x.shape)

        def mix(mu):
            return x + (xs - x) * mu[None, None, :]

        dt = _dt(cfg)
        xr, xk, xv, xg, xw = (mix(w[f"mu_{n}"]).astype(dt)
                              for n in ("r", "k", "v", "g", "w"))
        r = (xr @ w["w_r"]).reshape(B, S, H, hd)
        k = (xk @ w["w_k"]).reshape(B, S, H, hd)
        v = (xv @ w["w_v"]).reshape(B, S, H, hd)
        g = jax.nn.silu((xg @ w["w_g"]).astype(jnp.float32))
        logw = self._decay(w, xw).reshape(B, S, H, hd)
        decay = jnp.exp(logw)
        if decode:
            y, new_state = wkv6_decode(r, k, v, decay, w["bonus_u"], wkv_state)
        else:
            y, new_state = wkv6_chunked(r, k, v, decay, w["bonus_u"],
                                        wkv_state, chunk=self.run.seq_chunk,
                                        unroll=self.run.layer_mode == "unroll")
        y = y.reshape(B, S, D)
        y = L.layer_norm(y, w["gn"], w["gnb"])   # group-norm approximation
        y = (y * g).astype(dt) @ w["w_o"]
        return y, new_state, x[:, -1, :].astype(jnp.float32)

    def _channel_mix(self, w, x, prev_shift, decode: bool):
        dt = _dt(self.cfg)
        xs = self._shift(x, prev_shift) if not decode else \
            jnp.broadcast_to(prev_shift[:, None, :], x.shape)
        xk = (x + (xs - x) * w["mu_ck"][None, None, :]).astype(dt)
        xr = (x + (xs - x) * w["mu_cr"][None, None, :]).astype(dt)
        kk = jnp.square(jax.nn.relu(xk @ w["c_k"]))
        out = kk @ w["c_v"]
        rr = jax.nn.sigmoid((xr @ w["c_r"]).astype(jnp.float32))
        return (out.astype(jnp.float32) * rr).astype(x.dtype), \
            x[:, -1, :].astype(jnp.float32)

    def _block(self, w, x, state, decode: bool):
        wkv, sh_a, sh_f = state
        h = L.layer_norm(x, w["ln1"], w["ln1b"])
        o, wkv_new, sh_a_new = self._time_mix(w, h, sh_a, wkv, decode)
        x = x + o
        h = L.layer_norm(x, w["ln2"], w["ln2b"])
        o, sh_f_new = self._channel_mix(w, h, sh_f, decode)
        x = x + o
        x = constrain(x, P(self.run.data_axes, None, None))
        return x, (wkv_new, sh_a_new, sh_f_new)

    def _stack(self, params, x, cache, decode: bool):
        cfg = self.cfg
        B = x.shape[0]
        layers = params["layers"]
        if cache is None:
            H, hd = cfg.n_heads, self.head_dim
            z = jnp.zeros((cfg.n_layers, B, H, hd, hd), jnp.float32)
            zs = jnp.zeros((cfg.n_layers, B, cfg.d_model), jnp.float32)
            cache = {"wkv": z, "shift_att": zs, "shift_ffn": zs}
        block = self._block
        if self.run.remat and not decode:
            block = jax.checkpoint(block, static_argnums=(3,))

        def body(x, wl_state):
            wl, st = wl_state
            x, st_new = block(wl, x, st, decode)
            return x, st_new

        states = (cache["wkv"], cache["shift_att"], cache["shift_ffn"])
        if self.run.layer_mode == "scan":
            x, (wkv, sa, sf) = lax.scan(body, x, (layers, states))
        else:
            wkvs, sas, sfs = [], [], []
            for i in range(cfg.n_layers):
                wl = jax.tree.map(lambda a: a[i], layers)
                st = jax.tree.map(lambda a: a[i], states)
                x, (w1, s1, s2) = body(x, (wl, st))
                wkvs.append(w1); sas.append(s1); sfs.append(s2)
            wkv, sa, sf = (jnp.stack(t) for t in (wkvs, sas, sfs))
        return x, {"wkv": wkv, "shift_att": sa, "shift_ffn": sf}

    # ------------------------------------------------------------------ steps
    def forward(self, params, batch):
        cfg = self.cfg
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(_dt(cfg))
        x = constrain(x, P(self.run.data_axes, None, None))
        x = L.layer_norm(x, params["ln_in"], params["ln_inb"])
        x, _ = self._stack(params, x, None, decode=False)
        x = L.layer_norm(x, params["final_norm"], params["final_normb"])
        return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])

    def loss_fn(self, params, batch):
        logits = self.forward(params, batch).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                                   axis=-1)[..., 0]
        return jnp.mean(lse - gold)

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(_dt(cfg))
        x = L.layer_norm(x, params["ln_in"], params["ln_inb"])
        x, new_cache = self._stack(params, x, cache, decode=True)
        x = L.layer_norm(x, params["final_norm"], params["final_normb"])
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, -1]
        return logits, new_cache
