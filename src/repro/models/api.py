"""Model factory + run configuration.

``build_model(cfg, run_cfg)`` returns a ``Model`` with a uniform surface:

    init_params(rng)        -> real param pytree (smoke tests / examples)
    param_specs()           -> ShapeDtypeStruct pytree (dry-run, no alloc)
    param_pspecs()          -> PartitionSpec pytree (logical sharding rules)
    input_specs(shape)      -> dict of ShapeDtypeStructs for the step fn
    input_pspecs(shape)     -> matching PartitionSpecs
    train_step              -> (params, opt_state, batch) -> (params, opt_state, metrics)
    forward                 -> (params, batch) -> logits (prefill/train fwd)
    decode_step             -> (params, cache, batch) -> (logits, cache)
    init_cache(shape)       -> cache specs / zeros for decode shapes
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class RunConfig:
    """Execution knobs (orthogonal to the architecture)."""

    attn_impl: str = "jnp"        # jnp | pallas | reference
    q_chunk: int = 512
    kv_chunk: int = 1024
    layer_mode: str = "scan"      # scan | unroll (unroll => exact HLO costs)
    attn_unroll: bool = False     # inline attention chunk loops (exact costs)
    remat: bool = True            # activation checkpointing per layer
    remat_policy: str = "full"    # full | dots (save matmul outputs)
    sharded_decode: bool = False  # shard_map distributed flash-decode (HC2)
    fsdp_experts: bool = False    # shard expert FF dim over data axes (HC1)
    moe_capacity_factor: float = 1.25
    seq_chunk: int = 256          # rwkv/ssd chunk length
    data_axes: tuple = ("pod", "data")  # batch sharding axes
    model_axis: str = "model"
    use_zero1: bool = False       # shard optimizer state over data axes
    grad_compress: bool = False   # int8 gradient all-reduce (train/compress)
    grad_accum: int = 1


def build_model(cfg: ArchConfig, run_cfg: Optional[RunConfig] = None):
    run_cfg = run_cfg or RunConfig()
    if cfg.family in ("dense", "vlm"):
        from repro.models.transformer import DecoderLM
        return DecoderLM(cfg, run_cfg)
    if cfg.family == "moe":
        from repro.models.transformer import DecoderLM
        return DecoderLM(cfg, run_cfg)
    if cfg.family == "ssm":
        from repro.models.rwkv import RWKV6Model
        return RWKV6Model(cfg, run_cfg)
    if cfg.family == "hybrid":
        from repro.models.ssm import Zamba2Model
        return Zamba2Model(cfg, run_cfg)
    if cfg.family == "audio":
        from repro.models.whisper import WhisperModel
        return WhisperModel(cfg, run_cfg)
    raise ValueError(f"unknown family {cfg.family}")
