"""Distributed flash-decode over a sequence-sharded KV cache (shard_map).

When a model's KV heads are too few to shard (GQA kv < mesh model-axis), the
decode KV cache is sharded along the *sequence* dimension instead. Left to
GSPMD, the compiled HLO all-gathers the entire cache every step (hundreds of
GB on the wire per token — see EXPERIMENTS.md §Perf HC2 baseline). This
module replaces that with the explicit distributed flash-decode:

  * each model-axis shard holds a contiguous cache slice and the q heads it
    owns; it computes a *partial* softmax (m, l, acc) over its slice;
  * the new token's K/V is written by exactly the shard that owns position
    ``cache_len`` (predicated dynamic-update-slice);
  * partials merge with a max/sum-exp reduction: two tiny psums of
    O(B x H_local x head_dim) — kilobytes instead of the cache.

Requires n_q_heads % model_axis_size == 0.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.sharding import compat_shard_map, get_abstract_mesh

NEG_INF = -1e30


def decode_attention_seq_sharded(q, k_cache, v_cache, k_new, v_new,
                                 cache_len, *, model_axis: str,
                                 data_axes: tuple):
    """q: (B,1,Hq,D); caches: (B,Smax,Hkv,D) seq-sharded over model_axis;
    k_new/v_new: (B,1,Hkv,D). Returns (o, ck_updated, cv_updated)."""
    mesh = get_abstract_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    b, _, hq, d = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    dax = tuple(a for a in data_axes if a in sizes)
    bspec = dax if (b > 1 and dax) else None

    def per_shard(q_l, ck_l, cv_l, kn_l, vn_l, clen):
        # q is REPLICATED across the model axis: every shard computes all
        # heads over ITS sequence slice; the psum merge below combines the
        # per-slice partial softmaxes (flash-decode split-KV semantics).
        i = lax.axis_index(model_axis)
        s_loc = ck_l.shape[1]
        start = i * s_loc
        # -- predicated cache write (the owner shard writes the new token) --
        li = jnp.clip(clen - start, 0, s_loc - 1)
        own = jnp.logical_and(clen >= start, clen < start + s_loc)
        old_k = lax.dynamic_slice(ck_l, (0, li, 0, 0), kn_l.shape)
        old_v = lax.dynamic_slice(cv_l, (0, li, 0, 0), vn_l.shape)
        ck_l = lax.dynamic_update_slice(
            ck_l, jnp.where(own, kn_l, old_k), (0, li, 0, 0))
        cv_l = lax.dynamic_update_slice(
            cv_l, jnp.where(own, vn_l, old_v), (0, li, 0, 0))
        # -- local partial flash-decode over my cache slice (GQA-native) -----
        qg = q_l.reshape(b_l := q_l.shape[0], 1, hkv, g, d)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                       ck_l.astype(jnp.float32)) * scale
        pos = start + jnp.arange(s_loc)
        mask = pos[None, :] < jnp.reshape(clen + 1, (-1, 1))
        s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
        m_loc = s.max(axis=-1)                           # (B, hkv, g, 1)
        p = jnp.exp(s - m_loc[..., None])
        l_loc = p.sum(axis=-1)
        acc = jnp.einsum("bhgqk,bkhd->bhgqd", p, cv_l.astype(jnp.float32))
        # -- merge partials across the model axis (tiny collectives) ---------
        m_glob = lax.pmax(m_loc, model_axis)
        corr = jnp.exp(m_loc - m_glob)
        l_glob = lax.psum(l_loc * corr, model_axis)
        acc_glob = lax.psum(acc * corr[..., None], model_axis)
        out = acc_glob / jnp.maximum(l_glob, 1e-20)[..., None]
        # (B, hkv, g, 1, D) -> (B, 1, Hq, D)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b_l, 1, hq, d)
        return out.astype(q_l.dtype), ck_l, cv_l

    o, ck, cv = compat_shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(bspec, None, None, None),            # q: replicated over m
                  P(bspec, model_axis, None, None),      # caches: seq sharded
                  P(bspec, model_axis, None, None),
                  P(bspec, None, None, None),            # new K/V replicated
                  P(bspec, None, None, None),
                  P()),
        out_specs=(P(bspec, None, None, None),
                   P(bspec, model_axis, None, None),
                   P(bspec, model_axis, None, None)),
    )(q, k_cache, v_cache, k_new, v_new,
      jnp.asarray(cache_len, jnp.int32))
    return o, ck, cv
