"""Whisper-small — encoder-decoder transformer backbone.

The audio frontend (mel + conv subsampling) is a STUB per the assignment:
``input_specs()`` supplies precomputed encoder frame embeddings of shape
(B, 1500, d_model). The backbone is faithful: pre-LN encoder with
bidirectional self-attention, decoder with causal self-attention +
cross-attention, GELU MLPs, learned positions on the decoder side and
sinusoidal on the encoder side.

Decode carries a self-attention KV cache plus per-layer cross-attention K/V
computed once from the encoder output (stored in the cache pytree).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.simcore import stable_hash
from repro.models import layers as L
from repro.models.api import RunConfig
from repro.models.sharding import constrain

MAX_DEC_POS = 32768 * 16 + 8   # large enough for the decode_32k cell


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


class WhisperModel:
    def __init__(self, cfg: ArchConfig, run_cfg: RunConfig):
        self.cfg = cfg
        self.run = run_cfg
        self.enc_layers = cfg.enc_dec.n_encoder_layers
        self.enc_seq = cfg.enc_dec.encoder_seq

    # ------------------------------------------------------------------ params
    def _attn_shapes(self, prefix):
        cfg = self.cfg
        d, hd, hq = cfg.d_model, cfg.resolved_head_dim, cfg.n_heads
        dt = _dt(cfg)
        return {
            f"{prefix}ln": ((d,), jnp.float32),
            f"{prefix}lnb": ((d,), jnp.float32),
            f"{prefix}wq": ((d, hq * hd), dt),
            f"{prefix}wk": ((d, hq * hd), dt),
            f"{prefix}wv": ((d, hq * hd), dt),
            f"{prefix}wo": ((hq * hd, d), dt),
        }

    def _mlp_shapes(self):
        cfg = self.cfg
        d, f = cfg.d_model, cfg.d_ff
        dt = _dt(cfg)
        return {
            "mln": ((d,), jnp.float32), "mlnb": ((d,), jnp.float32),
            "w_up": ((d, f), dt), "b_up": ((f,), jnp.float32),
            "w_down": ((f, d), dt), "b_down": ((d,), jnp.float32),
        }

    def param_specs(self):
        cfg = self.cfg
        dt = _dt(cfg)
        enc = {**self._attn_shapes("s_"), **self._mlp_shapes()}
        dec = {**self._attn_shapes("s_"), **self._attn_shapes("x_"),
               **self._mlp_shapes()}
        enc_p = {k: jax.ShapeDtypeStruct((self.enc_layers,) + s, d)
                 for k, (s, d) in enc.items()}
        dec_p = {k: jax.ShapeDtypeStruct((cfg.n_layers,) + s, d)
                 for k, (s, d) in dec.items()}
        return {
            "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dt),
            "dec_pos": jax.ShapeDtypeStruct((MAX_DEC_POS, cfg.d_model), dt),
            "enc_final_ln": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32),
            "enc_final_lnb": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32),
            "dec_final_ln": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32),
            "dec_final_lnb": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32),
            "encoder": enc_p,
            "decoder": dec_p,
        }

    def param_pspecs(self):
        m = self.run.model_axis

        def spec_for(k, ndim):
            if k.endswith(("wq", "wk", "wv")) or k == "w_up":
                return P(*((None,) * (ndim - 1)), m)
            if k.endswith("wo") or k == "w_down":
                return P(*((None,) * (ndim - 2)), m, None)
            if k == "b_up":
                return P(None, m)
            return P(*((None,) * ndim))

        enc = {k: spec_for(k, 3) for k in
               {**self._attn_shapes("s_"), **self._mlp_shapes()}}
        # 1-D params stacked -> ndim 2
        for k, (s, _) in {**self._attn_shapes("s_"), **self._mlp_shapes()}.items():
            if len(s) == 1:
                enc[k] = P(None, m) if k == "b_up" else P(None, None)
        dec = {}
        for k, (s, _) in {**self._attn_shapes("s_"), **self._attn_shapes("x_"),
                          **self._mlp_shapes()}.items():
            dec[k] = (P(None, m) if (k == "b_up" and len(s) == 1)
                      else P(None, None) if len(s) == 1
                      else spec_for(k, 3))
        return {
            # vocab 51865 is not divisible by the model axis: replicate the
            # (tiny) embedding; logits stay replicated over `model`.
            "embed": P(None, None), "dec_pos": P(None, None),
            "enc_final_ln": P(None), "enc_final_lnb": P(None),
            "dec_final_ln": P(None), "dec_final_lnb": P(None),
            "encoder": enc, "decoder": dec,
        }

    def init_params(self, rng):
        specs = self.param_specs()

        def init_leaf(path, s):
            key = jax.random.fold_in(rng, stable_hash(path))
            name = path.split("/")[-1]
            if "ln" in name and not name.endswith("b"):
                return jnp.ones(s.shape, s.dtype)
            if name.endswith(("lnb", "b_up", "b_down")):
                return jnp.zeros(s.shape, s.dtype)
            scale = 0.02 if name in ("embed", "dec_pos") else None
            return L.dense_init(key, s.shape, s.dtype, scale=scale)

        def walk(prefix, tree):
            if isinstance(tree, dict):
                return {k: walk(f"{prefix}/{k}", v) for k, v in tree.items()}
            return init_leaf(prefix, tree)

        return walk("", specs)

    # ------------------------------------------------------------------ inputs
    def input_specs(self, shape: ShapeSpec):
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        dt = _dt(cfg)
        frames = jax.ShapeDtypeStruct((b, self.enc_seq, cfg.d_model), dt)
        if shape.kind == "train":
            return {"frames": frames,
                    "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if shape.kind == "prefill":
            return {"frames": frames,
                    "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}

    def input_pspecs(self, shape: ShapeSpec):
        dax = self.run.data_axes if shape.global_batch > 1 else None
        if shape.kind == "train":
            return {"frames": P(dax, None, None), "tokens": P(dax, None),
                    "labels": P(dax, None)}
        if shape.kind == "prefill":
            return {"frames": P(dax, None, None), "tokens": P(dax, None)}
        return {"tokens": P(dax, None), "cache_len": P()}

    def cache_specs(self, shape: ShapeSpec):
        cfg = self.cfg
        b, smax = shape.global_batch, shape.seq_len
        hq, hd = cfg.n_heads, cfg.resolved_head_dim
        dt = _dt(cfg)
        Lx = cfg.n_layers
        return {
            "k": jax.ShapeDtypeStruct((Lx, b, smax, hq, hd), dt),
            "v": jax.ShapeDtypeStruct((Lx, b, smax, hq, hd), dt),
            "xk": jax.ShapeDtypeStruct((Lx, b, self.enc_seq, hq, hd), dt),
            "xv": jax.ShapeDtypeStruct((Lx, b, self.enc_seq, hq, hd), dt),
        }

    def cache_pspecs(self, shape: ShapeSpec):
        dax = self.run.data_axes if shape.global_batch > 1 else None
        kv = P(None, dax, None, None, None)
        return {"k": kv, "v": kv, "xk": kv, "xv": kv}

    def init_cache(self, shape: ShapeSpec, batch: Optional[int] = None):
        specs = self.cache_specs(shape)
        b = batch or shape.global_batch
        return {k: jnp.zeros((s.shape[0], b) + s.shape[2:], s.dtype)
                for k, s in specs.items()}

    # ------------------------------------------------------------------ blocks
    def _self_attn(self, w, x, causal, cache_kv=None, cache_len=None,
                   prefix="s_"):
        cfg = self.cfg
        B, S, D = x.shape
        hq, hd = cfg.n_heads, cfg.resolved_head_dim
        h = L.layer_norm(x, w[f"{prefix}ln"], w[f"{prefix}lnb"]).astype(_dt(cfg))
        q = (h @ w[f"{prefix}wq"]).reshape(B, S, hq, hd)
        k = (h @ w[f"{prefix}wk"]).reshape(B, S, hq, hd)
        v = (h @ w[f"{prefix}wv"]).reshape(B, S, hq, hd)
        if cache_kv is None:
            o = L.flash_attention_jnp(q, k, v, causal=causal,
                                      q_chunk=self.run.q_chunk,
                                      kv_chunk=self.run.kv_chunk,
                                      unroll=self.run.attn_unroll)
            new_kv = None
        else:
            ck, cv = cache_kv
            ck = lax.dynamic_update_slice(ck, k, (0, cache_len, 0, 0))
            cv = lax.dynamic_update_slice(cv, v, (0, cache_len, 0, 0))
            o = L.decode_attention_jnp(q, ck, cv, cache_len + 1)
            new_kv = (ck, cv)
        return x + (o.reshape(B, S, hq * hd) @ w[f"{prefix}wo"]), new_kv

    def _cross_attn(self, w, x, enc_kv):
        cfg = self.cfg
        B, S, D = x.shape
        hq, hd = cfg.n_heads, cfg.resolved_head_dim
        h = L.layer_norm(x, w["x_ln"], w["x_lnb"]).astype(_dt(cfg))
        q = (h @ w["x_wq"]).reshape(B, S, hq, hd)
        ek, ev = enc_kv
        o = L.flash_attention_jnp(q, ek, ev, causal=False,
                                  q_chunk=self.run.q_chunk,
                                  kv_chunk=self.run.kv_chunk,
                                  unroll=self.run.attn_unroll)
        return x + (o.reshape(B, S, hq * hd) @ w["x_wo"])

    def _enc_kv(self, w, enc_out):
        cfg = self.cfg
        B, S, D = enc_out.shape
        hq, hd = cfg.n_heads, cfg.resolved_head_dim
        ek = (enc_out @ w["x_wk"]).reshape(B, S, hq, hd)
        ev = (enc_out @ w["x_wv"]).reshape(B, S, hq, hd)
        return ek, ev

    def _mlp(self, w, x):
        h = L.layer_norm(x, w["mln"], w["mlnb"]).astype(_dt(self.cfg))
        return x + L.gelu_mlp(h, w["w_up"], w["b_up"], w["w_down"],
                              w["b_down"])

    def encode(self, params, frames):
        x = frames + self._sinusoid(self.enc_seq, self.cfg.d_model)[None]
        x = constrain(x, P(self.run.data_axes, None, None))

        def body(x, wl):
            x, _ = self._self_attn(wl, x, causal=False)
            x = self._mlp(wl, x)
            x = constrain(x, P(self.run.data_axes, None, None))
            return x, None

        if self.run.layer_mode == "scan":
            x, _ = lax.scan(body, x, params["encoder"])
        else:
            for i in range(self.enc_layers):
                wl = jax.tree.map(lambda a: a[i], params["encoder"])
                x, _ = body(x, wl)
        return L.layer_norm(x, params["enc_final_ln"], params["enc_final_lnb"])

    def _sinusoid(self, S, D):
        pos = jnp.arange(S, dtype=jnp.float32)[:, None]
        dim = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
        ang = pos / jnp.power(10000.0, 2 * dim / D)
        return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                               axis=-1).astype(_dt(self.cfg))

    # ------------------------------------------------------------------ steps
    def forward(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(_dt(cfg))
        x = x + lax.dynamic_slice_in_dim(params["dec_pos"], 0, S, 0)[None]
        x = constrain(x, P(self.run.data_axes, None, None))

        def body(x, wl):
            x, _ = self._self_attn(wl, x, causal=True)
            x = self._cross_attn(wl, x, self._enc_kv(wl, enc_out))
            x = self._mlp(wl, x)
            x = constrain(x, P(self.run.data_axes, None, None))
            return x, None

        block = body
        if self.run.remat:
            block = jax.checkpoint(body)
        if self.run.layer_mode == "scan":
            x, _ = lax.scan(block, x, params["decoder"])
        else:
            for i in range(cfg.n_layers):
                wl = jax.tree.map(lambda a: a[i], params["decoder"])
                x, _ = block(x, wl)
        x = L.layer_norm(x, params["dec_final_ln"], params["dec_final_lnb"])
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])

    def loss_fn(self, params, batch):
        logits = self.forward(params, batch).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                                   axis=-1)[..., 0]
        return jnp.mean(lse - gold)

    def prefill_cross(self, params, frames, cache):
        """Fill the cross-attention K/V cache from encoder output."""
        enc_out = self.encode(params, frames)
        xks, xvs = [], []
        for i in range(self.cfg.n_layers):
            wl = jax.tree.map(lambda a: a[i], params["decoder"])
            ek, ev = self._enc_kv(wl, enc_out)
            xks.append(ek); xvs.append(ev)
        cache = dict(cache)
        cache["xk"] = jnp.stack(xks)
        cache["xv"] = jnp.stack(xvs)
        return cache

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        tokens, cache_len = batch["tokens"], batch["cache_len"]
        B = tokens.shape[0]
        x = jnp.take(params["embed"], tokens, axis=0).astype(_dt(cfg))
        posvec = lax.dynamic_slice_in_dim(params["dec_pos"],
                                          cache_len, 1, 0)[None]
        x = x + posvec

        def body(x, wl_c):
            wl, (ck, cv, xk, xv) = wl_c
            x, (nk, nv) = self._self_attn(wl, x, causal=True,
                                          cache_kv=(ck, cv),
                                          cache_len=cache_len)
            # cross attention against the (precomputed) encoder K/V
            hq, hd = cfg.n_heads, cfg.resolved_head_dim
            h = L.layer_norm(x, wl["x_ln"], wl["x_lnb"]).astype(_dt(cfg))
            q = (h @ wl["x_wq"]).reshape(B, 1, hq, hd)
            o = L.decode_attention_jnp(q, xk, xv,
                                       jnp.array(self.enc_seq, jnp.int32))
            x = x + (o.reshape(B, 1, hq * hd) @ wl["x_wo"])
            x = self._mlp(wl, x)
            return x, (nk, nv)

        caches = (cache["k"], cache["v"], cache["xk"], cache["xv"])
        if self.run.layer_mode == "scan":
            x, (nk, nv) = lax.scan(body, x, (params["decoder"], caches))
        else:
            nks, nvs = [], []
            for i in range(cfg.n_layers):
                wl = jax.tree.map(lambda a: a[i], params["decoder"])
                cs = jax.tree.map(lambda a: a[i], caches)
                x, (k1, v1) = body(x, (wl, cs))
                nks.append(k1); nvs.append(v1)
            nk, nv = jnp.stack(nks), jnp.stack(nvs)
        x = L.layer_norm(x, params["dec_final_ln"], params["dec_final_lnb"])
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])[:, -1]
        new_cache = {"k": nk, "v": nv, "xk": cache["xk"], "xv": cache["xv"]}
        return logits, new_cache
