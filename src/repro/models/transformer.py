"""Decoder-only transformer LM covering the dense / moe / vlm families.

Design notes:
  * params for the repeated blocks are stacked on a leading L axis so the
    layer stack can run as ``lax.scan`` (fast compile) or unrolled (exact
    HLO cost analysis for the dry-run), selected by RunConfig.layer_mode;
  * sharding is expressed as PartitionSpec trees (param_pspecs/input_pspecs)
    consumed by pjit at the launcher level, plus with_sharding_constraint on
    activations;
  * attention: blocked flash (jnp) for train/prefill, masked dense for
    single-token decode; GQA via KV-head repetition *after* cache update so
    the KV cache stays at n_kv_heads;
  * MoE: expert-parallel sorted dispatch under shard_map (see moe.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import layers as L
from repro.models.api import RunConfig
from repro.models.sharding import constrain
from repro.models.moe import moe_ffn, moe_param_specs, moe_param_pspecs, \
    init_moe_params


def _dt(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


class DecoderLM:
    def __init__(self, cfg: ArchConfig, run_cfg: RunConfig):
        self.cfg = cfg
        self.run = run_cfg

    # ------------------------------------------------------------------ params
    def _layer_shapes(self) -> Dict[str, Tuple[tuple, Any]]:
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.resolved_head_dim
        hq, hkv, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
        dt = _dt(cfg)
        shapes = {
            "ln1": ((d,), jnp.float32),
            "ln2": ((d,), jnp.float32),
            "wq": ((d, hq * hd), dt),
            "wk": ((d, hkv * hd), dt),
            "wv": ((d, hkv * hd), dt),
            "wo": ((hq * hd, d), dt),
        }
        if cfg.qk_norm:
            shapes["q_norm"] = ((hd,), jnp.float32)
            shapes["k_norm"] = ((hd,), jnp.float32)
        if cfg.moe is None:
            if cfg.mlp == "swiglu":
                shapes.update({
                    "w_gate": ((d, f), dt),
                    "w_up": ((d, f), dt),
                    "w_down": ((f, d), dt),
                })
            else:
                shapes.update({
                    "w_up": ((d, f), dt),
                    "b_up": ((f,), jnp.float32),
                    "w_down": ((f, d), dt),
                    "b_down": ((d,), jnp.float32),
                })
        return shapes

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        dt = _dt(cfg)
        Lx = cfg.n_layers
        layers = {k: jax.ShapeDtypeStruct((Lx,) + s, d)
                  for k, (s, d) in self._layer_shapes().items()}
        if cfg.moe is not None:
            layers.update(moe_param_specs(cfg, Lx))
        out = {
            "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dt),
            "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32),
            "layers": layers,
        }
        if not cfg.tie_embeddings:
            out["lm_head"] = jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), dt)
        return out

    def param_pspecs(self) -> Dict[str, Any]:
        cfg, m = self.cfg, self.run.model_axis
        layers = {
            "ln1": P(None, None), "ln2": P(None, None),
            "wq": P(None, None, m), "wo": P(None, m, None),
        }
        # KV projections: shard heads on the model axis only when there are
        # enough KV heads; MQA/GQA-with-few-heads replicates KV (cheap).
        kv_spec = P(None, None, m) if cfg.n_kv_heads >= 16 else P(None, None, None)
        layers["wk"] = kv_spec
        layers["wv"] = kv_spec
        if cfg.qk_norm:
            layers["q_norm"] = P(None, None)
            layers["k_norm"] = P(None, None)
        if cfg.moe is None:
            if cfg.mlp == "swiglu":
                layers.update({"w_gate": P(None, None, m),
                               "w_up": P(None, None, m),
                               "w_down": P(None, m, None)})
            else:
                layers.update({"w_up": P(None, None, m), "b_up": P(None, m),
                               "w_down": P(None, m, None),
                               "b_down": P(None, None)})
        else:
            layers.update(moe_param_pspecs(
                cfg, m,
                fsdp_axes=(self.run.data_axes if self.run.fsdp_experts
                           else None)))
        out = {
            "embed": P(m, None),
            "final_norm": P(None),
            "layers": layers,
        }
        if not cfg.tie_embeddings:
            out["lm_head"] = P(None, m)
        return out

    def init_params(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        dt = _dt(cfg)
        keys = jax.random.split(rng, 8)
        Lx = cfg.n_layers
        layers = {}
        for i, (k, (shape, d)) in enumerate(self._layer_shapes().items()):
            if k.startswith("ln") or k.endswith("norm"):
                layers[k] = jnp.ones((Lx,) + shape, d)
            elif k.startswith("b_"):
                layers[k] = jnp.zeros((Lx,) + shape, d)
            else:
                key = jax.random.fold_in(keys[0], i)
                layers[k] = L.dense_init(key, (Lx,) + shape, d)
        if cfg.moe is not None:
            layers.update(init_moe_params(cfg, keys[1], Lx))
        out = {
            "embed": L.dense_init(keys[2], (cfg.vocab, cfg.d_model), dt,
                                  scale=0.02),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "layers": layers,
        }
        if not cfg.tie_embeddings:
            out["lm_head"] = L.dense_init(keys[3], (cfg.d_model, cfg.vocab),
                                          dt)
        return out

    # ------------------------------------------------------------------ inputs
    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        # decode: one new token against a cache of length s
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}

    def input_pspecs(self, shape: ShapeSpec) -> Dict[str, Any]:
        dax = self.run.data_axes if shape.global_batch > 1 else None
        if shape.kind == "train":
            return {"tokens": P(dax, None), "labels": P(dax, None)}
        if shape.kind == "prefill":
            return {"tokens": P(dax, None)}
        return {"tokens": P(dax, None), "cache_len": P()}

    def cache_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        cfg = self.cfg
        b, smax = shape.global_batch, shape.seq_len
        hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        dt = _dt(cfg)
        return {
            "k": jax.ShapeDtypeStruct((cfg.n_layers, b, smax, hkv, hd), dt),
            "v": jax.ShapeDtypeStruct((cfg.n_layers, b, smax, hkv, hd), dt),
        }

    def cache_pspecs(self, shape: ShapeSpec) -> Dict[str, Any]:
        dax = self.run.data_axes if shape.global_batch > 1 else None
        cfg = self.cfg
        m = self.run.model_axis
        if cfg.n_kv_heads >= 16:
            kv = P(None, dax, None, m, None)     # shard KV heads
        else:
            kv = P(None, dax, m, None, None)     # shard cache sequence
        return {"k": kv, "v": kv}

    def init_cache(self, shape: ShapeSpec, batch: Optional[int] = None):
        cfg = self.cfg
        b = batch or shape.global_batch
        hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        dt = _dt(cfg)
        z = jnp.zeros((cfg.n_layers, b, shape.seq_len, hkv, hd), dt)
        return {"k": z, "v": z}

    # ------------------------------------------------------------------ blocks
    def _positions(self, tokens, offset=0):
        b, s = tokens.shape
        if hasattr(offset, "ndim") and getattr(offset, "ndim", 0) == 1:
            offset = offset[:, None]               # per-slot offsets (B, 1)
        pos = jnp.arange(s, dtype=jnp.int32)[None, :] + offset
        pos = jnp.broadcast_to(pos, (b, s))
        if self.cfg.mrope_sections is not None:
            return jnp.stack([pos, pos, pos], axis=-1)   # text-only stream
        return pos

    def _rope(self, x, pos):
        cfg = self.cfg
        if cfg.mrope_sections is not None:
            return L.apply_mrope(x, pos, cfg.mrope_sections, cfg.rope_theta)
        return L.apply_rope(x, pos, cfg.rope_theta)

    def _attn(self, w, x, pos, cache_kv=None, cache_len=None):
        """Returns (attn_out, new_kv) where new_kv is (k, v) for this layer."""
        cfg, run = self.cfg, self.run
        b, s, d = x.shape
        hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        h = L.rms_norm(x, w["ln1"]) if cfg.norm == "rmsnorm" else \
            L.layer_norm(x, w["ln1"], jnp.zeros_like(w["ln1"]))
        q = jnp.einsum("bsd,dh->bsh", h, w["wq"]).reshape(b, s, hq, hd)
        k = jnp.einsum("bsd,dh->bsh", h, w["wk"]).reshape(b, s, hkv, hd)
        v = jnp.einsum("bsd,dh->bsh", h, w["wv"]).reshape(b, s, hkv, hd)
        if cfg.qk_norm:
            q = L.rms_norm(q, w["q_norm"])
            k = L.rms_norm(k, w["k_norm"])
        q = self._rope(q, pos)
        k = self._rope(k, pos)
        if cache_kv is None:
            if run.attn_impl == "reference":
                o = L.attention_reference(q, L.repeat_kv(k, hq // hkv),
                                          L.repeat_kv(v, hq // hkv),
                                          causal=True)
            else:
                o = L.flash_attention_jnp(q, k, v, causal=True,
                                          q_chunk=run.q_chunk,
                                          kv_chunk=run.kv_chunk,
                                          unroll=run.attn_unroll)
            new_kv = (k, v)
        else:
            ck, cv = cache_kv
            if getattr(cache_len, "ndim", 0) == 1:
                # per-slot lengths (continuous batching): scatter each row
                bidx = jnp.arange(b)
                ck = ck.at[bidx, cache_len].set(k[:, 0])
                cv = cv.at[bidx, cache_len].set(v[:, 0])
                o = L.decode_attention_jnp(q, ck, cv, cache_len + 1)
            elif self._use_sharded_decode():
                from repro.models.distributed_attention import \
                    decode_attention_seq_sharded
                o, ck, cv = decode_attention_seq_sharded(
                    q, ck, cv, k, v, cache_len,
                    model_axis=self.run.model_axis,
                    data_axes=self.run.data_axes)
            else:
                ck = lax.dynamic_update_slice(ck, k, (0, cache_len, 0, 0))
                cv = lax.dynamic_update_slice(cv, v, (0, cache_len, 0, 0))
                o = L.decode_attention_jnp(q, ck, cv, cache_len + 1)
            new_kv = (ck, cv)
        o = jnp.einsum("bsh,hd->bsd", o.reshape(b, s, hq * hd), w["wo"])
        return o, new_kv

    def _use_sharded_decode(self) -> bool:
        """HC2: explicit distributed flash-decode when the cache is
        sequence-sharded (few KV heads) and q heads divide the model axis."""
        if not self.run.sharded_decode or self.cfg.n_kv_heads >= 16:
            return False
        from repro.models.sharding import mesh_axis_sizes
        return mesh_axis_sizes().get(self.run.model_axis, 1) > 1

    def _mlp(self, w, x):
        cfg = self.cfg
        h = L.rms_norm(x, w["ln2"]) if cfg.norm == "rmsnorm" else \
            L.layer_norm(x, w["ln2"], jnp.zeros_like(w["ln2"]))
        if cfg.moe is not None:
            return moe_ffn(cfg, self.run, w, h)
        if cfg.mlp == "swiglu":
            return L.swiglu(h, w["w_gate"], w["w_up"], w["w_down"])
        return L.gelu_mlp(h, w["w_up"], w["b_up"], w["w_down"], w["b_down"])

    def _block(self, w, x, pos, cache_kv=None, cache_len=None):
        dax, m = self.run.data_axes, self.run.model_axis
        o, new_kv = self._attn(w, x, pos, cache_kv, cache_len)
        x = x + o
        x = constrain(x, P(dax, None, None))
        x = x + self._mlp(w, x)
        x = constrain(x, P(dax, None, None))
        return x, new_kv

    def _stack(self, params, x, pos, cache=None, cache_len=None):
        """Run the layer stack; returns (x, new_cache or None)."""
        layers = params["layers"]
        block = self._block
        if self.run.remat and cache is None:   # no backward pass in decode
            if self.run.remat_policy == "dots":
                block = jax.checkpoint(
                    block, policy=jax.checkpoint_policies
                    .dots_with_no_batch_dims_saveable)
            else:
                block = jax.checkpoint(block)

        def body(carry, wl):
            x = carry
            if cache is None:
                x, _ = block(wl, x, pos)
                return x, None
            w, (ck, cv) = wl
            x, (nk, nv) = block(w, x, pos, (ck, cv), cache_len)
            return x, (nk, nv)

        if self.run.layer_mode == "scan":
            if cache is None:
                x, _ = lax.scan(body, x, layers)
                return x, None
            x, (nk, nv) = lax.scan(body, x, (layers, (cache["k"], cache["v"])))
            return x, {"k": nk, "v": nv}
        # unrolled
        nks, nvs = [], []
        for i in range(self.cfg.n_layers):
            wl = jax.tree.map(lambda a: a[i], layers)
            if cache is None:
                x, _ = block(wl, x, pos)
            else:
                x, (nk, nv) = block(wl, x, pos,
                                    (cache["k"][i], cache["v"][i]), cache_len)
                nks.append(nk)
                nvs.append(nv)
        if cache is None:
            return x, None
        return x, {"k": jnp.stack(nks), "v": jnp.stack(nvs)}

    # ------------------------------------------------------------------ steps
    def forward(self, params, batch) -> jax.Array:
        """Training/prefill forward -> logits (B, S, V)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(_dt(cfg))
        x = constrain(x, P(self.run.data_axes, None, None))
        pos = self._positions(tokens)
        x, _ = self._stack(params, x, pos)
        x = L.rms_norm(x, params["final_norm"]) if cfg.norm == "rmsnorm" else \
            L.layer_norm(x, params["final_norm"],
                         jnp.zeros_like(params["final_norm"]))
        head = params.get("lm_head")
        if head is None:
            head = params["embed"].T
        return jnp.einsum("bsd,dv->bsv", x, head)

    def loss_fn(self, params, batch) -> jax.Array:
        logits = self.forward(params, batch)
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   axis=-1)[..., 0]
        return jnp.mean(lse - gold)

    def decode_step(self, params, cache, batch):
        """One decode step: batch = {tokens (B,1), cache_len ()} -> logits."""
        cfg = self.cfg
        tokens, cache_len = batch["tokens"], batch["cache_len"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(_dt(cfg))
        pos = self._positions(tokens, offset=cache_len)
        x, new_cache = self._stack(params, x, pos, cache=cache,
                                   cache_len=cache_len)
        x = L.rms_norm(x, params["final_norm"]) if cfg.norm == "rmsnorm" else \
            L.layer_norm(x, params["final_norm"],
                         jnp.zeros_like(params["final_norm"]))
        head = params.get("lm_head")
        if head is None:
            head = params["embed"].T
        logits = jnp.einsum("bsd,dv->bsv", x, head)[:, -1]
        return logits, new_cache
