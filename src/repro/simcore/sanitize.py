"""Runtime determinism sanitizer for the DES engine (Layer 2 of simlint).

Enabled with ``Environment(sanitize=True)`` (or ``REPRO_SANITIZE=1``), the
sanitizer piggybacks on the engine's existing hooks — it schedules no
events, draws no randomness, and touches no simulation state, so event
counts and goldens are identical with sanitize on or off. Three checks:

* **Lock-order cycle detection.** Every ``Resource.acquire`` requested
  while the current process already holds other resources adds edges
  ``held → requested`` to a global acquisition-order graph; ``reserve``
  holds contribute edges the same way. A cycle means two code paths take
  the same locks in opposite orders — the deadlock/inversion class the
  id-sorted quiesce discipline in ``control_plane.py`` exists to prevent —
  and raises :class:`SanitizeError` at the acquire that closed the cycle.

* **Same-instant tie auditing.** Two different processes touching the same
  ``Resource``/``Store`` at the same sim time are ordered only by heap
  insertion seq — exactly the schedule-sensitive races that break replay
  when unrelated code motion reorders event creation. Ties are *recorded*
  (they are common and often benign: FIFO queueing absorbs most), keyed by
  resource and digit-normalized process names, and surfaced via
  :meth:`Sanitizer.report` so a churn cell can assert on unexpected pairs.

* **RNG discipline.** The global ``random`` / ``np.random`` states are
  snapshotted when ``env.run()`` starts and compared when it returns: any
  in-run draw that bypassed the named ``env.rng(<stream>)`` streams is a
  determinism leak (seeded replay would not reproduce it) and raises
  :class:`SanitizeError`.
"""
from __future__ import annotations

import random as _pyrandom
import re
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

_DIGITS = re.compile(r"\d+")


class SanitizeError(RuntimeError):
    """A determinism hazard detected by ``Environment(sanitize=True)``."""


class Sanitizer:
    """Attached as ``env.sanitizer``; every hook is a no-op unless the
    environment was built with ``sanitize=True`` (``env.sanitizer`` is
    ``None`` otherwise, and the engine guards each call site)."""

    TIE_EXAMPLE_CAP = 50

    def __init__(self, env: Any):
        self.env = env
        self.current: Any = None          # process being stepped, set by engine
        # lock-acquisition graph: id(resource) -> set of id(resource)
        self._edges: Dict[int, Set[int]] = {}
        self._labels: Dict[int, str] = {}
        self._label_seq = 0
        # per-process held resources (keyed by id(process); entries are
        # dropped when the list empties or the process ends)
        self._held: Dict[int, List[Any]] = {}
        # tie auditor: id(obj) -> (time, ctx identity, ctx name)
        self._last_touch: Dict[int, Tuple[float, Any, str]] = {}
        self.tie_hazards: Dict[Tuple[str, str, str], int] = {}
        self.tie_examples: List[Tuple[float, str, str, str]] = []
        self.lock_cycles: List[str] = []
        self.rng_violations: List[str] = []
        self._rng_snapshot: Optional[tuple] = None

    # -- labels / contexts --------------------------------------------------

    def _label(self, obj: Any) -> str:
        key = id(obj)
        name = self._labels.get(key)
        if name is None:
            explicit = getattr(obj, "name", None)
            if explicit:
                name = str(explicit)
            else:
                self._label_seq += 1
                name = f"{type(obj).__name__}#{self._label_seq}"
            self._labels[key] = name
        return name

    def _ctx(self) -> Tuple[Any, str]:
        """(identity, display name) of the running context. Plain
        ``schedule_at`` callbacks all collapse into one '<callback>'
        context: callback-vs-process ties are caught, callback-vs-callback
        ties are not (they carry no process identity to distinguish)."""
        p = self.current
        if p is None:
            return None, "<callback>"
        return id(p), p.name

    # -- lock-order graph ---------------------------------------------------

    def _find_path(self, src: int, dst: int) -> Optional[List[int]]:
        """DFS path src → dst in the acquisition graph, or None."""
        stack = [(src, [src])]
        seen = {src}
        while stack:
            node, path = stack.pop()
            if node == dst:
                return path
            for nxt in self._edges.get(node, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append((nxt, path + [nxt]))
        return None

    def _add_edge(self, a: Any, b: Any) -> None:
        ia, ib = id(a), id(b)
        if ia == ib:
            return
        adj = self._edges.setdefault(ia, set())
        if ib in adj:
            return
        back = self._find_path(ib, ia)
        if back is not None:
            # ``back`` is the established order b -> ... -> a; the requested
            # edge a -> b closes the cycle
            chain = " -> ".join(self._labels.get(n, "?") for n in back)
            _, ctx_name = self._ctx()
            msg = (f"lock-order cycle at t={self.env.now:.6f}: {ctx_name} "
                   f"acquires {self._label(b)} while holding "
                   f"{self._label(a)}, but the order "
                   f"{chain} -> {self._label(b)} was already established — "
                   f"acquire in one global (id-sorted) order")
            self.lock_cycles.append(msg)
            raise SanitizeError(msg)
        adj.add(ib)

    # -- engine hooks -------------------------------------------------------

    def on_acquire(self, res: Any) -> None:
        self._label(res)
        self._touch(res)
        ident, _ = self._ctx()
        if ident is None:
            return
        held = self._held.get(ident)
        if held:
            for h in held:
                self._add_edge(h, res)
            held.append(res)
        else:
            self._held[ident] = [res]

    def on_release(self, res: Any) -> None:
        self._touch(res)
        ident, _ = self._ctx()
        held = self._held.get(ident)
        if held is not None:
            try:
                held.remove(res)
            except ValueError:
                pass
            if not held:
                del self._held[ident]

    def on_reserve(self, res: Any) -> None:
        """A granted lazy hold: orders after whatever the caller holds, but
        is not itself tracked as held (it has no owning process)."""
        self._label(res)
        self._touch(res)
        ident, _ = self._ctx()
        if ident is not None:
            for h in self._held.get(ident, ()):
                self._add_edge(h, res)

    def on_store(self, store: Any) -> None:
        self._touch(store)

    def on_process_end(self, proc: Any) -> None:
        self._held.pop(id(proc), None)

    # -- tie auditor --------------------------------------------------------

    def _touch(self, obj: Any) -> None:
        t = self.env.now
        ident, name = self._ctx()
        key = id(obj)
        last = self._last_touch.get(key)
        self._last_touch[key] = (t, ident, name)
        if last is not None and last[0] == t and last[1] != ident:
            label = self._label(obj)
            pair = tuple(sorted((_DIGITS.sub("#", last[2]),
                                 _DIGITS.sub("#", name))))
            k = (label, pair[0], pair[1])
            self.tie_hazards[k] = self.tie_hazards.get(k, 0) + 1
            if len(self.tie_examples) < self.TIE_EXAMPLE_CAP:
                self.tie_examples.append((t, label, last[2], name))

    # -- RNG discipline -----------------------------------------------------

    @staticmethod
    def _rng_state() -> tuple:
        py = _pyrandom.getstate()
        kind, keys, pos, has_gauss, cached = np.random.get_state()
        return (py, kind, keys.tobytes(), pos, has_gauss, cached)

    def begin_run(self) -> None:
        self._rng_snapshot = self._rng_state()

    def end_run(self) -> None:
        snap, self._rng_snapshot = self._rng_snapshot, None
        if snap is None:
            return
        if self._rng_state() != snap:
            msg = (f"global RNG state changed during run (observed at "
                   f"t={self.env.now:.6f}): some code drew from the global "
                   f"random/np.random state instead of a named "
                   f"env.rng(<stream>) — seeded replay will not reproduce it")
            self.rng_violations.append(msg)
            raise SanitizeError(msg)

    # -- reporting ----------------------------------------------------------

    def report(self) -> Dict[str, Any]:
        """Summary for tests/CI: counts, not objects, so it prints cleanly."""
        return {
            "lock_edges": sum(len(v) for v in self._edges.values()),
            "lock_cycles": list(self.lock_cycles),
            "tie_hazards": {f"{r} :: {a} <> {b}": n
                            for (r, a, b), n in sorted(self.tie_hazards.items())},
            "tie_example_count": len(self.tie_examples),
            "rng_violations": list(self.rng_violations),
        }
