"""Discrete-event simulation engine (simpy-lite).

A minimal, deterministic, generator-based DES used to run the Dirigent and
Knative/K8s cluster-manager models in virtual time. Design goals:

  * determinism — a single event heap ordered by (time, seq); all randomness
    flows through named ``RngStream``s so runs are exactly reproducible;
  * generator processes — components are written as ``def proc(env): yield
    env.timeout(x)`` coroutines, like simpy;
  * tiny surface — Timeout, Event, Store (FIFO queue), Resource (counting
    semaphore), process interrupt/kill; nothing else is needed;
  * a cheap hot path — the engine itself must not be the bottleneck when a
    5000-worker cluster model is simulated (benchmarks/churn_scale.py tracks
    ``events_per_wall_s``). Hot-path events schedule *bound methods*, never
    per-event lambda closures; ``Process``/``Timeout``/``AnyOf`` carry
    ``__slots__``; and a process that is the sole waiter of a Timeout is
    resumed directly from the timer callback without touching the generic
    callback list (``Timeout._waiter``).

Besides events, the engine offers two zero-event modeling devices used by
the demand-driven timers in core/:

  * ``Environment.schedule_at`` — run a plain callback at an absolute sim
    time (one heap entry, no Process/Timeout objects), and
  * ``Resource.reserve`` — a *lazy hold*: take a slot for a known interval
    without any heap traffic unless a contender actually shows up, in which
    case the release materializes as a real event at the exact instant the
    modeled holder would have released (FIFO semantics preserved).

The same component code can also run in "live" mode (see core/cluster.py):
live mode never yields timeouts for modeled service times, it executes real
work instead.
"""
from __future__ import annotations

import heapq
import itertools
import math
import os
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Generator, Optional

import numpy as np


class Interrupt(Exception):
    """Raised inside a process when another process interrupts it."""

    def __init__(self, cause: Any = None):
        super().__init__(cause)
        self.cause = cause


class Event:
    """One-shot event; processes wait on it by yielding it.

    Lifecycle: *triggered* means the firing has been scheduled; *fired* means
    callbacks have run (waiters registered after firing are called at once).
    """

    __slots__ = ("env", "_value", "_ok", "triggered", "fired", "callbacks")

    def __init__(self, env: "Environment"):
        self.env = env
        self._value: Any = None
        self._ok: bool = True
        self.triggered = False
        self.fired = False
        self.callbacks: list[Callable[["Event"], None]] = []

    @property
    def ok(self) -> bool:
        """False iff the event has failed. With ``any_of`` the loser's
        exception arrives as the *value*; check the winner's ``ok`` before
        trusting it."""
        return self._ok

    def succeed(self, value: Any = None) -> "Event":
        if self.triggered:
            raise RuntimeError("event already triggered")
        self.triggered = True
        self._value = value
        self._ok = True
        self.env._schedule(self.env.now, self._run_callbacks)
        return self

    def fail(self, exc: BaseException) -> "Event":
        if self.triggered:
            raise RuntimeError("event already triggered")
        self.triggered = True
        self._value = exc
        self._ok = False
        self.env._schedule(self.env.now, self._run_callbacks)
        return self

    def add_callback(self, cb: Callable[["Event"], None]) -> None:
        """Register a waiter; if the event already fired, call it next turn."""
        if self.fired:
            self.env._schedule(self.env.now, lambda: cb(self))
        else:
            self.callbacks.append(cb)

    def _run_callbacks(self) -> None:
        self.fired = True
        cbs, self.callbacks = self.callbacks, []
        if not self._ok and not cbs:
            # Unobserved process failure: surface it instead of swallowing.
            raise self._value
        for cb in cbs:
            cb(self)


class Timeout(Event):
    """Fires after ``delay``. The overwhelmingly common waiter is a single
    Process (``yield env.timeout(x)``): that case is fast-pathed through the
    ``_waiter`` slot — the timer callback resumes the process directly,
    skipping callback-list append/swap/iterate entirely."""

    __slots__ = ("_waiter",)

    def __init__(self, env: "Environment", delay: float, value: Any = None,
                 at: Optional[float] = None):
        """Relative by default; pass ``at`` (absolute sim time) to fire at an
        exact precomputed instant — ``env.now + (t - env.now)`` does not
        round-trip in floating point, so timers that must hit a deadline
        bit-exactly (the heartbeat wheel) cannot go through a delay."""
        super().__init__(env)
        if at is None:
            if delay < 0:
                raise ValueError(f"negative delay {delay}")
            at = env.now + delay
        elif at < env.now:
            raise ValueError(f"timeout into the past: {at} < {env.now}")
        self._value = value
        self._waiter: Optional["Process"] = None
        env._schedule(at, self._trigger_now)

    def _trigger_now(self) -> None:
        self.triggered = True
        w = self._waiter
        if w is not None:
            self._waiter = None
            if not self.callbacks:
                # sole-waiter fast path: resume the process in-line
                self.fired = True
                if w._target is self:       # not interrupted/killed meanwhile
                    w._target = None
                    w._resume(self._value, True)
                return
            # callbacks were added after the sole waiter registered (rare):
            # fall back to the generic path, waiter first (registration order)
            self.callbacks.insert(0, w._on_target)
        self._run_callbacks()


class Process(Event):
    """A running generator. Also an Event that triggers when it returns."""

    __slots__ = ("gen", "name", "_target", "_alive")

    def __init__(self, env: "Environment", gen: Generator, name: str = "?"):
        super().__init__(env)
        self.gen = gen
        self.name = name
        self._target: Optional[Event] = None
        self._alive = True
        env._schedule(env.now, self._start)

    @property
    def is_alive(self) -> bool:
        return self._alive

    def _start(self) -> None:
        self._resume(None, True)

    def _detach_target(self) -> None:
        """Stop waiting on the current target (interrupt/kill)."""
        target, self._target = self._target, None
        if target is not None and not target.triggered:
            if type(target) is Timeout and target._waiter is self:
                target._waiter = None
            else:
                try:
                    target.callbacks.remove(self._on_target)
                except ValueError:
                    pass

    def interrupt(self, cause: Any = None) -> None:
        """Interrupt the process (throws Interrupt at its current yield)."""
        if not self._alive:
            return
        # Detach from whatever it is waiting on, then resume with an error.
        self._detach_target()
        self.env._schedule(self.env.now, lambda: self._throw(Interrupt(cause)))

    def kill(self) -> None:
        """Terminate the process without running any more of its body."""
        if not self._alive:
            return
        self._alive = False
        self._detach_target()
        san = self.env.sanitizer
        if san is None:
            self.gen.close()
        else:
            # GeneratorExit unwinds finally blocks that may release locks:
            # they must be attributed to this process
            san.current = self
            try:
                self.gen.close()
            finally:
                san.current = None
                san.on_process_end(self)
        if not self.triggered:
            self.succeed(None)

    # -- internal ---------------------------------------------------------
    def _on_target(self, evt: Event) -> None:
        if self._target is not evt:
            return  # stale wake-up (we were interrupted/killed meanwhile)
        self._target = None
        self._resume(evt._value, evt._ok)

    def _throw(self, exc: BaseException) -> None:
        if not self._alive:
            return
        san = self.env.sanitizer
        if san is not None:
            san.current = self
        try:
            nxt = self.gen.throw(exc)
        except StopIteration as stop:
            self._finish(stop.value)
            return
        except Interrupt:
            # Process chose not to catch the interrupt: dies quietly.
            self._finish(None)
            return
        except BaseException as e:  # noqa: BLE001 — simpy semantics
            self._fail(e)
            return
        finally:
            if san is not None:
                san.current = None
        self._wait_on(nxt)

    def _resume(self, value: Any, ok: bool) -> None:
        if not self._alive:
            return
        san = self.env.sanitizer
        if san is None:
            # hot path, untouched when sanitize is off
            try:
                nxt = self.gen.send(value) if ok else self.gen.throw(value)
            except StopIteration as stop:
                self._finish(stop.value)
                return
            except BaseException as e:  # noqa: BLE001 — simpy semantics
                self._fail(e)
                return
            self._wait_on(nxt)
            return
        san.current = self
        try:
            nxt = self.gen.send(value) if ok else self.gen.throw(value)
        except StopIteration as stop:
            self._finish(stop.value)
            return
        except BaseException as e:  # noqa: BLE001 — simpy semantics
            self._fail(e)
            return
        finally:
            san.current = None
        self._wait_on(nxt)

    def _wait_on(self, evt: Any) -> None:
        if type(evt) is Timeout:
            # sole-waiter fast path: a fresh `yield env.timeout(x)` — by far
            # the hottest wait in any simulation — skips the callback list
            if evt._waiter is None and not evt.fired and not evt.callbacks:
                self._target = evt
                evt._waiter = self
                return
        elif not isinstance(evt, Event):
            raise TypeError(f"process {self.name} yielded non-event {evt!r}")
        self._target = evt
        evt.add_callback(self._on_target)

    def _finish(self, value: Any) -> None:
        self._alive = False
        san = self.env.sanitizer
        if san is not None:
            san.on_process_end(self)
        if not self.triggered:
            self.succeed(value)

    def _fail(self, exc: BaseException) -> None:
        """Process raised: fail our event. A waiting parent gets the exception
        thrown at its yield; an unobserved failure crashes the event loop."""
        self._alive = False
        san = self.env.sanitizer
        if san is not None:
            san.on_process_end(self)
        if not self.triggered:
            self.fail(exc)


def _observed(evt: "Event") -> None:
    """Shared no-op observer left on a detached any_of loser: failures of a
    raced-and-lost event stay *observed* (not re-raised into the event loop),
    exactly as when the dead AnyOf closure was still attached."""


class AnyOf(Event):
    """Triggers when the first of ``events`` triggers; value = (index, value).

    When the winner fires, the callbacks registered on the still-pending
    *losers* are detached. Without that, a long-lived event that repeatedly
    loses ``any_of`` races (e.g. a completion event raced against retry
    timeouts in a loop) accumulates one dead closure per race for the rest of
    its life — a genuine memory/CPU leak in long simulations. A loser left
    with no other waiter gets the shared ``_observed`` sentinel (at most one,
    ever), keeping the pre-detach failure semantics without the per-race
    closure."""

    __slots__ = ("_done", "_waits")

    def __init__(self, env: "Environment", events: list[Event]):
        super().__init__(env)
        self._done = False
        self._waits: list[tuple[Event, Callable[[Event], None]]] = []
        for i, e in enumerate(events):
            cb = self._make_cb(i)
            self._waits.append((e, cb))
            e.add_callback(cb)

    def _make_cb(self, i: int) -> Callable[[Event], None]:
        def cb(evt: Event) -> None:
            self._fire(i, evt._value)
        return cb

    def _fire(self, i: int, value: Any) -> None:
        if self._done or self.triggered:
            return
        self._done = True
        # detach loser callbacks: an event that never fires must not keep a
        # reference to this (finished) AnyOf via its callback list. Losers
        # already triggered will fire on their own; their callback finds
        # ``_done`` set and is a no-op.
        for e, cb in self._waits:
            if not e.triggered and not e.fired:
                try:
                    e.callbacks.remove(cb)
                except ValueError:
                    pass
                if not e.callbacks:
                    e.callbacks.append(_observed)
        self._waits = []
        self.succeed((i, value))


class Store:
    """Unbounded FIFO queue with blocking get()."""

    def __init__(self, env: "Environment", name: Optional[str] = None):
        self.env = env
        self.name = name
        self.items: Deque[Any] = deque()
        self._getters: Deque[Event] = deque()

    def put(self, item: Any) -> None:
        san = self.env.sanitizer
        if san is not None:
            san.on_store(self)
        if self._getters:
            evt = self._getters.popleft()
            evt.succeed(item)
        else:
            self.items.append(item)

    def get(self) -> Event:
        san = self.env.sanitizer
        if san is not None:
            san.on_store(self)
        evt = Event(self.env)
        if self.items:
            evt.succeed(self.items.popleft())
        else:
            self._getters.append(evt)
        return evt

    def __len__(self) -> int:
        return len(self.items)


class Resource:
    """Counting semaphore; models a contended resource (CPU, lock, ports).

    ``reserve(until)`` is a *lazy hold*: it takes a slot synchronously for a
    known interval without scheduling anything. If nobody contends before
    ``until``, the hold is reclaimed in-place by the next acquire/reserve —
    zero heap events for the whole critical section. The first contender
    *materializes* the release as a real scheduled event at exactly
    ``until``, so queueing (who waits, until when, in what order) is
    indistinguishable from a process that acquired, held a timeout and
    released. This is what lets the C9 heartbeat lock touches cost no events
    unless they actually collide with a creation (core/control_plane.py)."""

    __slots__ = ("env", "capacity", "in_use", "name", "_waiters",
                 "_reserved_until")

    def __init__(self, env: "Environment", capacity: int = 1,
                 name: Optional[str] = None):
        self.env = env
        self.capacity = capacity
        self.in_use = 0
        self.name = name
        self._waiters: Deque[Event] = deque()
        self._reserved_until: Optional[float] = None

    def _settle_reservation(self) -> None:
        """Resolve an outstanding lazy hold: reclaim it if it expired, or
        materialize its release event if it is still running (a contender is
        about to queue behind it)."""
        r = self._reserved_until
        if r is None:
            return
        self._reserved_until = None
        if self.env.now >= r:
            self.in_use -= 1        # the phantom holder released in the past
        else:
            self.env._schedule(r, self.release)

    def reserve(self, until: float) -> bool:
        """Lazily hold one slot until sim time ``until`` (see class doc).
        Returns False when the resource is busy or waited on — the caller
        must then fall back to the normal acquire/timeout/release path."""
        if self._reserved_until is not None:
            if self.env.now >= self._reserved_until:
                self.in_use -= 1
                self._reserved_until = None
            else:
                return False        # an earlier lazy hold is still running
        if self.in_use < self.capacity and not self._waiters:
            san = self.env.sanitizer
            if san is not None:
                san.on_reserve(self)
            self.in_use += 1
            self._reserved_until = until
            return True
        return False

    def acquire(self) -> Event:
        self._settle_reservation()
        san = self.env.sanitizer
        if san is not None:
            san.on_acquire(self)
        evt = Event(self.env)
        if self.in_use < self.capacity:
            self.in_use += 1
            evt.succeed(None)
        else:
            self._waiters.append(evt)
        return evt

    def release(self) -> None:
        san = self.env.sanitizer
        if san is not None:
            san.on_release(self)
        if self._waiters:
            evt = self._waiters.popleft()
            evt.succeed(None)
        else:
            self.in_use -= 1
            if self.in_use < 0:
                raise RuntimeError("release without acquire")

    @property
    def queue_len(self) -> int:
        return len(self._waiters)


@dataclass
class RngStream:
    """Named deterministic random stream."""

    rng: np.random.Generator

    def expovariate(self, rate: float) -> float:
        return float(self.rng.exponential(1.0 / rate))

    def lognormal(self, median: float, sigma: float) -> float:
        return float(median * np.exp(self.rng.normal(0.0, sigma)))

    def uniform(self, lo: float, hi: float) -> float:
        return float(self.rng.uniform(lo, hi))

    def randint(self, lo: int, hi: int) -> int:
        return int(self.rng.integers(lo, hi))

    def choice(self, n: int) -> int:
        return int(self.rng.integers(0, n))

    def random(self) -> float:
        return float(self.rng.random())


def stable_hash(name: str) -> int:
    """Process-independent string hash (builtin ``hash`` is salted per
    process and must never feed simulation state)."""
    return zlib.crc32(name.encode()) & 0x7FFFFFFF


def grid_ceil(x: float, quantum: float) -> float:
    """Smallest multiple of ``quantum`` that is ``>= x``.

    Deadline quantization for cohort scheduling (the heartbeat wheel's
    ``hb_cohort_quantum``): timers rounded UP onto one shared grid collapse
    into cohorts that pop in a single heap event. With a power-of-two
    quantum (e.g. ``0.0078125 == 2**-7``) both the division and the final
    multiply are exact float operations, so grid points accumulated as
    ``t + k*period`` (period itself a multiple of the quantum) stay ON the
    grid bit-exactly — cohorts never drift apart."""
    return math.ceil(x / quantum) * quantum


class Environment:
    """The event loop. Time is float seconds.

    ``sanitize=True`` (or env var ``REPRO_SANITIZE=1``) attaches a runtime
    determinism sanitizer — lock-order cycle detection, same-instant tie
    auditing, global-RNG discipline (see simcore/sanitize.py). The
    sanitizer observes through hooks that are dead branches when off and
    schedules no events when on, so event counts are bit-identical either
    way."""

    def __init__(self, seed: int = 0, sanitize: Optional[bool] = None):
        self.now: float = 0.0
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._next_seq = itertools.count().__next__
        self._seed = seed
        self._streams: dict[str, RngStream] = {}
        self.events_processed = 0   # wall-clock throughput accounting
        if sanitize is None:
            sanitize = os.environ.get("REPRO_SANITIZE", "") not in ("", "0")
        if sanitize:
            from .sanitize import Sanitizer
            self.sanitizer: Optional["Sanitizer"] = Sanitizer(self)
        else:
            self.sanitizer = None

    # -- rng ---------------------------------------------------------------
    def rng(self, name: str) -> RngStream:
        if name not in self._streams:
            # independent child stream per name, derived from the seed via a
            # stable hash — builtin hash() is salted per process
            # (PYTHONHASHSEED), which silently broke cross-process
            # reproducibility of every benchmark
            ss = np.random.SeedSequence(self._seed)
            child = np.random.SeedSequence(
                entropy=ss.entropy, spawn_key=(stable_hash(name),))
            self._streams[name] = RngStream(np.random.default_rng(child))
        return self._streams[name]

    # -- primitives ---------------------------------------------------------
    def timeout(self, delay: float, value: Any = None) -> Timeout:
        return Timeout(self, delay, value)

    def timeout_at(self, t: float, value: Any = None) -> Timeout:
        """A timeout firing at *absolute* sim time ``t`` (bit-exact)."""
        return Timeout(self, 0.0, value, at=t)

    def event(self) -> Event:
        return Event(self)

    def any_of(self, events: list[Event]) -> AnyOf:
        return AnyOf(self, events)

    def store(self, name: Optional[str] = None) -> Store:
        return Store(self, name)

    def resource(self, capacity: int = 1,
                 name: Optional[str] = None) -> Resource:
        return Resource(self, capacity, name)

    def process(self, gen: Generator, name: str = "?") -> Process:
        return Process(self, gen, name)

    # -- loop ---------------------------------------------------------------
    def _schedule(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (t, self._next_seq(), fn))

    def schedule_at(self, t: float, fn: Callable[[], None]) -> None:
        """Run a plain callback at absolute sim time ``t`` (>= now).

        The cheapest way to model a timer: one heap entry, no Process or
        Timeout objects. Used by demand-driven background machinery (netcfg
        refills, lazy lock releases) whose per-firing work is plain state
        mutation, not a coroutine."""
        if t < self.now:
            raise ValueError(f"schedule_at into the past: {t} < {self.now}")
        heapq.heappush(self._heap, (t, self._next_seq(), fn))

    def run(self, until: Optional[float] = None) -> None:
        san = self.sanitizer
        if san is not None:
            san.begin_run()
        # localized loop: heap/pop bound once; the count is folded back in a
        # finally so events_processed stays correct when a callback raises
        heap = self._heap
        pop = heapq.heappop
        n = 0
        try:
            while heap:
                if until is not None and heap[0][0] > until:
                    self.now = until
                    if san is not None:
                        san.end_run()
                    return
                item = pop(heap)
                self.now = item[0]
                n += 1
                item[2]()
            if until is not None:
                self.now = until
        finally:
            self.events_processed += n
        if san is not None:
            san.end_run()

    def run_until_event(self, evt: Event, hard_limit: float = 1e12) -> Any:
        san = self.sanitizer
        if san is not None:
            san.begin_run()
        heap = self._heap
        pop = heapq.heappop
        n = 0
        try:
            while not evt.fired:
                if not heap:
                    break
                item = pop(heap)
                if item[0] > hard_limit:
                    raise RuntimeError("run_until_event exceeded hard limit")
                self.now = item[0]
                n += 1
                item[2]()
        finally:
            self.events_processed += n
        if san is not None:
            san.end_run()
        if not evt.fired:
            raise RuntimeError("event never triggered")
        return evt._value
