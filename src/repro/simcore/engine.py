"""Discrete-event simulation engine (simpy-lite).

A minimal, deterministic, generator-based DES used to run the Dirigent and
Knative/K8s cluster-manager models in virtual time. Design goals:

  * determinism — a single event heap ordered by (time, seq); all randomness
    flows through named ``RngStream``s so runs are exactly reproducible;
  * generator processes — components are written as ``def proc(env): yield
    env.timeout(x)`` coroutines, like simpy;
  * tiny surface — Timeout, Event, Store (FIFO queue), Resource (counting
    semaphore), process interrupt/kill; nothing else is needed.

The same component code can also run in "live" mode (see core/cluster.py):
live mode never yields timeouts for modeled service times, it executes real
work instead.
"""
from __future__ import annotations

import heapq
import itertools
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Generator, Optional

import numpy as np


class Interrupt(Exception):
    """Raised inside a process when another process interrupts it."""

    def __init__(self, cause: Any = None):
        super().__init__(cause)
        self.cause = cause


class Event:
    """One-shot event; processes wait on it by yielding it.

    Lifecycle: *triggered* means the firing has been scheduled; *fired* means
    callbacks have run (waiters registered after firing are called at once).
    """

    __slots__ = ("env", "_value", "_ok", "triggered", "fired", "callbacks")

    def __init__(self, env: "Environment"):
        self.env = env
        self._value: Any = None
        self._ok: bool = True
        self.triggered = False
        self.fired = False
        self.callbacks: list[Callable[["Event"], None]] = []

    @property
    def ok(self) -> bool:
        """False iff the event has failed. With ``any_of`` the loser's
        exception arrives as the *value*; check the winner's ``ok`` before
        trusting it."""
        return self._ok

    def succeed(self, value: Any = None) -> "Event":
        if self.triggered:
            raise RuntimeError("event already triggered")
        self.triggered = True
        self._value = value
        self._ok = True
        self.env._schedule(self.env.now, self._run_callbacks)
        return self

    def fail(self, exc: BaseException) -> "Event":
        if self.triggered:
            raise RuntimeError("event already triggered")
        self.triggered = True
        self._value = exc
        self._ok = False
        self.env._schedule(self.env.now, self._run_callbacks)
        return self

    def add_callback(self, cb: Callable[["Event"], None]) -> None:
        """Register a waiter; if the event already fired, call it next turn."""
        if self.fired:
            self.env._schedule(self.env.now, lambda: cb(self))
        else:
            self.callbacks.append(cb)

    def _run_callbacks(self) -> None:
        self.fired = True
        cbs, self.callbacks = self.callbacks, []
        if not self._ok and not cbs:
            # Unobserved process failure: surface it instead of swallowing.
            raise self._value
        for cb in cbs:
            cb(self)


class Timeout(Event):
    def __init__(self, env: "Environment", delay: float, value: Any = None):
        super().__init__(env)
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        self._value = value
        env._schedule(env.now + delay, self._trigger_now)

    def _trigger_now(self) -> None:
        self.triggered = True
        self._run_callbacks()


class Process(Event):
    """A running generator. Also an Event that triggers when it returns."""

    def __init__(self, env: "Environment", gen: Generator, name: str = "?"):
        super().__init__(env)
        self.gen = gen
        self.name = name
        self._target: Optional[Event] = None
        self._alive = True
        env._schedule(env.now, lambda: self._resume(None, True))

    @property
    def is_alive(self) -> bool:
        return self._alive

    def interrupt(self, cause: Any = None) -> None:
        """Interrupt the process (throws Interrupt at its current yield)."""
        if not self._alive:
            return
        # Detach from whatever it is waiting on, then resume with an error.
        target, self._target = self._target, None
        if target is not None and not target.triggered:
            try:
                target.callbacks.remove(self._on_target)
            except ValueError:
                pass
        self.env._schedule(self.env.now, lambda: self._throw(Interrupt(cause)))

    def kill(self) -> None:
        """Terminate the process without running any more of its body."""
        if not self._alive:
            return
        self._alive = False
        target, self._target = self._target, None
        if target is not None and not target.triggered:
            try:
                target.callbacks.remove(self._on_target)
            except ValueError:
                pass
        self.gen.close()
        if not self.triggered:
            self.succeed(None)

    # -- internal ---------------------------------------------------------
    def _on_target(self, evt: Event) -> None:
        if self._target is not evt:
            return  # stale wake-up (we were interrupted/killed meanwhile)
        self._target = None
        self._resume(evt._value, evt._ok)

    def _throw(self, exc: BaseException) -> None:
        if not self._alive:
            return
        try:
            nxt = self.gen.throw(exc)
        except StopIteration as stop:
            self._finish(stop.value)
            return
        except Interrupt:
            # Process chose not to catch the interrupt: dies quietly.
            self._finish(None)
            return
        except BaseException as e:  # noqa: BLE001 — simpy semantics
            self._fail(e)
            return
        self._wait_on(nxt)

    def _resume(self, value: Any, ok: bool) -> None:
        if not self._alive:
            return
        try:
            nxt = self.gen.send(value) if ok else self.gen.throw(value)
        except StopIteration as stop:
            self._finish(stop.value)
            return
        except BaseException as e:  # noqa: BLE001 — simpy semantics
            self._fail(e)
            return
        self._wait_on(nxt)

    def _wait_on(self, evt: Any) -> None:
        if not isinstance(evt, Event):
            raise TypeError(f"process {self.name} yielded non-event {evt!r}")
        self._target = evt
        evt.add_callback(self._on_target)

    def _finish(self, value: Any) -> None:
        self._alive = False
        if not self.triggered:
            self.succeed(value)

    def _fail(self, exc: BaseException) -> None:
        """Process raised: fail our event. A waiting parent gets the exception
        thrown at its yield; an unobserved failure crashes the event loop."""
        self._alive = False
        if not self.triggered:
            self.fail(exc)


class AnyOf(Event):
    """Triggers when the first of ``events`` triggers; value = (index, value)."""

    def __init__(self, env: "Environment", events: list[Event]):
        super().__init__(env)
        self._done = False
        for i, e in enumerate(events):
            e.add_callback(self._make_cb(i))

    def _make_cb(self, i: int) -> Callable[[Event], None]:
        def cb(evt: Event) -> None:
            self._fire(i, evt._value)
        return cb

    def _fire(self, i: int, value: Any) -> None:
        if self._done or self.triggered:
            return
        self._done = True
        self.succeed((i, value))


class Store:
    """Unbounded FIFO queue with blocking get()."""

    def __init__(self, env: "Environment"):
        self.env = env
        self.items: Deque[Any] = deque()
        self._getters: Deque[Event] = deque()

    def put(self, item: Any) -> None:
        if self._getters:
            evt = self._getters.popleft()
            evt.succeed(item)
        else:
            self.items.append(item)

    def get(self) -> Event:
        evt = Event(self.env)
        if self.items:
            evt.succeed(self.items.popleft())
        else:
            self._getters.append(evt)
        return evt

    def __len__(self) -> int:
        return len(self.items)


class Resource:
    """Counting semaphore; models a contended resource (CPU, lock, ports)."""

    def __init__(self, env: "Environment", capacity: int = 1):
        self.env = env
        self.capacity = capacity
        self.in_use = 0
        self._waiters: Deque[Event] = deque()

    def acquire(self) -> Event:
        evt = Event(self.env)
        if self.in_use < self.capacity:
            self.in_use += 1
            evt.succeed(None)
        else:
            self._waiters.append(evt)
        return evt

    def release(self) -> None:
        if self._waiters:
            evt = self._waiters.popleft()
            evt.succeed(None)
        else:
            self.in_use -= 1
            if self.in_use < 0:
                raise RuntimeError("release without acquire")

    @property
    def queue_len(self) -> int:
        return len(self._waiters)


@dataclass
class RngStream:
    """Named deterministic random stream."""

    rng: np.random.Generator

    def expovariate(self, rate: float) -> float:
        return float(self.rng.exponential(1.0 / rate))

    def lognormal(self, median: float, sigma: float) -> float:
        return float(median * np.exp(self.rng.normal(0.0, sigma)))

    def uniform(self, lo: float, hi: float) -> float:
        return float(self.rng.uniform(lo, hi))

    def randint(self, lo: int, hi: int) -> int:
        return int(self.rng.integers(lo, hi))

    def choice(self, n: int) -> int:
        return int(self.rng.integers(0, n))

    def random(self) -> float:
        return float(self.rng.random())


def stable_hash(name: str) -> int:
    """Process-independent string hash (builtin ``hash`` is salted per
    process and must never feed simulation state)."""
    return zlib.crc32(name.encode()) & 0x7FFFFFFF


class Environment:
    """The event loop. Time is float seconds."""

    def __init__(self, seed: int = 0):
        self.now: float = 0.0
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self._seed = seed
        self._streams: dict[str, RngStream] = {}
        self.events_processed = 0   # wall-clock throughput accounting

    # -- rng ---------------------------------------------------------------
    def rng(self, name: str) -> RngStream:
        if name not in self._streams:
            # independent child stream per name, derived from the seed via a
            # stable hash — builtin hash() is salted per process
            # (PYTHONHASHSEED), which silently broke cross-process
            # reproducibility of every benchmark
            ss = np.random.SeedSequence(self._seed)
            child = np.random.SeedSequence(
                entropy=ss.entropy, spawn_key=(stable_hash(name),))
            self._streams[name] = RngStream(np.random.default_rng(child))
        return self._streams[name]

    # -- primitives ---------------------------------------------------------
    def timeout(self, delay: float, value: Any = None) -> Timeout:
        return Timeout(self, delay, value)

    def event(self) -> Event:
        return Event(self)

    def any_of(self, events: list[Event]) -> AnyOf:
        return AnyOf(self, events)

    def store(self) -> Store:
        return Store(self)

    def resource(self, capacity: int = 1) -> Resource:
        return Resource(self, capacity)

    def process(self, gen: Generator, name: str = "?") -> Process:
        return Process(self, gen, name)

    # -- loop ---------------------------------------------------------------
    def _schedule(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def run(self, until: Optional[float] = None) -> None:
        while self._heap:
            t, _, fn = self._heap[0]
            if until is not None and t > until:
                self.now = until
                return
            heapq.heappop(self._heap)
            self.now = t
            self.events_processed += 1
            fn()
        if until is not None:
            self.now = until

    def run_until_event(self, evt: Event, hard_limit: float = 1e12) -> Any:
        while not evt.fired:
            if not self._heap:
                break
            t, _, fn = heapq.heappop(self._heap)
            if t > hard_limit:
                raise RuntimeError("run_until_event exceeded hard limit")
            self.now = t
            self.events_processed += 1
            fn()
        if not evt.fired:
            raise RuntimeError("event never triggered")
        return evt._value
