from repro.simcore.engine import (
    AnyOf,
    Environment,
    Event,
    Interrupt,
    Process,
    Resource,
    RngStream,
    Store,
    Timeout,
)

__all__ = [
    "AnyOf",
    "Environment",
    "Event",
    "Interrupt",
    "Process",
    "Resource",
    "RngStream",
    "Store",
    "Timeout",
]
