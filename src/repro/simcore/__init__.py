from repro.simcore.engine import (
    AnyOf,
    Environment,
    Event,
    Interrupt,
    Process,
    Resource,
    RngStream,
    Store,
    Timeout,
    stable_hash,
)

__all__ = [
    "AnyOf",
    "Environment",
    "Event",
    "Interrupt",
    "Process",
    "Resource",
    "RngStream",
    "Store",
    "Timeout",
    "stable_hash",
]
