from repro.simcore.engine import (
    AnyOf,
    Environment,
    Event,
    Interrupt,
    Process,
    Resource,
    RngStream,
    Store,
    Timeout,
    grid_ceil,
    stable_hash,
)
from repro.simcore.sanitize import SanitizeError, Sanitizer

__all__ = [
    "AnyOf",
    "Environment",
    "Event",
    "Interrupt",
    "Process",
    "Resource",
    "RngStream",
    "SanitizeError",
    "Sanitizer",
    "Store",
    "Timeout",
    "grid_ceil",
    "stable_hash",
]
