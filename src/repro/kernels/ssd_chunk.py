"""Pallas TPU kernel: chunk-parallel Mamba-2 SSD scan (zamba2 mixer).

Grid: (batch, ssm_heads, n_chunks), chunk innermost; the (P x N) state lives
in VMEM scratch across chunks. Scalar-per-head decay makes the within-chunk
form a masked (C x C) matmul (``scores = (C B^T) * decay``) plus two (C x P/N)
GEMMs — MXU-shaped when C, P, N are multiples of the native tile.
Semantics == ref.ssd_ref (kernel tests sweep shapes/dtypes in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
                y_ref, hout_ref, state_scr, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)          # (C, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (C, 1)
    A = a_ref[0]                                  # scalar decay coef
    Bm = b_ref[0, 0].astype(jnp.float32)         # (C, N)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (C, N)
    D = d_ref[0]                                  # scalar

    la = dt[:, 0] * A                            # (C,) log decay
    cum = jnp.cumsum(la)                         # cum_i (inclusive)
    cum_last = cum[-1]

    xdt = x * dt                                  # (C, P)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (C, C)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.clip(cum[:, None] - cum[None, :], -60.0, 0.0))
    scores = jnp.where(jj <= ii, scores * decay, 0.0)

    h_prev = state_scr[...]                      # (P, N)
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())))   # (C, P)
    q_dec = Cm * jnp.exp(cum)[:, None]
    y = y + jax.lax.dot_general(q_dec, h_prev, (((1,), (1,)), ((), ())))
    y = y + D * x
    y_ref[0, 0] = y.astype(y_ref.dtype)

    k_rem = Bm * jnp.exp(cum_last - cum)[:, None]
    h_new = (jnp.exp(cum_last) * h_prev
             + jax.lax.dot_general(xdt, k_rem, (((0,), (0,)), ((), ()))))
    state_scr[...] = h_new

    @pl.when(ic == n_chunks - 1)
    def _final():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, dt, A, B, C, D, initial_state=None, chunk: int = 64,
               interpret: bool = False):
    """Same semantics as ref.ssd_ref. x: (b,S,H,P); dt: (b,S,H);
    A,D: (H,); B,C: (b,S,H,N)."""
    b, S, H, Pd = x.shape
    N = B.shape[-1]
    assert S % chunk == 0
    n_chunks = S // chunk
    if initial_state is None:
        initial_state = jnp.zeros((b, H, Pd, N), jnp.float32)

    xt = x.transpose(0, 2, 1, 3)                 # (b, H, S, P)
    dtt = dt.transpose(0, 2, 1)[..., None]       # (b, H, S, 1)
    Bt = B.transpose(0, 2, 1, 3)
    Ct = C.transpose(0, 2, 1, 3)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=(b, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, Pd), lambda b_, h, ic: (b_, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b_, h, ic: (b_, h, ic, 0)),
            pl.BlockSpec((1,), lambda b_, h, ic: (h,)),
            pl.BlockSpec((1, 1, chunk, N), lambda b_, h, ic: (b_, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b_, h, ic: (b_, h, ic, 0)),
            pl.BlockSpec((1,), lambda b_, h, ic: (h,)),
            pl.BlockSpec((1, 1, Pd, N), lambda b_, h, ic: (b_, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, Pd), lambda b_, h, ic: (b_, h, ic, 0)),
            pl.BlockSpec((1, 1, Pd, N), lambda b_, h, ic: (b_, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, H, S, Pd), jnp.float32),
            jax.ShapeDtypeStruct((b, H, Pd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Pd, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A, Bt, Ct, D, initial_state)
    return y.transpose(0, 2, 1, 3), h_fin
