"""Pallas TPU kernel: flash-decode — one query token vs a long KV cache.

Grid: (batch, q_heads, n_kv_blocks); the kv dimension is innermost and
carries running (m, l, acc) in VMEM scratch — the classic split-KV decode
kernel, with the cache-length mask applied per block. The GQA index map
reads each KV block once per query head group without materializing
repeated KV (the cache stays at Hkv width in HBM; blocks stream into VMEM).

For v5e: pick block_kv as a multiple of 128; the (1, d) query row is small —
the kernel is memory-bound by design (one cache pass), which is exactly the
regime the roofline analysis shows for decode shapes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_kv: int, n_kv: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cache_len = len_ref[0]
    # skip blocks entirely beyond the cache length
    @pl.when(ik * block_kv < cache_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (1,bkv)
        kpos = ik * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_kv), 1)
        s = jnp.where(kpos < cache_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_scr[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-20)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, cache_len,
                            block_kv: int = 512, interpret: bool = False):
    """q: (B, 1, Hq, D); caches: (B, Smax, Hkv, D); cache_len: () int32."""
    b, _, hq, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    block_kv = min(block_kv, smax)
    assert smax % block_kv == 0
    n_kv = smax // block_kv
    scale = 1.0 / math.sqrt(d)

    qt = q.transpose(0, 2, 1, 3)                 # (B, Hq, 1, D)
    kt = k_cache.transpose(0, 2, 1, 3)           # (B, Hkv, Smax, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    lens = jnp.broadcast_to(jnp.reshape(cache_len, (1,)), (1,)).astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, scale=scale,
                               block_kv=block_kv, n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, n_kv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, d), lambda b_, h, ik: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h, ik: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h, ik: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda b_, h, ik: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
