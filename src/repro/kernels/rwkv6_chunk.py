"""Pallas TPU kernel: chunk-parallel RWKV6 (WKV) recurrence.

Grid: (batch, heads, n_chunks) with the chunk dimension innermost; the
(dk x dv) state matrix lives in VMEM scratch and carries across chunk
iterations — HBM traffic is one pass over r/k/v/w plus the y output, while
the within-chunk math is dense (C x C and C x d matmuls on the MXU), i.e.
the same matmul form as kernels/chunked.wkv6_chunked (the jnp oracle-adjacent
implementation); ref.wkv6_ref is the semantic ground truth.

Stability contract is shared with chunked.py: |log w| * C < ~80 (the models
clamp log-decay; default C=64..128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                state_scr, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)          # (C, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)          # (C, dv)
    w = w_ref[0, 0].astype(jnp.float32)          # (C, dk) decay in (0,1)
    u = u_ref[0].astype(jnp.float32)             # (dk,)

    logw = jnp.log(w)
    cum = jnp.cumsum(logw, axis=0)               # cum_{i+1}
    cum_in = cum - logw                          # cum_i
    cum_last = cum[-1:, :]                       # (1, dk)

    q_dec = r * jnp.exp(cum_in)
    k_dec = k * jnp.exp(-cum)
    k_rem = k * jnp.exp(cum_last - cum)

    scores = jax.lax.dot_general(q_dec, k_dec, (((1,), (1,)), ((), ())))
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(jj < ii, scores, 0.0)     # strict lower triangle
    bonus = jnp.sum(r * (u[None, :] * k), axis=-1)   # (C,)
    scores = scores + jnp.where(jj == ii, bonus[:, None], 0.0)

    S = state_scr[...]                           # (dk, dv)
    y = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())))
    y = y + jax.lax.dot_general(q_dec, S, (((1,), (0,)), ((), ())))
    y_ref[0, 0] = y.astype(y_ref.dtype)

    S_new = (jnp.exp(cum_last).T * S
             + jax.lax.dot_general(k_rem, v, (((0,), (0,)), ((), ()))))
    state_scr[...] = S_new

    @pl.when(ic == n_chunks - 1)
    def _final():
        sout_ref[0, 0] = S_new.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w, u, initial_state=None, chunk: int = 64,
                interpret: bool = False):
    """Same semantics as ref.wkv6_ref. r,k,w: (B,S,H,dk); v: (B,S,H,dv)."""
    B, S, H, dk = r.shape
    dv = v.shape[-1]
    assert S % chunk == 0
    n_chunks = S // chunk
    if initial_state is None:
        initial_state = jnp.zeros((B, H, dk, dv), jnp.float32)

    # (B, H, S, d) layout for chunk-blocked access
    rt, kt, wt = (x.transpose(0, 2, 1, 3) for x in (r, k, w))
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dk), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, dk), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, dv), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, dk), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, dk), lambda b, h, ic: (h, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, dv), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, dv), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u, initial_state)
    return y.transpose(0, 2, 1, 3), s_fin
