"""Pure-jnp oracles for every kernel in this package.

These are the semantic ground truth: naive recurrences / O(S^2) attention,
written for clarity not speed. Kernel tests assert the Pallas kernels and the
chunked jnp forms match these to float tolerance across shape/dtype sweeps.

Shapes follow the (B, S, H, D) convention used by the models.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax


# -- attention oracles (delegate to models.layers, the single source) --------

def flash_attention_ref(q, k, v, causal: bool = True, q_offset: int = 0):
    from repro.models.layers import attention_reference
    return attention_reference(q, k, v, causal=causal, q_offset=q_offset)


def decode_attention_ref(q, k_cache, v_cache, cache_len):
    from repro.models.layers import decode_attention_jnp
    return decode_attention_jnp(q, k_cache, v_cache, cache_len)


# -- RWKV6 (Finch) WKV recurrence ---------------------------------------------
#
# Per head, with r_t, k_t, w_t in R^dk, v_t in R^dv, bonus u in R^dk and
# state S in R^{dk x dv}:
#     y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)        (readout + bonus)
#     S_t = diag(w_t) S_{t-1} + k_t v_t^T              (data-dependent decay)

def wkv6_ref(r, k, v, w, u, initial_state=None):
    """r,k,w: (B,S,H,dk); v: (B,S,H,dv); u: (H,dk);
    initial_state: (B,H,dk,dv) or None. Returns (y, final_state)."""
    B, S, H, dk = r.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    u = u.astype(f32)
    S0 = (jnp.zeros((B, H, dk, dv), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(S, inputs):
        rt, kt, vt, wt = inputs           # (B,H,dk) / (B,H,dv)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,dk,dv)
        y = jnp.einsum("bhk,bhkv->bhv", rt,
                       S + u[None, :, :, None] * kv)
        S_new = wt[..., :, None] * S + kv
        return S_new, y

    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    S_fin, ys = lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3), S_fin     # (B,S,H,dv), (B,H,dk,dv)


# -- Mamba-2 SSD recurrence ------------------------------------------------------
#
# Per head, scalar decay a_t = exp(dt_t * A) (A < 0), input x_t in R^P,
# B_t, C_t in R^N, state h in R^{P x N}:
#     h_t = a_t h_{t-1} + (dt_t x_t) B_t^T
#     y_t = h_t C_t + D x_t

def ssd_ref(x, dt, A, B, C, D, initial_state=None):
    """x: (b,S,H,P); dt: (b,S,H); A: (H,); B,C: (b,S,H,N); D: (H,).
    Returns (y, final_state (b,H,P,N))."""
    b, S, H, Pd = x.shape
    N = B.shape[-1]
    f32 = jnp.float32
    x, dt, B, C = (t.astype(f32) for t in (x, dt, B, C))
    A = A.astype(f32)
    D = D.astype(f32)
    h0 = (jnp.zeros((b, H, Pd, N), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(h, inputs):
        xt, dtt, Bt, Ct = inputs          # (b,H,P), (b,H), (b,H,N), (b,H,N)
        a = jnp.exp(dtt * A[None, :])     # (b,H)
        upd = (dtt[..., None] * xt)[..., :, None] * Bt[..., None, :]
        h_new = a[..., None, None] * h + upd
        y = jnp.einsum("bhpn,bhn->bhp", h_new, Ct) + D[None, :, None] * xt
        return h_new, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          B.transpose(1, 0, 2, 3), C.transpose(1, 0, 2, 3))
    h_fin, ys = lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), h_fin
