"""Public kernel entry points with implementation dispatch.

``impl``:
  * "jnp"              — blocked/chunked pure-jnp forms (portable; used by the
                         dry-run HLO and CPU execution);
  * "pallas"           — Pallas TPU kernels (deployment target);
  * "pallas_interpret" — Pallas kernels executed by the interpreter (CPU
                         correctness testing of the TPU kernel bodies);
  * "reference"        — naive oracles from ref.py (tests only).
  * "auto"             — pallas on TPU backends, jnp elsewhere.
"""
from __future__ import annotations

import jax

from repro.kernels import chunked, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rwkv6_chunk import wkv6_pallas
from repro.kernels.ssd_chunk import ssd_pallas


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def flash_attention(q, k, v, causal=True, q_chunk=512, kv_chunk=1024,
                    q_offset=0, impl="auto", unroll=False):
    impl = _resolve(impl)
    if impl == "jnp":
        from repro.models.layers import flash_attention_jnp
        return flash_attention_jnp(q, k, v, causal=causal, q_chunk=q_chunk,
                                   kv_chunk=kv_chunk, q_offset=q_offset,
                                   unroll=unroll)
    if impl in ("pallas", "pallas_interpret"):
        return flash_attention_pallas(q, k, v, causal=causal,
                                      block_q=min(q_chunk, 128),
                                      block_kv=min(kv_chunk, 128),
                                      q_offset=q_offset,
                                      interpret=(impl == "pallas_interpret"))
    if impl == "reference":
        from repro.models.layers import repeat_kv
        g = q.shape[2] // k.shape[2]
        return ref.flash_attention_ref(q, repeat_kv(k, g), repeat_kv(v, g),
                                       causal=causal, q_offset=q_offset)
    raise ValueError(impl)


def decode_attention(q, k_cache, v_cache, cache_len, impl="auto",
                     block_kv=512):
    impl = _resolve(impl)
    if impl == "jnp":
        from repro.models.layers import decode_attention_jnp
        return decode_attention_jnp(q, k_cache, v_cache, cache_len)
    if impl in ("pallas", "pallas_interpret"):
        return decode_attention_pallas(
            q, k_cache, v_cache, cache_len, block_kv=block_kv,
            interpret=(impl == "pallas_interpret"))
    if impl == "reference":
        return ref.decode_attention_ref(q, k_cache, v_cache, cache_len)
    raise ValueError(impl)


def wkv6(r, k, v, w, u, initial_state=None, chunk=64, impl="auto",
         unroll=False):
    impl = _resolve(impl)
    if impl == "jnp":
        return chunked.wkv6_chunked(r, k, v, w, u, initial_state, chunk=chunk,
                                    unroll=unroll)
    if impl in ("pallas", "pallas_interpret"):
        return wkv6_pallas(r, k, v, w, u, initial_state, chunk=chunk,
                           interpret=(impl == "pallas_interpret"))
    if impl == "reference":
        return ref.wkv6_ref(r, k, v, w, u, initial_state)
    raise ValueError(impl)


def ssd(x, dt, A, B, C, D, initial_state=None, chunk=64, impl="auto",
        unroll=False):
    impl = _resolve(impl)
    if impl == "jnp":
        return chunked.ssd_chunked(x, dt, A, B, C, D, initial_state,
                                   chunk=chunk, unroll=unroll)
    if impl in ("pallas", "pallas_interpret"):
        return ssd_pallas(x, dt, A, B, C, D, initial_state, chunk=chunk,
                          interpret=(impl == "pallas_interpret"))
    if impl == "reference":
        return ref.ssd_ref(x, dt, A, B, C, D, initial_state)
    raise ValueError(impl)
