"""Pallas TPU kernel: blocked causal GQA flash attention (prefill/train fwd).

Grid: (batch, q_heads, n_q_blocks, n_kv_blocks) — the kv dimension is the
innermost (sequential per core), carrying the online-softmax running state
(m, l, acc) in VMEM scratch. Block shapes are BlockSpec-tiled so the working
set (q block, kv block, acc) lives in VMEM; for the MXU, pick block_q/block_kv
as multiples of 128 and head_dim a multiple of 128 (v5e native tiling).
Causal blocks entirely above the diagonal are skipped with ``pl.when``.

GQA: the KV block index map divides the query-head index by the group size,
so KV is never replicated in memory.

Validated in ``interpret=True`` mode against ``ref.flash_attention_ref``
(tests/test_kernels.py sweeps shapes/dtypes); TPU is the deployment target.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, block_q: int, block_kv: int, n_kv: int,
                 causal: bool, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # kv block strictly above the causal diagonal contributes nothing
        first_q = iq * block_q + q_offset
        run = ik * block_kv <= first_q + block_q - 1

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            qpos = (iq * block_q + q_offset
                    + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0))
            kpos = (ik * block_kv
                    + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1))
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-20)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "q_offset", "interpret"))
def flash_attention_pallas(q, k, v, causal: bool = True, block_q: int = 128,
                           block_kv: int = 128, q_offset: int = 0,
                           interpret: bool = False):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0, \
        "pad sequences to block multiples"
    n_q = sq // block_q
    n_kv = skv // block_kv
    scale = 1.0 / math.sqrt(d)

    qt = q.transpose(0, 2, 1, 3)       # (B, Hq, Sq, D)
    kt = k.transpose(0, 2, 1, 3)       # (B, Hkv, Skv, D)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        n_kv=n_kv, causal=causal, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h, iq, ik: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h, iq, ik: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
