"""Chunk-parallel (matmul-form) implementations of the recurrences.

These are the compute-efficient forms the models use for training/prefill:
within a chunk of length C the recurrence is evaluated as dense matmuls
(MXU-friendly), with an exact state carry between chunks — mathematically
identical to the token-by-token recurrence (kernel tests assert allclose
against ref.py).

Derivation (WKV6; cum_i = sum_{l<i} log w_l, so cum_0 = 0):
    intra:  y_i += sum_{j<i} (r_i . (k_j * exp(cum_i - cum_{j+1}))) v_j
    bonus:  y_i += (r_i . (u * k_i)) v_i
    inter:  y_i += (r_i * exp(cum_i)) @ S_prev
    state:  S_new = diag(exp(cum_C)) S_prev
                  + sum_j (k_j * exp(cum_C - cum_{j+1}))^T v_j

Numerical-stability contract: |log w| * chunk_len must stay well under the
fp32 exp overflow (~88). The models clamp log w to [-0.5, -1e-4] and use
chunk_len <= 128, giving a worst-case exponent of 64 — safe in fp32.
The SSD decay is scalar-per-head with the same structure.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


def _split_chunks(x, C):
    B, S = x.shape[0], x.shape[1]
    n = S // C
    return x.reshape((B, n, C) + x.shape[2:])


@partial(jax.jit, static_argnames=("chunk", "unroll"))
def wkv6_chunked(r, k, v, w, u, initial_state=None, chunk: int = 64,
                 unroll: bool = False):
    """Same signature/semantics as ref.wkv6_ref. S must be divisible by chunk."""
    B, S, H, dk = r.shape
    dv = v.shape[-1]
    assert S % chunk == 0, f"seq {S} not divisible by chunk {chunk}"
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    u = u.astype(f32)
    S0 = (jnp.zeros((B, H, dk, dv), f32) if initial_state is None
          else initial_state.astype(f32))

    rc = _split_chunks(r, chunk)      # (B, n, C, H, dk)
    kc = _split_chunks(k, chunk)
    vc = _split_chunks(v, chunk)
    wc = _split_chunks(w, chunk)

    mask = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1)   # strict lower (j<i)

    def chunk_step(S_prev, inputs):
        rr, kk, vv, ww = inputs           # (B, C, H, dk|dv)
        logw = jnp.log(ww)                # (B, C, H, dk)
        cum = jnp.cumsum(logw, axis=1)    # cum_{i+1} = sum_{l<=i}
        cum_in = cum - logw               # cum_i   = sum_{l<i}
        cum_last = cum[:, -1:, :, :]      # cum_C

        q_dec = rr * jnp.exp(cum_in)                     # r_i * exp(cum_i)
        k_dec = kk * jnp.exp(-cum)                       # k_j * exp(-cum_{j+1})
        k_rem = kk * jnp.exp(cum_last - cum)             # for the state update

        # intra-chunk: scores_ij = q_dec_i . k_dec_j  (== r.k * exp(cum_i - cum_{j+1}))
        scores = jnp.einsum("bihk,bjhk->bhij", q_dec, k_dec)
        scores = scores * mask[None, None]
        bonus = jnp.einsum("bihk,bihk->bhi", rr, u[None, None] * kk)
        scores = scores + jnp.zeros_like(scores).at[
            ..., jnp.arange(chunk), jnp.arange(chunk)].add(bonus)
        y = jnp.einsum("bhij,bjhv->bihv", scores, vv)
        # inter-chunk
        y = y + jnp.einsum("bihk,bhkv->bihv", q_dec, S_prev)
        # state carry
        S_new = jnp.exp(cum_last)[:, 0, :, :, None] * S_prev \
            + jnp.einsum("bjhk,bjhv->bhkv", k_rem, vv)
        return S_new, y

    xs = (rc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), wc.transpose(1, 0, 2, 3, 4))
    S_fin, ys = lax.scan(chunk_step, S0, xs, unroll=unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dv)
    return y, S_fin


def wkv6_decode(r, k, v, w, u, state):
    """Single-token step: r,k,v,w: (B,1,H,d); state: (B,H,dk,dv)."""
    f32 = jnp.float32
    rt = r[:, 0].astype(f32)
    kt = k[:, 0].astype(f32)
    vt = v[:, 0].astype(f32)
    wt = w[:, 0].astype(f32)
    kv = kt[..., :, None] * vt[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", rt,
                   state.astype(f32) + u.astype(f32)[None, :, :, None] * kv)
    S_new = wt[..., :, None] * state.astype(f32) + kv
    return y[:, None], S_new


@partial(jax.jit, static_argnames=("chunk", "unroll"))
def ssd_chunked(x, dt, A, B, C, D, initial_state=None, chunk: int = 64,
                unroll: bool = False):
    """Mamba-2 SSD, chunked matmul form. Same semantics as ref.ssd_ref."""
    b, S, H, Pd = x.shape
    N = B.shape[-1]
    assert S % chunk == 0
    f32 = jnp.float32
    x, dt, B, C = (t.astype(f32) for t in (x, dt, B, C))
    A = A.astype(f32)
    D = D.astype(f32)
    h0 = (jnp.zeros((b, H, Pd, N), f32) if initial_state is None
          else initial_state.astype(f32))

    xc = _split_chunks(x, chunk)         # (b, n, C, H, P)
    dtc = _split_chunks(dt, chunk)       # (b, n, C, H)
    Bc = _split_chunks(B, chunk)
    Cc = _split_chunks(C, chunk)

    mask = jnp.tril(jnp.ones((chunk, chunk), f32))        # j <= i (post-update)

    def chunk_step(h_prev, inputs):
        xx, dd, BB, CC = inputs          # (b,C,H,P), (b,C,H), (b,C,H,N) x2
        la = dd * A[None, None, :]       # log a_t  (b,C,H)
        cum = jnp.cumsum(la, axis=1)     # cum_{i} = sum_{l<=i} log a_l
        cum_last = cum[:, -1:, :]

        xdt = xx * dd[..., None]         # dt_j x_j
        # intra: y_i = sum_{j<=i} exp(cum_i - cum_j) (C_i . B_j) (dt_j x_j)
        decay = jnp.exp(jnp.clip(cum[:, :, None, :] - cum[:, None, :, :],
                                 -60.0, 0.0))             # (b,i,j,H)
        scores = jnp.einsum("bihn,bjhn->bijh", CC, BB) * decay \
            * mask[None, :, :, None]
        y = jnp.einsum("bijh,bjhp->bihp", scores, xdt)
        # inter: exp(cum_i) C_i . h_prev
        q_dec = CC * jnp.exp(cum)[..., None]
        y = y + jnp.einsum("bihn,bhpn->bihp", q_dec, h_prev)
        y = y + D[None, None, :, None] * xx
        # state carry: h_new = exp(cum_C) h_prev + sum_j exp(cum_C - cum_j) (dt_j x_j) B_j^T
        k_rem = BB * jnp.exp(cum_last - cum)[..., None]
        h_new = jnp.exp(cum_last)[:, 0, :, None, None] * h_prev \
            + jnp.einsum("bjhp,bjhn->bhpn", xdt, k_rem)
        return h_new, y

    xs = (xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
          Bc.transpose(1, 0, 2, 3, 4), Cc.transpose(1, 0, 2, 3, 4))
    h_fin, ys = lax.scan(chunk_step, h0, xs, unroll=unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, S, H, Pd)
    return y, h_fin


def ssd_decode(x, dt, A, B, C, D, state):
    """Single-token SSD step. x: (b,1,H,P); state: (b,H,P,N)."""
    f32 = jnp.float32
    xt, dtt = x[:, 0].astype(f32), dt[:, 0].astype(f32)
    Bt, Ct = B[:, 0].astype(f32), C[:, 0].astype(f32)
    a = jnp.exp(dtt * A.astype(f32)[None, :])
    upd = (dtt[..., None] * xt)[..., :, None] * Bt[..., None, :]
    h_new = a[..., None, None] * state.astype(f32) + upd
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ct) \
        + D.astype(f32)[None, :, None] * xt
    return y[:, None], h_new
