"""Static determinism analysis for the DES control plane (``simlint``).

The simulator's headline property — bit-identical replay from a seed,
pinned by exact event budgets and goldens — survives only as long as no
code path consults process-varying state (builtin ``hash``, wall clocks,
the global RNG) or iterates hash-ordered containers on a scheduling path.
This package holds the AST-visitor rules behind ``tools/simlint.py``; the
rule catalog with rationale lives in ``docs/determinism.md``.
"""
from .lint import (DEFAULT_PATHS, Finding, lint_file, lint_paths,
                   lint_source, main)
from .rules import RULES

__all__ = ["DEFAULT_PATHS", "Finding", "RULES", "lint_file", "lint_paths",
           "lint_source", "main"]
