"""simlint driver: parse, run rules, apply inline suppressions.

Suppression syntax (documented in docs/determinism.md):

    x = hash(name) % 4          # simlint: ok(builtin-hash): <justification>
    # simlint: ok(held-lock-timeout): modeled hold window, released below
    yield env.timeout(hold)

A trailing comment covers its own line; a comment alone on a line covers
the next line. Several rules may be listed: ``ok(rule-a, rule-b)``. Every
suppression must actually suppress something — one that matches no finding
is itself reported as ``stale-suppression``, so stale annotations cannot
accumulate as the code under them changes.
"""
from __future__ import annotations

import argparse
import os
import re
import sys
from dataclasses import dataclass
from typing import Iterable, List, Set

from . import rules as _rules

DEFAULT_PATHS = ("src/repro/core", "src/repro/simcore")

_SUPPRESS_RE = re.compile(r"#\s*simlint:\s*ok\(([^)]*)\)(?::\s*(\S.*))?")


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class _Suppression:
    line: int               # line the comment sits on
    covers: Set[int]        # lines it applies to
    rules: Set[str]
    used: bool = False


def _collect_suppressions(source: str, path: str) -> List[_Suppression]:
    out: List[_Suppression] = []
    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if m is None:
            continue
        names = {r.strip() for r in m.group(1).split(",") if r.strip()}
        covers = {i + 1} if text.lstrip().startswith("#") else {i}
        out.append(_Suppression(line=i, covers=covers, rules=names))
    return out


def lint_source(source: str, path: str = "<string>") -> List[Finding]:
    import ast
    tree = ast.parse(source, filename=path)
    raw = _rules.all_raw_findings(tree, source)
    supps = _collect_suppressions(source, path)
    findings: List[Finding] = []
    for line, rule, message in raw:
        sup = next((s for s in supps
                    if line in s.covers and rule in s.rules), None)
        if sup is not None:
            sup.used = True
            continue
        findings.append(Finding(path, line, rule, message))
    for sup in supps:
        unknown = sup.rules - set(_rules.RULE_NAMES)
        if unknown:
            findings.append(Finding(
                path, sup.line, "stale-suppression",
                f"unknown rule name(s) {sorted(unknown)} in suppression"))
        elif not sup.used:
            findings.append(Finding(
                path, sup.line, "stale-suppression",
                f"suppression ok({', '.join(sorted(sup.rules))}) matches no "
                f"finding — the code it excused has changed; delete it"))
    findings.sort(key=lambda f: (f.line, f.rule))
    return findings


def lint_file(path: str) -> List[Finding]:
    with open(path, encoding="utf-8") as fh:
        return lint_source(fh.read(), path)


def _py_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                out.extend(os.path.join(root, n)
                           for n in names if n.endswith(".py"))
        elif p.endswith(".py"):
            out.append(p)
        else:
            print(f"warning: skipping non-python argument {p!r}",
                  file=sys.stderr)
    return sorted(out)


def lint_paths(paths: Iterable[str]) -> List[Finding]:
    findings: List[Finding] = []
    for f in _py_files(paths):
        findings.extend(lint_file(f))
    return findings


def main(argv: List[str] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="simlint",
        description="determinism lint for the DES control plane "
                    "(rule catalog: docs/determinism.md)")
    parser.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                        help="files or directories to lint "
                             f"(default: {' '.join(DEFAULT_PATHS)})")
    parser.add_argument("--list-rules", action="store_true",
                        help="print rule names and exit")
    args = parser.parse_args(argv)
    if args.list_rules:
        for name in _rules.RULE_NAMES:
            print(name)
        return 0
    findings = lint_paths(args.paths)
    for f in findings:
        print(f)
    n_files = len(_py_files(args.paths))
    print(f"simlint: checked {n_files} files, {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
