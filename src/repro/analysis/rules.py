"""AST lint rules enforcing the simulator's determinism contract.

Each rule is a callable ``(tree, source) -> [(line, rule_name, message)]``
registered in ``RULES``. The rules are deliberately *lexical*: they reason
about one module at a time with no imports resolved and no type inference
beyond local assignment tracking. That keeps them fast, dependency-free and
predictable — a finding always points at the exact expression that needs a
``sorted(...)`` wrap, a named ``RngStream``, or a justified
``# simlint: ok(<rule>)`` suppression (see docs/determinism.md).

Rule summary:

* ``builtin-hash``      — builtin ``hash()`` is salted per process
                          (PYTHONHASHSEED); use ``simcore.stable_hash``.
* ``wall-clock``        — ``time.time``/``perf_counter``/``datetime.now``
                          never feed simulated state; sim time is ``env.now``.
* ``global-rng``        — draws on the process-global ``random`` /
                          ``np.random`` state bypass named ``RngStream``s.
* ``set-iteration``     — iterating a ``set`` observes hash order (salted
                          for str, insertion-history-dependent for int)
                          unless wrapped in ``sorted(...)``.
* ``dict-iteration``    — ``.keys()/.values()/.items()`` iteration inside
                          order-sensitive functions (place/steal/rebalance/
                          split/merge/migrate/recover/pick/victim) must be
                          ``sorted(...)`` or justified as insertion-
                          deterministic via a suppression.
* ``lock-order``        — consecutive ``yield <x>.<lock>.acquire()`` in one
                          function must derive from an id-``sorted``
                          sequence (the quiesce discipline of
                          ``_migrate_functions``/``_split_function``).
* ``held-lock-timeout`` — ``yield env.timeout(...)`` while a ``*lock*``
                          resource is held is a modeled hold window and must
                          be annotated with a suppression that justifies it.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

RawFinding = Tuple[int, str, str]

# -- shared helpers -----------------------------------------------------------

_WALL_CLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "datetime.now", "datetime.utcnow", "datetime.today", "date.today",
}

# construction/inspection of RNG machinery is fine; *draws* and global
# seeding are not
_NP_RANDOM_OK = {
    "default_rng", "SeedSequence", "Generator", "RandomState",
    "BitGenerator", "PCG64", "Philox", "MT19937", "get_state",
}
_PY_RANDOM_OK = {"Random", "SystemRandom", "getstate"}

# callables whose result does not depend on argument iteration order
# (``sorted``/``min``/``max`` only without ``key=``: ties under a key
# function are resolved by input order)
_ORDER_INSENSITIVE = {"sorted", "len", "any", "all", "set", "frozenset",
                      "min", "max"}
_ITERATING_SINKS = {"list", "tuple", "iter", "enumerate", "reversed"}

_ORDER_SENSITIVE_FN = re.compile(
    r"place|steal|rebalance|pick|victim|split|merge|migrat|recover")

_LOCKISH = re.compile(r"lock", re.IGNORECASE)


def _dotted(node: ast.AST) -> Optional[str]:
    """Best-effort dotted repr of an expression: ``self.env.timeout`` →
    ``"self.env.timeout"``, subscripts become ``[]``, calls ``()``."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    if isinstance(node, ast.Subscript):
        base = _dotted(node.value)
        return f"{base}[]" if base else None
    if isinstance(node, ast.Call):
        base = _dotted(node.func)
        return f"{base}()" if base else None
    return None


def _call_name(node: ast.Call) -> Optional[str]:
    return _dotted(node.func)


def _annotate_parents(tree: ast.AST) -> None:
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._simlint_parent = parent  # type: ignore[attr-defined]


def _parent(node: ast.AST) -> Optional[ast.AST]:
    return getattr(node, "_simlint_parent", None)


def _is_sorted_call(node: ast.AST) -> bool:
    """``sorted(...)`` with no ``key=`` (ties under a key keep input order)."""
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "sorted"
            and not any(kw.arg == "key" for kw in node.keywords))


# -- rule: builtin-hash -------------------------------------------------------

def rule_builtin_hash(tree: ast.AST, source: str) -> List[RawFinding]:
    out = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "hash"):
            out.append((node.lineno, "builtin-hash",
                        "builtin hash() is salted per process "
                        "(PYTHONHASHSEED) and must never feed simulation "
                        "state — use simcore.stable_hash"))
    return out


# -- rule: wall-clock ---------------------------------------------------------

def rule_wall_clock(tree: ast.AST, source: str) -> List[RawFinding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name is None:
            continue
        if name in _WALL_CLOCK or any(name.endswith("." + w)
                                      for w in _WALL_CLOCK):
            out.append((node.lineno, "wall-clock",
                        f"wall-clock call {name}() — simulated state must "
                        f"only observe env.now"))
    return out


# -- rule: global-rng ---------------------------------------------------------

def rule_global_rng(tree: ast.AST, source: str) -> List[RawFinding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name is None or "." not in name:
            continue
        parts = name.split(".")
        if parts[0] == "random" and len(parts) >= 2 \
                and parts[1] not in _PY_RANDOM_OK:
            out.append((node.lineno, "global-rng",
                        f"{name}() uses the process-global random state — "
                        f"draw through a named env.rng(<stream>) instead"))
        elif len(parts) >= 3 and parts[0] in ("np", "numpy") \
                and parts[1] == "random" and parts[2] not in _NP_RANDOM_OK:
            out.append((node.lineno, "global-rng",
                        f"{name}() draws from numpy's global RNG — draw "
                        f"through a named env.rng(<stream>) instead"))
    return out


# -- rule: set-iteration / dict-iteration -------------------------------------

class _SetFacts(ast.NodeVisitor):
    """Collect names statically known to hold sets.

    Attribute names are pooled module-wide (``self.pending`` in one class
    taints ``x.pending`` everywhere — deliberate conservatism); bare names
    are collected per enclosing function by the caller.
    """

    def __init__(self) -> None:
        self.names: Set[str] = set()
        self.attrs: Set[str] = set()

    @staticmethod
    def _set_annotation(ann: Optional[ast.AST]) -> bool:
        if ann is None:
            return False
        base = ann.value if isinstance(ann, ast.Subscript) else ann
        name = _dotted(base)
        if name is None and isinstance(base, ast.Constant):
            name = str(base.value)
        return name is not None and name.split(".")[-1] in (
            "set", "Set", "frozenset", "FrozenSet", "MutableSet", "AbstractSet")

    @staticmethod
    def _set_value(value: Optional[ast.AST]) -> bool:
        if value is None:
            return False
        if isinstance(value, (ast.Set, ast.SetComp)):
            return True
        if isinstance(value, ast.Call):
            name = _call_name(value)
            if name in ("set", "frozenset"):
                return True
            # dataclasses: field(default_factory=set)
            if name == "field":
                for kw in value.keywords:
                    if kw.arg == "default_factory" and \
                            isinstance(kw.value, ast.Name) and \
                            kw.value.id in ("set", "frozenset"):
                        return True
        return False

    def _record(self, target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            self.names.add(target.id)
        elif isinstance(target, ast.Attribute):
            self.attrs.add(target.attr)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        # class-level declarations (dataclass fields) are attribute facts:
        # ``sandbox_ids: set = field(default_factory=set)`` taints
        # ``<x>.sandbox_ids`` everywhere in the module
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name) and \
                    (self._set_annotation(stmt.annotation)
                     or self._set_value(stmt.value)):
                self.attrs.add(stmt.target.id)
            elif isinstance(stmt, ast.Assign) and self._set_value(stmt.value):
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        self.attrs.add(t.id)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if self._set_annotation(node.annotation) or self._set_value(node.value):
            self._record(node.target)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        if self._set_value(node.value):
            for t in node.targets:
                self._record(t)
        self.generic_visit(node)

    def visit_arg(self, node: ast.arg) -> None:
        if self._set_annotation(node.annotation):
            self.names.add(node.arg)
        self.generic_visit(node)


def _module_set_attrs(tree: ast.AST) -> Set[str]:
    facts = _SetFacts()
    facts.visit(tree)
    return facts.attrs


def _function_set_names(fn: ast.AST) -> Set[str]:
    facts = _SetFacts()
    facts.visit(fn)
    return facts.names


def _is_set_expr(node: ast.AST, names: Set[str], attrs: Set[str]) -> bool:
    if isinstance(node, ast.Name):
        return node.id in names
    if isinstance(node, ast.Attribute):
        return node.attr in attrs
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        name = _call_name(node)
        if name in ("set", "frozenset"):
            return True
        if isinstance(node.func, ast.Attribute) and node.func.attr in (
                "union", "intersection", "difference",
                "symmetric_difference", "copy"):
            return _is_set_expr(node.func.value, names, attrs)
        return False
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.BitXor, ast.Sub)):
        return (_is_set_expr(node.left, names, attrs)
                or _is_set_expr(node.right, names, attrs))
    return False


def _comp_sink_ok(comp: ast.AST) -> bool:
    """A comprehension/genexp feeding an order-insensitive callable (or a
    constant-element ``sum``) is exempt: the iteration order cannot leak."""
    parent = _parent(comp)
    if not (isinstance(parent, ast.Call) and isinstance(parent.func, ast.Name)):
        return False
    fname = parent.func.id
    if fname in ("sorted", "min", "max"):
        return not any(kw.arg == "key" for kw in parent.keywords)
    if fname in _ORDER_INSENSITIVE:
        return True
    if fname == "sum" and isinstance(comp, (ast.GeneratorExp, ast.ListComp)):
        return isinstance(comp.elt, ast.Constant)
    return False


def _iteration_findings(fn: ast.AST, names: Set[str], attrs: Set[str],
                        order_sensitive: bool) -> List[RawFinding]:
    out: List[RawFinding] = []

    def check_iter(it: ast.AST, where: str, sink_ok: bool) -> None:
        if _is_sorted_call(it):
            return
        if _is_set_expr(it, names, attrs):
            if sink_ok:
                return
            out.append((it.lineno, "set-iteration",
                        f"{where} iterates a set ({_dotted(it) or 'set expr'})"
                        f" in hash order — wrap in sorted(...)"))
        elif order_sensitive and isinstance(it, ast.Call) and \
                isinstance(it.func, ast.Attribute) and \
                it.func.attr in ("keys", "values", "items") and not it.args:
            if sink_ok:
                return
            out.append((it.lineno, "dict-iteration",
                        f"{where} iterates {_dotted(it)} on an order-"
                        f"sensitive path — wrap in sorted(...) or suppress "
                        f"with a note proving insertion order is "
                        f"deterministic"))

    for node in ast.walk(fn):
        if isinstance(node, ast.For):
            check_iter(node.iter, "for-loop", sink_ok=False)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                               ast.DictComp)):
            sink_ok = _comp_sink_ok(node)
            for gen in node.generators:
                check_iter(gen.iter, "comprehension", sink_ok=sink_ok)
        elif isinstance(node, ast.Call):
            fname = _call_name(node)
            if fname in _ITERATING_SINKS and node.args:
                check_iter(node.args[0], f"{fname}()", sink_ok=False)
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "join" and node.args:
                check_iter(node.args[0], "str.join()", sink_ok=False)
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "pop" and not node.args and \
                    _is_set_expr(node.func.value, names, attrs):
                out.append((node.lineno, "set-iteration",
                            f"{_dotted(node.func.value)}.pop() returns an "
                            f"arbitrary (hash-order) element — pop from a "
                            f"sorted sequence instead"))
    return out


def rule_container_iteration(tree: ast.AST, source: str) -> List[RawFinding]:
    attrs = _module_set_attrs(tree)
    out: List[RawFinding] = []
    seen_fn_lines: Set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            seen_fn_lines.add(node.lineno)
            names = _function_set_names(node)
            sensitive = bool(_ORDER_SENSITIVE_FN.search(node.name))
            out.extend(_iteration_findings(node, names, attrs, sensitive))
    # dedup: nested functions are walked twice (outer + inner visit)
    uniq = sorted(set(out))
    return uniq


# -- rule: lock-order / held-lock-timeout -------------------------------------

def _lockish_acquire(call: ast.Call) -> Optional[str]:
    """Dotted base of ``<base>.acquire()`` when <base> smells like a lock."""
    if isinstance(call.func, ast.Attribute) and call.func.attr == "acquire":
        base = _dotted(call.func.value)
        if base and _LOCKISH.search(base):
            return base
    return None


def _lockish_release(call: ast.Call) -> Optional[str]:
    if isinstance(call.func, ast.Attribute) and call.func.attr == "release":
        base = _dotted(call.func.value)
        if base and _LOCKISH.search(base):
            return base
    return None


def _is_env_timeout(call: ast.Call) -> bool:
    name = _call_name(call)
    if not name:
        return False
    parts = name.split(".")
    return parts[-1] in ("timeout", "timeout_at") and (
        len(parts) >= 2 and parts[-2] == "env" or parts[0] == "env")


def _yielded_call(stmt: ast.stmt) -> Optional[ast.Call]:
    """The Call inside ``yield <call>`` as an expression statement or the
    RHS of an assignment (``x = yield <call>``)."""
    value = None
    if isinstance(stmt, ast.Expr):
        value = stmt.value
    elif isinstance(stmt, ast.Assign):
        value = stmt.value
    if isinstance(value, (ast.Yield, ast.YieldFrom)) and \
            isinstance(value.value, ast.Call):
        return value.value
    return None


class _OrderedNames:
    """Names provably derived from a ``sorted(...)`` sequence inside one
    function — the id-sorted quiesce discipline's dataflow. Unlike the
    set-iteration exemption, ``sorted`` with a ``key=`` counts: lock bases
    are sorted by unique ids, so keyed sorts impose the same global order
    on every process."""

    def __init__(self, fn: ast.AST) -> None:
        self.names: Set[str] = set()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            if self._ordered_value(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        self.names.add(t.id)
                    elif isinstance(t, ast.Tuple):
                        for elt in t.elts:
                            if isinstance(elt, ast.Name):
                                self.names.add(elt.id)

    @staticmethod
    def _any_sorted_call(node: ast.AST) -> bool:
        return (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "sorted")

    def _ordered_value(self, value: ast.AST) -> bool:
        if self._any_sorted_call(value):
            return True
        if isinstance(value, ast.Name) and value.id in self.names:
            return True
        if isinstance(value, (ast.ListComp, ast.GeneratorExp)) and \
                len(value.generators) == 1:
            return self.iter_ordered(value.generators[0].iter)
        if isinstance(value, ast.Call) and _call_name(value) in (
                "list", "tuple") and value.args:
            return self.iter_ordered(value.args[0])
        return False

    def iter_ordered(self, it: ast.AST) -> bool:
        if self._any_sorted_call(it):
            return True
        return isinstance(it, ast.Name) and it.id in self.names


class _LockScanner:
    """Lexical abstract interpretation of lock holds in one function body.

    Tracks the set of held lock bases through straight-line code, branches
    (union), loops (entry ∪ body-exit ∪ state-at-each-break) and
    try/finally. Emits ``lock-order`` when a second lock is requested while
    one is held and either base does not trace to an id-``sorted`` sequence,
    and ``held-lock-timeout`` for every ``yield env.timeout(...)`` reached
    with a non-empty held set.
    """

    def __init__(self, fn: ast.AST) -> None:
        self.fn = fn
        self.ordered = _OrderedNames(fn)
        self.findings: List[RawFinding] = []
        # loop targets whose iterable was ordered: acquires rooted at these
        # names are part of a sanctioned sorted sweep
        self._ordered_loop_roots: Set[str] = set()

    def run(self) -> List[RawFinding]:
        self._scan(self.fn.body, {}, [])
        return self.findings

    # held: dict base -> first acquire line; breaks: list of held snapshots
    def _scan(self, stmts, held: Dict[str, int], breaks) -> Dict[str, int]:
        for stmt in stmts:
            held = self._scan_stmt(stmt, held, breaks)
        return held

    def _root_ordered(self, base: str) -> bool:
        root = base.split(".")[0].split("[")[0]
        return root in self.ordered.names or root in self._ordered_loop_roots

    def _on_acquire(self, base: str, line: int, held: Dict[str, int]) -> None:
        if base in held:
            self.findings.append((line, "lock-order",
                                  f"re-acquire of held lock {base} "
                                  f"(first acquired at line {held[base]}) "
                                  f"would self-deadlock"))
            return
        if held:
            bad = [b for b in [*held, base] if not self._root_ordered(b)]
            if bad:
                self.findings.append(
                    (line, "lock-order",
                     f"acquiring {base} while holding "
                     f"{sorted(held)} — multi-lock acquires must derive "
                     f"from an id-sorted sequence (unsorted: {sorted(bad)})"))
        held[base] = line

    def _scan_stmt(self, stmt, held, breaks) -> Dict[str, int]:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return held          # nested defs run later, scanned separately
        if isinstance(stmt, ast.Break):
            breaks.append(dict(held))
            return held
        call = _yielded_call(stmt)
        if call is not None:
            base = _lockish_acquire(call)
            if base is not None:
                self._on_acquire(base, call.lineno, held)
                return held
            if _is_env_timeout(call) and held:
                locks = ", ".join(sorted(held))
                self.findings.append(
                    (call.lineno, "held-lock-timeout",
                     f"yield env.timeout(...) while holding {locks} — "
                     f"annotate the modeled hold window with "
                     f"`# simlint: ok(held-lock-timeout): <why>`"))
                return held
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            base = _lockish_release(stmt.value)
            if base is not None:
                held.pop(base, None)
                return held
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            return self._scan_loop(stmt, held, breaks)
        if isinstance(stmt, ast.While):
            return self._scan_loop(stmt, held, breaks)
        if isinstance(stmt, ast.If):
            a = self._scan(stmt.body, dict(held), breaks)
            b = self._scan(stmt.orelse, dict(held), breaks)
            return {**a, **b}
        if isinstance(stmt, ast.Try):
            body = self._scan(stmt.body, dict(held), breaks)
            merged = {**held, **body}
            for handler in stmt.handlers:
                merged.update(self._scan(handler.body, dict(merged), breaks))
            merged.update(self._scan(stmt.orelse, dict(body), breaks))
            return self._scan(stmt.finalbody, merged, breaks)
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            return self._scan(stmt.body, held, breaks)
        return held

    def _scan_loop(self, stmt, held, breaks) -> Dict[str, int]:
        target = stmt.target.id if isinstance(stmt, (ast.For, ast.AsyncFor)) \
            and isinstance(stmt.target, ast.Name) else None
        iter_ordered = isinstance(stmt, (ast.For, ast.AsyncFor)) and \
            self.ordered.iter_ordered(stmt.iter)
        if target is not None and iter_ordered:
            self._ordered_loop_roots.add(target)

        # a loop that acquires on its own target and releases nothing inside
        # is a multi-lock sweep: the iterable itself must be id-sorted
        if target is not None and not iter_ordered:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Call):
                    base = _lockish_acquire(node)
                    if base and base.split(".")[0] == target:
                        self.findings.append(
                            (node.lineno, "lock-order",
                             f"lock sweep acquires {base} while looping "
                             f"over an iterable not provably sorted — "
                             f"iterate a sorted(...) sequence"))
                        break

        loop_breaks: List[Dict[str, int]] = []
        body_exit = self._scan(stmt.body, dict(held), loop_breaks)
        orelse_exit = self._scan(stmt.orelse, dict(body_exit), loop_breaks)
        out = dict(held)
        out.update(body_exit)
        out.update(orelse_exit)
        for snap in loop_breaks:
            out.update(snap)
        return out


def rule_locks(tree: ast.AST, source: str) -> List[RawFinding]:
    out: List[RawFinding] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.extend(_LockScanner(node).run())
    return sorted(set(out))


# -- registry -----------------------------------------------------------------

RULES = {
    "builtin-hash": rule_builtin_hash,
    "wall-clock": rule_wall_clock,
    "global-rng": rule_global_rng,
    "set-iteration": rule_container_iteration,   # also emits dict-iteration
    "lock-order": rule_locks,                    # also emits held-lock-timeout
}

# every rule name a finding (or suppression) may carry
RULE_NAMES = ("builtin-hash", "wall-clock", "global-rng", "set-iteration",
              "dict-iteration", "lock-order", "held-lock-timeout",
              "stale-suppression")


def all_raw_findings(tree: ast.AST, source: str) -> List[RawFinding]:
    _annotate_parents(tree)
    out: List[RawFinding] = []
    for rule in RULES.values():
        out.extend(rule(tree, source))
    return sorted(set(out))
