"""int8 gradient/checkpoint compression with stochastic rounding.

Per-tensor absmax scaling; stochastic rounding keeps the quantizer unbiased
(E[deq(q(x))] = x), which is what makes it usable on the gradient path. On a
real multi-pod deployment this codec wraps the pod-axis (DCN) gradient
all-reduce — DCN bandwidth is the scarce resource at 2+ pods; here it is
exercised on the gradient path pre-optimizer and by the checkpoint writer.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    lo = jnp.floor(y)
    p_up = y - lo
    up = jax.random.uniform(key, x.shape) < p_up
    q = jnp.clip(lo + up.astype(jnp.float32), -128, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(tree: Any, key: jax.Array) -> Any:
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        q, s = quantize_int8(leaf, jax.random.fold_in(key, i))
        out.append((q, s))
    return jax.tree.unflatten(treedef, out)


def decompress_tree(ctree: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(lambda qs: dequantize_int8(qs[0], qs[1], dtype),
                        ctree, is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and hasattr(x[0], "dtype"))


def roundtrip_tree(tree: Any, key: jax.Array) -> Any:
    """Quantize+dequantize in place (the numerical effect of a compressed
    all-reduce, without materializing int8 buffers across the tree)."""
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        q, s = quantize_int8(leaf, jax.random.fold_in(key, i))
        out.append(dequantize_int8(q, s, leaf.dtype))
    return jax.tree.unflatten(treedef, out)
