"""AdamW in pure JAX, with optional ZeRO-1 optimizer-state sharding."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def adamw_init_specs(param_specs) -> AdamWState:
    """ShapeDtypeStruct version for AOT lowering."""
    f32 = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                       param_specs)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=f32,
                      nu=jax.tree.map(lambda s: s, f32))


def zero1_pspec(param_pspec: P, shape: tuple, dax) -> P:
    """ZeRO-1: additionally shard optimizer state over the data axes on the
    first dimension that is unsharded (moment tensors are only read/written
    by the optimizer, so data-sharding them removes their replication).
    No-op if the param spec already uses any of the data axes (e.g. FSDP
    expert shards) — a mesh axis may appear only once in a spec."""
    dax_set = set(dax if isinstance(dax, (tuple, list)) else (dax,))
    for entry in param_pspec:
        entries = entry if isinstance(entry, (tuple, list)) else (entry,)
        if dax_set & set(e for e in entries if e is not None):
            return param_pspec
    dims = list(param_pspec) + [None] * (len(shape) - len(param_pspec))
    for i, (entry, size) in enumerate(zip(dims, shape)):
        if entry is None and size >= 64 and size % 2 == 0:
            dims[i] = dax
            return P(*dims)
    return P(*dims)


def adamw_pspecs(param_pspecs, param_specs, use_zero1: bool = False,
                 dax=("pod", "data")) -> AdamWState:
    if not use_zero1:
        mu = param_pspecs
    else:
        mu = jax.tree.map(
            lambda ps, sp: zero1_pspec(ps, sp.shape, dax),
            param_pspecs, param_specs,
            is_leaf=lambda x: isinstance(x, P))
    return AdamWState(step=P(), mu=mu, nu=jax.tree.map(lambda x: x, mu))


def adamw_update(grads, state: AdamWState, params, lr: float = 3e-4,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: Optional[float] = 1.0):
    step = state.step + 1
    if grad_clip is not None:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
    else:
        gnorm = jnp.zeros(())
        scale = 1.0

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return m_new, v_new, p_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v,
                                                 flat_p)]
    mu = jax.tree.unflatten(treedef, [o[0] for o in out])
    nu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_params = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, AdamWState(step=step, mu=mu, nu=nu), gnorm
