"""Deterministic synthetic data pipeline.

Two generators:
  * ``random_tokens`` — uniform tokens (dry-run / throughput benchmarks);
  * ``ZipfLMStream``  — a learnable synthetic language: Zipf unigram
    distribution with a deterministic bigram transition structure, so
    training actually reduces loss (used by examples/train_smollm.py and the
    training tests).

Both are seeded and step-indexed: batch(step) is a pure function, so a
restarted/rescaled job resumes with identical data order (fault-tolerance
property tested in tests/test_checkpoint.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


def random_tokens(step: int, batch: int, seq: int, vocab: int,
                  seed: int = 0) -> dict:
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    toks = jax.random.randint(key, (batch, seq + 1), 0, vocab)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass
class ZipfLMStream:
    vocab: int
    seq: int
    batch: int
    seed: int = 0
    alpha: float = 1.2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        self.unigram = ranks ** (-self.alpha)
        self.unigram /= self.unigram.sum()
        # deterministic bigram structure: each token prefers a fixed
        # successor window (makes next-token prediction learnable)
        self.succ = rng.integers(0, self.vocab, size=self.vocab)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        toks = np.empty((self.batch, self.seq + 1), dtype=np.int32)
        cur = rng.choice(self.vocab, size=self.batch, p=self.unigram)
        toks[:, 0] = cur
        for t in range(1, self.seq + 1):
            follow = rng.random(self.batch) < 0.7
            nxt = np.where(
                follow, self.succ[toks[:, t - 1]],
                rng.choice(self.vocab, size=self.batch, p=self.unigram))
            toks[:, t] = nxt
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}
