"""Train-step factory: loss + grad (+ accumulation) + AdamW, pjit-ready."""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.api import RunConfig
from repro.train.optimizer import AdamWState, adamw_update
from repro.train.compress import roundtrip_tree


def make_train_step(model, lr: float = 3e-4,
                    weight_decay: float = 0.1) -> Callable:
    """Returns train_step(params, opt_state, batch, rng) ->
    (params, opt_state, metrics). Honors RunConfig.grad_accum and
    RunConfig.grad_compress."""
    run: RunConfig = model.run

    def compute_grads(params, batch):
        return jax.value_and_grad(model.loss_fn)(params, batch)

    def train_step(params, opt_state: AdamWState, batch, rng):
        accum = run.grad_accum
        if accum <= 1:
            loss, grads = compute_grads(params, batch)
        else:
            # split the batch into microbatches along dim 0 and scan:
            # overlaps per-microbatch backward with the gradient reduction
            def micro(carry, mb):
                acc = carry
                l, g = compute_grads(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, l

            mbatch = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            # unrolled in exact-cost (probe) mode so cost_analysis counts
            # every microbatch (see launch/dryrun.py)
            grads, losses = jax.lax.scan(micro, zero, mbatch,
                                         unroll=(run.layer_mode == "unroll"))
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = jnp.mean(losses)

        if run.grad_compress:
            # int8 stochastic-rounding codec on the gradient path (stands in
            # for the pod-axis DCN compressed all-reduce; see compress.py)
            grads = roundtrip_tree(grads, rng)

        params, opt_state, gnorm = adamw_update(
            grads, opt_state, params, lr=lr, weight_decay=weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt_state.step}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model) -> Callable:
    def eval_step(params, batch):
        return model.loss_fn(params, batch)
    return eval_step
