"""Sharded checkpointing with elastic resharding and async save.

Layout: ``<dir>/step_<N>/manifest.msgpack`` + one ``.npy``-in-``.npz`` shard
file per leaf (chunked along dim 0 above a size threshold so very large
leaves parallelize across writers on a real fleet). The manifest records the
pytree structure, shapes, dtypes and chunking — restore reassembles leaves
and ``jax.device_put``s them with ANY target sharding, which is what makes
restarts onto a *different mesh shape* (elastic scaling after node loss)
work: tests/test_checkpoint.py asserts train-state equivalence after a
save -> shrink-mesh -> restore -> resume cycle.

Async mode: the save runs on a background thread from host copies, so the
training loop resumes immediately (checkpoint/restart without stalling the
step loop).
"""
from __future__ import annotations

import os
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_CHUNK_BYTES = 256 * 1024 * 1024


def _leaf_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaf_paths(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "shape"):
        for i, v in enumerate(tree):
            yield from _leaf_paths(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def _set_path(tree, path, value):
    parts = [p for p in path.split("/") if p]
    node = tree
    for p in parts[:-1]:
        node = node[p] if isinstance(node, dict) else node[int(p)]
    last = parts[-1]
    if isinstance(node, dict):
        node[last] = value
    else:
        node[int(last)] = value


def save_checkpoint(path: str, step: int, tree: Any,
                    async_save: bool = False) -> Optional[threading.Thread]:
    """Save a pytree of jax/np arrays. Returns the writer thread if async."""
    ckpt_dir = os.path.join(path, f"step_{step:08d}")
    tmp_dir = ckpt_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)

    # copy to host synchronously (cheap vs device compute), write async
    host_leaves = []
    manifest = {"step": step, "leaves": []}
    for lpath, leaf in _leaf_paths(tree):
        arr = np.asarray(leaf)
        n_chunks = max(1, -(-arr.nbytes // _CHUNK_BYTES)) if arr.ndim > 0 else 1
        n_chunks = min(n_chunks, arr.shape[0]) if arr.ndim > 0 else 1
        manifest["leaves"].append({
            "path": lpath, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "chunks": n_chunks,
        })
        host_leaves.append((lpath, arr, n_chunks))

    def write():
        for lpath, arr, n_chunks in host_leaves:
            safe = lpath.strip("/").replace("/", ".")
            if n_chunks == 1:
                np.save(os.path.join(tmp_dir, f"{safe}.npy"), arr)
            else:
                for ci, chunk in enumerate(np.array_split(arr, n_chunks)):
                    np.save(os.path.join(tmp_dir, f"{safe}.{ci:04d}.npy"),
                            chunk)
        with open(os.path.join(tmp_dir, "manifest.msgpack"), "wb") as fh:
            fh.write(msgpack.packb(manifest))
        os.replace(tmp_dir, ckpt_dir)   # atomic publish

    if async_save:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(path: str, step: Optional[int], like: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (same structure, jax.sharding.Sharding
    leaves) enables restore onto any mesh — the elastic-rescale path."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    ckpt_dir = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(ckpt_dir, "manifest.msgpack"), "rb") as fh:
        manifest = msgpack.unpackb(fh.read())

    shard_map_ = None
    if shardings is not None:
        shard_map_ = dict(_leaf_paths(shardings))

    leaves = {}
    for rec in manifest["leaves"]:
        lpath = rec["path"]
        safe = lpath.strip("/").replace("/", ".")
        if rec["chunks"] == 1:
            arr = np.load(os.path.join(ckpt_dir, f"{safe}.npy"))
        else:
            parts = [np.load(os.path.join(ckpt_dir, f"{safe}.{ci:04d}.npy"))
                     for ci in range(rec["chunks"])]
            arr = np.concatenate(parts, axis=0)
        arr = arr.reshape(rec["shape"]).astype(rec["dtype"])
        if shard_map_ is not None and lpath in shard_map_:
            leaves[lpath] = jax.device_put(arr, shard_map_[lpath])
        else:
            leaves[lpath] = jnp.asarray(arr)

    # rebuild the tree in one pass (sorted keys to match _leaf_paths order)
    def rebuild(t, prefix=""):
        if isinstance(t, dict):
            return {k: rebuild(t[k], f"{prefix}/{k}") for k in t}
        if isinstance(t, (list, tuple)) and not hasattr(t, "shape"):
            vals = [rebuild(v, f"{prefix}/{i}") for i, v in enumerate(t)]
            if hasattr(t, "_fields"):   # NamedTuple (AdamWState)
                return type(t)(*vals)
            return vals if isinstance(t, list) else tuple(vals)
        return leaves[prefix]

    return rebuild(like), manifest["step"]
