"""Dirigent's four cluster-management abstractions (paper §3.2, Table 3).

The control plane orchestrates exactly four object kinds:

  * ``Function``   — user-registered recipe for sandboxes (persisted, except
                     scheduling *metrics* which are reconstructible from DP
                     traffic);
  * ``Sandbox``    — a running instance on a worker node (NOT persisted;
                     reconstructible from worker nodes). Serialized state is
                     16 bytes (vs ≈17 KB for a K8s Pod object);
  * ``DataPlane``  — a data-plane replica endpoint (persisted);
  * ``WorkerNode`` — a worker daemon endpoint (persisted).

The binary codec below is the literal "16 bytes per sandbox" artifact: the
tests assert ``len(sandbox.to_bytes()) == 16`` and round-tripping.
"""
from __future__ import annotations

import enum
import struct
from dataclasses import dataclass, field
from typing import Optional


class SandboxState(enum.IntEnum):
    CREATING = 0
    READY = 1
    DRAINING = 2
    TERMINATING = 3


# -- Function ---------------------------------------------------------------


@dataclass
class ScalingConfig:
    """Per-function autoscaling knobs (Knative-default policy, paper §4)."""

    target_concurrency: float = 1.0   # sandboxes process 1 request at a time
    stable_window: float = 60.0       # seconds
    panic_window: float = 6.0         # seconds
    panic_threshold: float = 2.0      # panic if desired >= 2x ready
    scale_to_zero_grace: float = 30.0  # seconds of zero concurrency
    max_scale: int = 10_000
    cpu_req_millis: int = 250          # placement resource request
    mem_req_mb: int = 256


@dataclass
class FunctionMetrics:
    """Scheduling metrics — in-memory only, never persisted (Table 3)."""

    inflight: int = 0                 # executing + queued, cluster-wide
    total_invocations: int = 0
    cold_starts: int = 0

    def snapshot(self) -> dict:
        return {
            "inflight": self.inflight,
            "total_invocations": self.total_invocations,
            "cold_starts": self.cold_starts,
        }


@dataclass
class Function:
    name: str
    image_url: str
    port: int
    scaling: ScalingConfig = field(default_factory=ScalingConfig)
    # in-memory only:
    metrics: FunctionMetrics = field(default_factory=FunctionMetrics)

    def persisted_record(self) -> bytes:
        """Binary record persisted on registration (excludes metrics)."""
        name_b = self.name.encode()
        url_b = self.image_url.encode()
        s = self.scaling
        return struct.pack(
            f"<H{len(name_b)}sH{len(url_b)}sHfffffIHH",
            len(name_b), name_b, len(url_b), url_b, self.port,
            s.target_concurrency, s.stable_window, s.panic_window,
            s.panic_threshold, s.scale_to_zero_grace, s.max_scale,
            s.cpu_req_millis, s.mem_req_mb,
        )

    @staticmethod
    def from_record(buf: bytes) -> "Function":
        off = 0
        (nlen,) = struct.unpack_from("<H", buf, off); off += 2
        name = buf[off:off + nlen].decode(); off += nlen
        (ulen,) = struct.unpack_from("<H", buf, off); off += 2
        url = buf[off:off + ulen].decode(); off += ulen
        (port, tc, sw, pw, pt, g, ms, cpu, mem) = struct.unpack_from(
            "<HfffffIHH", buf, off)
        return Function(
            name=name, image_url=url, port=port,
            scaling=ScalingConfig(
                target_concurrency=tc, stable_window=sw, panic_window=pw,
                panic_threshold=pt, scale_to_zero_grace=g, max_scale=ms,
                cpu_req_millis=cpu, mem_req_mb=mem,
            ),
        )


# -- Sandbox ------------------------------------------------------------------

_SANDBOX_FMT = "<I4sHIBx"  # id, ipv4, port, worker_id, state, pad  == 16 bytes
assert struct.calcsize(_SANDBOX_FMT) == 16


@dataclass
class Sandbox:
    """A sandbox instance. 16-byte binary state (paper §3.2)."""

    sandbox_id: int
    function_name: str        # implied by the per-function table it lives in
    ip: tuple[int, int, int, int]
    port: int
    worker_id: int
    state: SandboxState = SandboxState.CREATING

    def to_bytes(self) -> bytes:
        return struct.pack(
            _SANDBOX_FMT, self.sandbox_id, bytes(self.ip), self.port,
            self.worker_id, int(self.state),
        )

    @staticmethod
    def from_bytes(buf: bytes, function_name: str = "") -> "Sandbox":
        sid, ip, port, wid, state = struct.unpack(_SANDBOX_FMT, buf)
        return Sandbox(
            sandbox_id=sid, function_name=function_name,
            ip=tuple(ip), port=port, worker_id=wid,
            state=SandboxState(state),
        )

    @property
    def key(self) -> str:
        return f"{self.function_name}/{self.sandbox_id}"


# -- DataPlane / WorkerNode ----------------------------------------------------


@dataclass
class DataPlaneInfo:
    dp_id: int
    ip: tuple[int, int, int, int]
    port: int

    def persisted_record(self) -> bytes:
        return struct.pack("<I4sH", self.dp_id, bytes(self.ip), self.port)

    @staticmethod
    def from_record(buf: bytes) -> "DataPlaneInfo":
        dp_id, ip, port = struct.unpack("<I4sH", buf)
        return DataPlaneInfo(dp_id=dp_id, ip=tuple(ip), port=port)


@dataclass
class WorkerNodeInfo:
    worker_id: int
    name: str
    ip: tuple[int, int, int, int]
    port: int
    cpu_capacity_millis: int = 10_000
    mem_capacity_mb: int = 64_000

    def persisted_record(self) -> bytes:
        name_b = self.name.encode()
        return struct.pack(
            f"<IH{len(name_b)}s4sHII", self.worker_id, len(name_b), name_b,
            bytes(self.ip), self.port, self.cpu_capacity_millis,
            self.mem_capacity_mb,
        )

    @staticmethod
    def from_record(buf: bytes) -> "WorkerNodeInfo":
        off = 0
        (wid, nlen) = struct.unpack_from("<IH", buf, off); off += 6
        name = buf[off:off + nlen].decode(); off += nlen
        ip, port, cpu, mem = struct.unpack_from("<4sHII", buf, off)
        return WorkerNodeInfo(
            worker_id=wid, name=name, ip=tuple(ip), port=port,
            cpu_capacity_millis=cpu, mem_capacity_mb=mem,
        )
