"""Experiment metric collection: per-invocation records, percentiles, CDFs."""
from __future__ import annotations

from bisect import bisect_left
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core.request import Invocation


def percentile(xs: Iterable[float], p: float) -> float:
    arr = np.asarray(list(xs), dtype=np.float64)
    if arr.size == 0:
        return float("nan")
    return float(np.percentile(arr, p))


def geomean(xs: Iterable[float]) -> float:
    arr = np.asarray(list(xs), dtype=np.float64)
    arr = arr[arr > 0]
    if arr.size == 0:
        return float("nan")
    return float(np.exp(np.mean(np.log(arr))))


@dataclass
class Collector:
    invocations: List[Invocation] = field(default_factory=list)
    events: List[tuple] = field(default_factory=list)   # (t, kind, detail)
    sandbox_creations: int = 0
    sandbox_teardowns: int = 0
    reconciles: int = 0        # autoscale/reconcile decisions taken by the CP
    fn_migrations: int = 0     # functions moved between CP shards (rebalancer)
    fn_splits: int = 0         # functions split across a CP shard-set
    fn_merges: int = 0         # split functions folded back to a sole owner
    steal_probes: int = 0      # cross-shard capacity probes paid (spill path)
    steals: int = 0            # placements satisfied by a foreign shard

    # per-kind timestamp index (events arrive in nondecreasing sim time, so
    # each list is sorted): the failover benches probe creation timelines
    # per cell, and at 100k-worker scale each probe was a full O(events)
    # scan of the flat list
    _times_by_kind: Dict[str, List[float]] = field(default_factory=dict)

    def done(self, inv: Invocation) -> None:
        self.invocations.append(inv)

    def event(self, t: float, kind: str, detail: object = None) -> None:
        self.events.append((t, kind, detail))
        self._times_by_kind.setdefault(kind, []).append(t)

    # -- views ---------------------------------------------------------------
    @property
    def completed(self) -> List[Invocation]:
        return [i for i in self.invocations if not i.failed]

    @property
    def failed(self) -> List[Invocation]:
        return [i for i in self.invocations if i.failed]

    def sched_latencies(self, warmup: float = 0.0) -> np.ndarray:
        return np.array([i.scheduling_latency for i in self.completed
                         if i.arrival >= warmup], dtype=np.float64)

    def slowdowns(self, warmup: float = 0.0) -> np.ndarray:
        return np.array([i.slowdown for i in self.completed
                         if i.arrival >= warmup], dtype=np.float64)

    def e2e_latencies(self, warmup: float = 0.0) -> np.ndarray:
        return np.array([i.e2e_latency for i in self.completed
                         if i.arrival >= warmup], dtype=np.float64)

    def event_times(self, kind: str, after: float = 0.0) -> List[float]:
        """Timestamps of every recorded ``kind`` event at or after ``after``
        (failover analysis: creation timelines, recovery milestones)."""
        ts = self._times_by_kind.get(kind, [])
        return ts[bisect_left(ts, after):]

    def first_event_at(self, kind: str, after: float = 0.0) -> Optional[float]:
        """Instant of the first ``kind`` event at or after ``after``; ``None``
        if it never happened. ``first_event_at("sandbox-created", t_kill)``
        is the failover benchmark's time-to-first-creation probe."""
        ts = self._times_by_kind.get(kind, [])
        i = bisect_left(ts, after)
        return ts[i] if i < len(ts) else None

    def window_sched_latencies(self, t0: float, t1: float) -> np.ndarray:
        """Scheduling latencies of completed invocations that *arrived*
        inside ``[t0, t1)`` — the recovery-window view: requests landing
        between leader kill and full recovery, wherever they finish."""
        return np.array([i.scheduling_latency for i in self.completed
                         if t0 <= i.arrival < t1], dtype=np.float64)

    def per_function_mean_sched(self, warmup: float = 0.0) -> Dict[str, float]:
        acc: Dict[str, List[float]] = defaultdict(list)
        for i in self.completed:
            if i.arrival >= warmup:
                acc[i.function_name].append(i.scheduling_latency)
        return {f: float(np.mean(v)) for f, v in acc.items()}

    def per_function_geomean_slowdown(self, warmup: float = 0.0) -> Dict[str, float]:
        acc: Dict[str, List[float]] = defaultdict(list)
        for i in self.completed:
            if i.arrival >= warmup:
                acc[i.function_name].append(i.slowdown)
        return {f: geomean(v) for f, v in acc.items()}

    def summary(self, warmup: float = 0.0) -> Dict[str, float]:
        sched = self.sched_latencies(warmup)
        slow = self.slowdowns(warmup)
        pf_sched = list(self.per_function_mean_sched(warmup).values())
        pf_slow = list(self.per_function_geomean_slowdown(warmup).values())
        return {
            "n_completed": len(self.completed),
            "n_failed": len(self.failed),
            "sched_p50_ms": percentile(sched, 50) * 1e3,
            "sched_p99_ms": percentile(sched, 99) * 1e3,
            "slowdown_p50": percentile(slow, 50),
            "slowdown_p99": percentile(slow, 99),
            "perfn_sched_p50_ms": percentile(pf_sched, 50) * 1e3,
            "perfn_sched_p99_ms": percentile(pf_sched, 99) * 1e3,
            "perfn_slowdown_p50": percentile(pf_slow, 50),
            "perfn_slowdown_p99": percentile(pf_slow, 99),
            "sandbox_creations": self.sandbox_creations,
        }
