"""K8s/Knative baseline cluster-manager simulator (paper §2.2 root causes).

This is the *baseline the paper measures against*, reproduced at the
queueing-mechanism level:

  * every cluster-state change is a read-modify-write against a centralized
    API server backed by a strongly-consistent store (etcd): controller ->
    API-server RPC, CPU to (de)serialize ~17 KB nested objects, serialized
    WAL fsync;
  * controllers are independent microservices that only communicate through
    watch events on the store (informer cache lag), with client-go
    token-bucket rate limiting;
  * concurrent RMWs to the same hot object (the per-function Deployment /
    ReplicaSet / Endpoints) hit optimistic-concurrency conflicts and retry
    with backoff — this is what collapses throughput under churn;
  * sandbox = Pod with a queue-proxy sidecar created *sequentially* after the
    user container, then both must pass readiness probes (Fig 1);
  * the warm path crosses istio ingress + activator + queue-proxy;
  * the autoscaler is the same KPA policy Dirigent uses (paper §4), but it
    acts through Deployment updates and sees metrics with reporting lag.

``fused=True`` models the K3s experiment (all components in one process: no
inter-component RPC, watch lag ≈ a channel op) — the paper's point is that
this barely helps because serialization + persistence dominate (C4).
``flavor="openwhisk"`` adds the Kafka hop + CouchDB read that put OpenWhisk's
warm path behind Knative's (Fig 8, [48]).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Generator, List, Optional

from repro.core.abstractions import Function, Sandbox, SandboxState, WorkerNodeInfo
from repro.core.autoscaler import FunctionAutoscalerState
from repro.core.costmodel import CostModel, DEFAULT_COSTS, KnativeCosts
from repro.core.metrics import Collector
from repro.core.placement import Placer
from repro.core.request import Invocation, InvocationMode
from repro.simcore import Environment, Event, Interrupt


class TokenBucket:
    """client-go flow-control: qps refill with burst credit (GCRA form)."""

    def __init__(self, env: Environment, qps: float, burst: int):
        self.env = env
        self.interval = 1.0 / qps
        self.tau = burst * self.interval
        self._last_target = -1e18

    def acquire(self) -> Generator:
        now = self.env.now
        target = max(now - self.tau, self._last_target + self.interval)
        self._last_target = target
        wait = max(0.0, target - now)
        if wait > 0:
            yield self.env.timeout(wait)


class ApiServer:
    """The K8s API server + etcd pair: CPU for serialization, WAL for writes,
    optimistic concurrency on object versions."""

    def __init__(self, env: Environment, costs: KnativeCosts):
        self.env = env
        self.costs = costs
        self.cpu = env.resource(capacity=costs.apiserver_cores,
                                name="apiserver-cpu")
        self.etcd_wal = env.resource(capacity=1, name="etcd-wal")
        self.versions: Dict[str, int] = {}
        self.op_count = 0
        self.conflict_count = 0
        self.cpu_busy = 0.0

    def read(self, key: str, kb: Optional[float] = None) -> Generator:
        c = self.costs
        kb = c.small_object_kb if kb is None else kb
        yield self.cpu.acquire()
        try:
            dt = kb * c.serialize_per_kb * 0.3   # reads deserialize less
            self.cpu_busy += dt
            yield self.env.timeout(dt)
        finally:
            self.cpu.release()
        yield self.env.timeout(c.etcd_read)
        self.op_count += 1
        return self.versions.get(key, 0)

    def write(self, key: str, expect_version: Optional[int] = None,
              kb: Optional[float] = None) -> Generator:
        """Returns True on success, False on a version conflict."""
        c = self.costs
        kb = c.object_kb if kb is None else kb
        yield self.cpu.acquire()
        try:
            dt = kb * c.serialize_per_kb
            self.cpu_busy += dt
            yield self.env.timeout(dt)
        finally:
            self.cpu.release()
        cur = self.versions.get(key, 0)
        if expect_version is not None and cur != expect_version:
            self.conflict_count += 1
            return False
        yield self.etcd_wal.acquire()
        try:
            yield self.env.timeout(c.etcd_fsync)
        finally:
            self.etcd_wal.release()
        self.versions[key] = cur + 1
        self.op_count += 1
        return True

    def rmw(self, key: str, bucket: TokenBucket, kb: Optional[float] = None,
            max_retries: int = 8) -> Generator:
        """Full controller read-modify-write with conflict retries."""
        c = self.costs
        for attempt in range(max_retries):
            yield from bucket.acquire()
            yield self.env.timeout(c.rpc)
            ver = yield from self.read(key, kb=c.small_object_kb)
            yield self.env.timeout(c.rpc)
            ok = yield from self.write(key, expect_version=ver, kb=kb)
            if ok:
                return attempt
            yield self.env.timeout(c.conflict_backoff * (1.5 ** attempt))
        return max_retries


@dataclass
class PodEndpoint:
    sandbox: Sandbox
    capacity: int = 1
    in_use: int = 0
    draining: bool = False

    @property
    def free(self) -> int:
        return 0 if self.draining else self.capacity - self.in_use


@dataclass
class KnFunctionState:
    function: Function
    autoscaler: FunctionAutoscalerState
    endpoints: Dict[int, PodEndpoint] = field(default_factory=dict)
    queue: List[Invocation] = field(default_factory=list)
    inflight: int = 0
    creating: int = 0

    @property
    def ready_count(self) -> int:
        return len(self.endpoints)


class KnativeCluster:
    """Knative/K8s (or fused-K3s / OpenWhisk-flavored) FaaS platform model."""

    def __init__(self, env: Environment, n_workers: int = 93,
                 costs: Optional[CostModel] = None,
                 fused: bool = False, flavor: str = "knative",
                 sandbox_concurrency: int = 1):
        self.env = env
        self.costs = (costs or DEFAULT_COSTS).knative
        self.fused = fused
        self.flavor = flavor
        self.collector = Collector()
        self.api = ApiServer(env, self.costs)
        self.placer = Placer()
        self.functions: Dict[str, KnFunctionState] = {}
        self.workers: Dict[int, WorkerNodeInfo] = {}
        self._worker_kernel_locks: Dict[int, object] = {}
        self._activator_cpu = env.resource(capacity=self.costs.activator_cores)
        self._workqueue = env.resource(capacity=self.costs.workqueue_workers)
        self._scheduler = env.resource(capacity=1)
        self._buckets: Dict[str, TokenBucket] = {}
        self._sandbox_ids = itertools.count(1)
        self._inv_ids = itertools.count(1)
        self._rng = env.rng("knative")
        self.registered_count = 0
        self.alive = True
        for wid in range(n_workers):
            info = WorkerNodeInfo(worker_id=wid, name=f"w{wid}",
                                  ip=(10, 0, wid // 250, wid % 250), port=9000)
            self.workers[wid] = info
            self.placer.add_node(wid, info.cpu_capacity_millis,
                                 info.mem_capacity_mb)
            self._worker_kernel_locks[wid] = env.resource(
                capacity=1, name=f"kn-kernel-lock-w{wid}")
        self._loops = [env.process(self._kpa_loop(), name="kpa")]

    # -- plumbing ------------------------------------------------------------------
    def _bucket(self, controller: str) -> TokenBucket:
        if controller not in self._buckets:
            self._buckets[controller] = TokenBucket(
                self.env, self.costs.controller_qps, self.costs.controller_burst)
        return self._buckets[controller]

    def _hop(self) -> Generator:
        """Inter-component hop: RPC normally, a channel op when fused (K3s)."""
        yield self.env.timeout(2e-6 if self.fused else self.costs.rpc)

    def _watch(self) -> Generator:
        """Watch/informer propagation between controllers."""
        yield self.env.timeout(2e-6 if self.fused else self.costs.watch_propagation)

    # -- registration (paper §5.2.4) --------------------------------------------------
    def register_function(self, fn: Function) -> Generator:
        c = self.costs
        st = KnFunctionState(function=fn,
                             autoscaler=FunctionAutoscalerState(fn.scaling))
        # Knative ascribes multiple objects per function: service, config,
        # revision, route, SKS, deployment, ingress — each an API-server RMW
        # through its own controller, chained by watch events.
        for i in range(c.registration_objects):
            yield from self._watch()
            yield from self.api.rmw(f"reg/{fn.name}/{i}", self._bucket(f"reg{i}"))
            yield self.env.timeout(c.registration_xds_sync)
        # ingress/route resync grows with the number of existing functions
        grow = c.registration_growth * self.registered_count
        if grow > 0:
            yield self.api.cpu.acquire()
            try:
                self.api.cpu_busy += grow
                yield self.env.timeout(grow)
            finally:
                self.api.cpu.release()
        self.functions[fn.name] = st
        self.registered_count += 1
        return fn.name

    def register_sync(self, fn: Function) -> None:
        done = self.env.event()

        def reg(env):
            yield from self.register_function(fn)
            done.succeed(None)

        self.env.process(reg(self.env), name=f"register-{fn.name}")
        self.env.run_until_event(done)

    # -- invocation path -----------------------------------------------------------------
    def invoke(self, function_name: str, exec_time: float,
               mode: InvocationMode = InvocationMode.SYNC) -> Invocation:
        inv = Invocation(inv_id=next(self._inv_ids),
                         function_name=function_name,
                         arrival=self.env.now, exec_time=exec_time, mode=mode)
        self.env.process(self._handle(inv), name=f"kninv-{inv.inv_id}")
        return inv

    def _handle(self, inv: Invocation) -> Generator:
        c = self.costs
        st = self.functions.get(inv.function_name)
        if st is None or not self.alive:
            inv.failed = True
            inv.failure_reason = "unknown function or platform down"
            inv.t_done = self.env.now
            self.collector.done(inv)
            return
        # front-end LB -> istio ingress -> activator
        yield self.env.timeout(c.lb_hop)
        yield self.env.timeout(c.istio_hop)
        if self.flavor == "openwhisk":
            # OpenWhisk: Kafka + CouchDB on the critical path [48]
            yield self.env.timeout(5.0e-3)     # kafka produce/consume
            yield self.env.timeout(10.0e-3)    # couchdb activation record
        yield self._activator_cpu.acquire()
        try:
            yield self.env.timeout(c.activator_cpu)
        finally:
            self._activator_cpu.release()

        st.inflight += 1
        inv.t_dp_arrival = self.env.now
        try:
            ep = self._pick_endpoint(st)
            if ep is None:
                inv.t_queued = self.env.now
                inv.cold = st.ready_count == 0
                waiter = self.env.event()
                st.queue.append(inv)
                inv._waiter = waiter   # type: ignore[attr-defined]
                if st.ready_count + st.creating == 0:
                    # scale-from-zero: the activator pokes the autoscaler
                    # immediately rather than waiting for the 2 s KPA tick
                    st.autoscaler.record_metric(self.env.now,
                                                float(st.inflight))
                    delta = max(st.autoscaler.desired(self.env.now, 0), 1)
                    st.creating += delta
                    self.env.process(self._scale_up(st, delta),
                                     name=f"scaleup0-{inv.function_name}")
                ep = yield waiter
            # activator -> pod hop + queue-proxy sidecar hop
            yield self.env.timeout(c.pod_hop + c.queue_proxy_hop)
            inv.t_dispatch = self.env.now
            inv.t_exec_start = self.env.now
            yield self.env.timeout(inv.exec_time)
            inv.t_done = self.env.now
            self.collector.done(inv)
            self._release(st, ep)
        finally:
            st.inflight = max(0, st.inflight - 1)

    def _pick_endpoint(self, st: KnFunctionState) -> Optional[PodEndpoint]:
        best = None
        for ep in st.endpoints.values():  # simlint: ok(dict-iteration): pod creation order is deterministic
            if ep.free > 0 and (best is None or ep.in_use < best.in_use):
                best = ep
        if best is not None:
            best.in_use += 1
        return best

    def _release(self, st: KnFunctionState, ep: PodEndpoint) -> None:
        ep.in_use -= 1
        if ep.draining and ep.in_use == 0:
            st.endpoints.pop(ep.sandbox.sandbox_id, None)
        self._drain(st)

    def _drain(self, st: KnFunctionState) -> None:
        while st.queue:
            ep = self._pick_endpoint(st)
            if ep is None:
                return
            inv = st.queue.pop(0)
            inv._waiter.succeed(ep)   # type: ignore[attr-defined]

    # -- autoscaling (KPA through K8s machinery) --------------------------------------------
    def _kpa_loop(self) -> Generator:
        c = self.costs
        while True:
            yield self.env.timeout(c.autoscale_period)
            if not self.alive:
                continue
            for name, st in list(self.functions.items()):
                # metrics arrive with reporting lag; sample current inflight
                st.autoscaler.record_metric(self.env.now, float(st.inflight))
                current = st.ready_count + st.creating
                desired = st.autoscaler.desired(self.env.now, current)
                if desired > current:
                    delta = desired - current
                    st.creating += delta
                    self.env.process(self._scale_up(st, delta),
                                     name=f"scaleup-{name}")
                elif desired < current and st.creating == 0:
                    for ep in self._victims(st, current - desired):
                        self.env.process(self._delete_pod(st, ep),
                                         name=f"del-{name}")

    def _victims(self, st: KnFunctionState, n: int) -> List[PodEndpoint]:
        pods = sorted(st.endpoints.values(), key=lambda e: -e.sandbox.sandbox_id)
        out = []
        for ep in pods:
            if len(out) == n:
                break
            ep.draining = True
            out.append(ep)
        return out

    # -- pod lifecycle: the reconcile chain (paper §2.2) ----------------------------------------
    def _bg_load(self) -> None:
        """Asynchronous per-creation API-server work (Events, status updates,
        informer resyncs, istio xDS pushes). Shares the API-server CPU with
        the critical chain — this is what saturates it at ~2 creations/s."""
        c = self.costs
        n_chunks = max(1, int(round(c.bg_cpu_per_creation / c.bg_chunk)))

        def chunk(env, delay):
            yield env.timeout(delay)
            yield self.api.cpu.acquire()
            try:
                self.api.cpu_busy += c.bg_chunk
                yield env.timeout(c.bg_chunk)
            finally:
                self.api.cpu.release()

        for _ in range(n_chunks):
            # spread across the creation's lifetime (status syncs, resyncs)
            self.env.process(chunk(self.env, self._rng.uniform(0, c.bg_spread)),
                             name="api-bg")

    def _scale_up(self, st: KnFunctionState, delta: int) -> Generator:
        """One reconcile *wave* creating ``delta`` pods for a function.

        Batch semantics match K8s: the Deployment/ReplicaSet updates happen
        once per wave, the RS controller then creates ``delta`` Pod objects
        (small writes, rate-limited), the scheduler binds them serially,
        kubelets boot in parallel, and the Endpoints controller publishes one
        batched update when pods turn ready. This is why a 100-pod burst for
        ONE function is far faster than 100 independent creations — and why
        the steady-state cap (~2/s, API-server CPU) still bites for the
        many-function trace.
        """
        c = self.costs
        fn = st.function.name
        try:
            # bounded controller workqueue concurrency (workers per controller)
            yield self._workqueue.acquire()
            try:
                # wave-level RMWs on hot per-function objects
                yield from self.api.rmw(f"deploy/{fn}", self._bucket("kpa"))
                yield from self._watch()
                yield from self.api.rmw(f"rs/{fn}", self._bucket("deployment"))
                yield from self._watch()
            finally:
                self._workqueue.release()

            # per-pod pipeline, in parallel
            done_pods: List[Sandbox] = []
            waiters = []
            for _ in range(delta):
                ev = self.env.event()
                waiters.append(ev)
                self.env.process(self._boot_pod(st, done_pods, ev),
                                 name=f"boot-{fn}")
            for ev in waiters:
                yield ev

            if done_pods:
                # one batched endpoints + SKS update for the wave
                yield from self.api.rmw(f"endpoints/{fn}",
                                        self._bucket("endpoints"))
                yield from self._watch()
                yield from self.api.rmw(f"sks/{fn}", self._bucket("sks"))
                yield from self._watch()
                for sb in done_pods:
                    st.endpoints[sb.sandbox_id] = PodEndpoint(
                        sandbox=sb, capacity=max(
                            1, int(st.function.scaling.target_concurrency)))
                    self.collector.sandbox_creations += 1
                    self.collector.event(self.env.now, "sandbox-created", fn)
                self._drain(st)
        finally:
            st.creating = max(0, st.creating - delta)

    def _boot_pod(self, st: KnFunctionState, done_pods: list,
                  done_ev) -> Generator:
        c = self.costs
        fn = st.function.name
        try:
            self._bg_load()
            sid = next(self._sandbox_ids)
            # RS controller creates the Pod object (small write, rate-limited)
            yield from self._bucket("replicaset").acquire()
            _ = yield from self.api.write(f"pod/{fn}/{sid}",
                                          kb=c.small_object_kb)
            # scheduler: a single serialized queue (~100 binds/s)
            yield self._scheduler.acquire()
            try:
                yield self.env.timeout(c.scheduler_bind)
                wid = self.placer.place(st.function.scaling.cpu_req_millis,
                                        st.function.scaling.mem_req_mb)
            finally:
                self._scheduler.release()
            if wid is None:
                return
            yield from self.api.write(f"pod/{fn}/{sid}",
                                      kb=c.small_object_kb)   # binding
            yield from self._watch()
            # kubelet boots user container then the queue-proxy sidecar,
            # sequentially, then both pass readiness probes (Fig 1)
            yield self.env.timeout(c.kubelet_sync_period * self._rng.random())
            lock = self._worker_kernel_locks[wid]
            for _ in range(2):
                yield lock.acquire()
                try:
                    # simlint: ok(held-lock-timeout): modeled kernel hold
                    yield self.env.timeout(0.052)
                finally:
                    lock.release()
                boot = self._rng.lognormal(c.user_container_create - 0.052, 0.3)
                yield self.env.timeout(max(boot, 1e-4))
            yield self.env.timeout(c.readiness_probe_wait)
            # kubelet posts pod status (big nested Pod object)
            yield from self.api.rmw(f"pod/{fn}/{sid}", self._bucket("kubelet"))
            done_pods.append(Sandbox(
                sandbox_id=sid, function_name=fn, ip=self.workers[wid].ip,
                port=st.function.port, worker_id=wid,
                state=SandboxState.READY))
        finally:
            done_ev.succeed(None)

    def _delete_pod(self, st: KnFunctionState, ep: PodEndpoint) -> Generator:
        fn = st.function.name
        yield from self.api.rmw(f"deploy/{fn}", self._bucket("kpa"))
        yield from self._watch()
        yield from self.api.rmw(f"rs/{fn}", self._bucket("deployment"))
        yield from self._watch()
        yield from self.api.rmw(f"pod/{fn}/{ep.sandbox.sandbox_id}",
                                self._bucket("replicaset"))
        yield from self.api.rmw(f"endpoints/{fn}", self._bucket("endpoints"))
        if ep.in_use == 0:
            st.endpoints.pop(ep.sandbox.sandbox_id, None)
        self.placer.release(ep.sandbox.worker_id,
                            st.function.scaling.cpu_req_millis,
                            st.function.scaling.mem_req_mb)
        self.collector.sandbox_teardowns += 1

    # -- failure injection (paper §5.4) ------------------------------------------------------
    def fail_control_plane(self) -> None:
        """All controller microservices crash; K8s restarts them one by one."""
        self.alive = False
        self.collector.event(self.env.now, "cp-failed", None)
        self.env.process(self._recover_control_plane(), name="kn-cp-recover")

    def _recover_control_plane(self) -> Generator:
        c = self.costs
        yield self.env.timeout(c.pod_restart_delay)
        # each microservice (autoscaler, controller, webhook, activator...)
        # recovers at its own pace; the system serves again when all are up
        yield self.env.timeout(self._rng.uniform(0.5, 1.0)
                               * c.component_recover_spread)
        self.alive = True
        self.collector.event(self.env.now, "cp-recovered", None)

    def fail_data_plane(self) -> Generator:
        """Istio ingress gateway + activator crash (C11: ~15 s recovery)."""
        self.alive = False
        self.collector.event(self.env.now, "dp-failed", None)
        yield self.env.timeout(self.costs.pod_restart_delay)
        yield self.env.timeout(self.costs.istio_gateway_recover)
        self.alive = True
        self.collector.event(self.env.now, "dp-recovered", None)
