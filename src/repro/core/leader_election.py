"""Raft-lite leader election among control-plane replicas (paper §4).

Dirigent uses RAFT for CP leader election and collocates a Redis replica with
each CP replica (the Redis master follows the CP leader). We model the
timing-relevant subset: leader heartbeats, randomized election timeouts, a
single uncontested election round (vote RPCs), and the recovery procedure on
the new leader. The paper's C10 claim: detect + elect + fetch + DP-sync in
~10 ms.
"""
from __future__ import annotations

from typing import Generator, List, Optional, TYPE_CHECKING

from repro.core.costmodel import DirigentCosts
from repro.simcore import Environment

if TYPE_CHECKING:
    from repro.core.control_plane import ControlPlane
    from repro.core.cluster import Cluster


class LeaderElector:
    def __init__(self, env: Environment, cluster: "Cluster",
                 costs: DirigentCosts, enable_hb_sim: bool = True):
        self.env = env
        self.cluster = cluster
        self.costs = costs
        self.enable_hb_sim = enable_hb_sim
        self.term = 0
        self.leader_id: Optional[int] = None
        self._rng = env.rng("raft")
        self._monitor = None

    def bootstrap(self) -> None:
        """Initial election at cluster start (replica 0 wins)."""
        alive = self.cluster.control_planes_alive()
        if not alive:
            return
        self.term += 1
        leader = alive[0]
        self.leader_id = leader.cp_id
        leader.start_leader()
        if self.enable_hb_sim:
            self._monitor = self.env.process(self._monitor_loop(),
                                             name="raft-monitor")

    def _monitor_loop(self) -> Generator:
        """Followers' view: check leader liveness every heartbeat period."""
        c = self.costs
        while True:
            yield self.env.timeout(c.raft_heartbeat_period)
            leader = self.cluster.control_plane_by_id(self.leader_id)
            if leader is None or not leader.alive:
                # randomized election timeout, then a vote round
                yield self.env.timeout(
                    self._rng.uniform(0.5, 1.0) * c.raft_election_timeout)
                yield from self._elect()

    def _elect(self) -> Generator:
        alive = self.cluster.control_planes_alive()
        if not alive:
            self.leader_id = None
            return
        self.term += 1
        # one round of RequestVote RPCs among the survivors
        yield self.env.timeout(self.costs.raft_election_cost)
        new_leader = alive[0]
        self.leader_id = new_leader.cp_id
        self.cluster.collector.event(self.env.now, "leader-elected",
                                     new_leader.cp_id)
        yield from new_leader.recover_as_leader()
