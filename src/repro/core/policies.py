"""Pluggable scheduling policies (paper §4).

"Dirigent supports Hermod [56] and CH-RLU [50] scheduling policies, though
they are unused in our evaluation to ensure a fair comparison to Knative.
Implementing new scheduling policies and metrics involves extending the
relevant Go interfaces" — this module is that interface surface, in Python:

  * load balancing (data plane): ``least_loaded`` (Knative default, used by
    every benchmark), ``ch_rlu`` (consistent hashing with bounded loads and
    warm-locality preference, after Fuerst & Sharma HPDC'22), ``random``;
  * placement (control plane): ``balanced`` (kube-scheduler default, used by
    every benchmark), ``hermod_packing`` (Hermod's hybrid: pack onto the
    busiest node that still fits, keeping other nodes free for bursts),
    ``random``; plus ``partitioned`` (Archipelago-style sharded placement for
    the 5000-worker regime — a placer structure, not a scoring function; see
    core/placement.py).

Three sharding knobs exist and they are different layers:

  * ``placement_policy="partitioned"`` shards only the placer's *score
    index* (a data-structure optimization inside one scheduling domain);
  * ``Cluster(cp_shards=N)`` shards the *control plane itself* — per-shard
    scale locks, autoscale loops, health monitors and endpoint-flush queues
    (core/control_plane.py). With ``cp_shards > 1`` the CP composes a
    ``PartitionedPlacer`` whose partitions align with the CP shards, so any
    scoring policy here runs shard-locally on the hot path; when a shard's
    partition is full, the spill steals capacity from the least-loaded
    foreign shard (with backoff) rather than probing round-robin, and
    ``Cluster(cp_rebalance_enabled=True)`` additionally migrates hot
    functions off overloaded shards (docs/operations.md);
  * ``Cluster(cp_fn_split_enabled=True)`` shards *individual hot functions*:
    when one function's creation load dominates a shard (no whole-function
    move fixes that), its ownership escalates from one shard to a shard-set
    — per-subshard state slices, each creating under its own scale lock on
    its own placer partition — and folds back when the heat decays
    (core/control_plane.py ``FunctionSlice``; docs/operations.md).

Benchmarks keep the Knative-default policies for paper fidelity; the
policies here are selectable via ``Cluster(lb_policy=...)`` /
``Placer(policy=...)`` / ``Cluster(cp_shards=...)`` and covered by
tests/test_policies.py and tests/test_cp_sharding.py.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Optional


# -- load balancing (endpoint selection in the data plane) --------------------

def lb_least_loaded(endpoints: Dict[int, object], fn: str,
                    exclude: Optional[int] = None) -> Optional[object]:
    # direct attribute reads, not the Endpoint.free property: this scan runs
    # once per dispatch over every endpoint of the function and dominated
    # burst-drain wall time; selection (first-seen wins ties, same iteration
    # order) is unchanged
    best = None
    best_in_use = -1
    for sid, ep in endpoints.items():
        if sid == exclude or ep.draining:
            continue
        in_use = ep.in_use
        if in_use < ep.capacity and (best is None or in_use < best_in_use):
            best = ep
            best_in_use = in_use
    return best


def lb_random(endpoints: Dict[int, object], fn: str,
              exclude: Optional[int] = None, _state={"n": 0}) -> Optional[object]:
    free = [ep for sid, ep in endpoints.items()
            if sid != exclude and ep.free > 0]
    if not free:
        return None
    _state["n"] += 1
    return free[_state["n"] % len(free)]


def lb_ch_rlu(endpoints: Dict[int, object], fn: str,
              exclude: Optional[int] = None,
              load_bound: float = 2.0) -> Optional[object]:
    """Consistent hashing with Relaxed Load Upper-bounds (CH-RLU, simplified):
    prefer the ring position hashed from the function name (warm locality —
    the same sandbox keeps serving the function), walking forward when the
    preferred sandbox exceeds the load bound."""
    sids = sorted(sid for sid in endpoints if sid != exclude)
    if not sids:
        return None
    h = int(hashlib.md5(fn.encode()).hexdigest(), 16)
    start = h % len(sids)
    mean_load = max(sum(endpoints[s].in_use for s in sids) / len(sids), 0.25)
    # first pass: bounded-load walk from the preferred position
    for k in range(len(sids)):
        ep = endpoints[sids[(start + k) % len(sids)]]
        if ep.free > 0 and ep.in_use <= load_bound * mean_load:
            return ep
    # relaxed pass: any free slot
    for k in range(len(sids)):
        ep = endpoints[sids[(start + k) % len(sids)]]
        if ep.free > 0:
            return ep
    return None


LB_POLICIES = {
    "least_loaded": lb_least_loaded,
    "ch_rlu": lb_ch_rlu,
    "random": lb_random,
}


# -- placement (worker-node scoring in the control plane) -----------------------

def place_balanced(node, cpu: int, mem: int) -> float:
    """K8s default: least-allocated, balanced across CPU and memory."""
    cpu_frac = (node.cpu_used + cpu) / node.cpu_capacity
    mem_frac = (node.mem_used + mem) / node.mem_capacity
    least_allocated = 1.0 - (cpu_frac + mem_frac) / 2.0
    balance = 1.0 - abs(cpu_frac - mem_frac)
    return 0.75 * least_allocated + 0.25 * balance


def place_hermod(node, cpu: int, mem: int) -> float:
    """Hermod-style hybrid packing: prefer the MOST-utilized node that still
    fits (bin packing keeps whole nodes free, which helps cold-start bursts
    and lets idle nodes power down)."""
    cpu_frac = (node.cpu_used + cpu) / node.cpu_capacity
    mem_frac = (node.mem_used + mem) / node.mem_capacity
    return (cpu_frac + mem_frac) / 2.0


def place_random(node, cpu: int, mem: int, _state={"n": 0}) -> float:
    _state["n"] = (_state["n"] * 1103515245 + 12345) % (1 << 31)
    return _state["n"] / float(1 << 31)


# call-order-dependent scoring cannot be cached in the placer's incremental
# index (core/placement.py falls back to the brute-force scan)
place_random.stateful = True


PLACEMENT_POLICIES = {
    "balanced": place_balanced,
    "hermod_packing": place_hermod,
    "random": place_random,
}
