"""Operations & monitoring (paper §4).

"Dirigent components expose global and per-function metrics (e.g., the
number of in-flight requests, queue depth, and number of successful
invocations) via HTTP" — this module renders that endpoint's payload
(Prometheus text exposition format) from live cluster state, plus the
event-log view used to break down end-to-end function latency.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, List

if TYPE_CHECKING:
    from repro.core.cluster import Cluster


def render_metrics(cluster: "Cluster") -> str:
    """Prometheus-style text exposition of global + per-function metrics."""
    lines: List[str] = []
    c = cluster.collector
    lines.append("# TYPE dirigent_invocations_total counter")
    lines.append(f"dirigent_invocations_total{{status=\"ok\"}} "
                 f"{len(c.completed)}")
    lines.append(f"dirigent_invocations_total{{status=\"failed\"}} "
                 f"{len(c.failed)}")
    lines.append("# TYPE dirigent_sandbox_creations_total counter")
    lines.append(f"dirigent_sandbox_creations_total {c.sandbox_creations}")
    lines.append(f"dirigent_sandbox_teardowns_total {c.sandbox_teardowns}")
    lines.append("# TYPE dirigent_cp_reconciles_total counter")
    lines.append(f"dirigent_cp_reconciles_total {c.reconciles}")
    lines.append("# TYPE dirigent_cp_fn_migrations_total counter")
    lines.append(f"dirigent_cp_fn_migrations_total {c.fn_migrations}")
    lines.append("# TYPE dirigent_cp_fn_splits_total counter")
    lines.append(f"dirigent_cp_fn_splits_total {c.fn_splits}")
    lines.append("# TYPE dirigent_cp_fn_merges_total counter")
    lines.append(f"dirigent_cp_fn_merges_total {c.fn_merges}")
    lines.append("# TYPE dirigent_cp_steals_total counter")
    lines.append(f"dirigent_cp_steals_total {c.steals}")
    lines.append("# TYPE dirigent_cp_steal_probes_total counter")
    lines.append(f"dirigent_cp_steal_probes_total {c.steal_probes}")
    lines.append("# TYPE dirigent_persistent_writes_total counter")
    lines.append(f"dirigent_persistent_writes_total {cluster.store.write_count}")
    store = cluster.store
    lines.append("# TYPE dirigent_store_group_commits_total counter")
    lines.append(f"dirigent_store_group_commits_total {store.group_commits}")
    lines.append("# TYPE dirigent_store_group_commit_batch_size gauge")
    lines.append(f"dirigent_store_group_commit_batch_size "
                 f"{store.last_batch_size}")
    lines.append("# TYPE dirigent_store_checkpoint_epoch gauge")
    lines.append(f"dirigent_store_checkpoint_epoch {store.checkpoint_epoch}")
    # -1 = no checkpoint written yet (or checkpointing disabled)
    ckpt_age = (-1 if store.checkpoint_at is None
                else cluster.env.now - store.checkpoint_at)
    lines.append("# TYPE dirigent_store_checkpoint_age_seconds gauge")
    lines.append(f"dirigent_store_checkpoint_age_seconds {ckpt_age:.6f}")

    leader = cluster.control_plane_leader()
    lines.append("# TYPE dirigent_control_plane_leader gauge")
    lines.append(f"dirigent_control_plane_leader "
                 f"{leader.cp_id if leader else -1}")
    if leader is not None:
        # per-shard CP health: ownership counts, lock queue depth, and the
        # accumulated scale-lock convoy time sharding exists to remove (C1)
        shard_families = [
            ("dirigent_cp_shard_functions", "gauge",
             lambda s: len(s.functions)),
            ("dirigent_cp_shard_workers", "gauge",
             lambda s: len(s.worker_last_hb)),
            ("dirigent_cp_shard_lock_queue", "gauge",
             lambda s: s.scale_lock.queue_len),
            ("dirigent_cp_shard_lock_wait_seconds_total", "counter",
             lambda s: f"{s.lock_wait_s:.6f}"),
            # the rebalancer/steal load signal: recent lock wait + expected
            # wait implied by the current lock queue (docs/operations.md)
            ("dirigent_cp_shard_load", "gauge",
             lambda s: f"{leader.shard_load(s):.6f}"),
        ]
        for family, kind, value in shard_families:
            lines.append(f"# TYPE {family} {kind}")
            for shard in leader.shards:
                lines.append(f"{family}{{shard=\"{shard.shard_id}\"}} "
                             f"{value(shard)}")
        # per-subshard load of split functions (shard-set ownership): how a
        # split function's replicas/creations/heat spread over its set
        split = [(n, st) for n, st in sorted(leader.functions.items())
                 if st.slices is not None]
        if split:
            lines.append("# TYPE dirigent_cp_fn_slice_sandboxes gauge")
            lines.append("# TYPE dirigent_cp_fn_slice_creating gauge")
            lines.append("# TYPE dirigent_cp_fn_slice_heat gauge")
            for name, st in split:
                for k in sorted(st.slices):
                    sl = st.slices[k]
                    tags = f"{{function=\"{name}\",shard=\"{k}\"}}"
                    lines.append(f"dirigent_cp_fn_slice_sandboxes{tags} "
                                 f"{len(sl.sandbox_ids)}")
                    lines.append(f"dirigent_cp_fn_slice_creating{tags} "
                                 f"{sl.creating}")
                    lines.append(f"dirigent_cp_fn_slice_heat{tags} "
                                 f"{sl.heat:.3f}")
        lines.append("# TYPE dirigent_function_ready_sandboxes gauge")
        for name, st in sorted(leader.functions.items()):
            lines.append(f"dirigent_function_ready_sandboxes"
                         f"{{function=\"{name}\"}} {st.ready_count}")
            lines.append(f"dirigent_function_creating"
                         f"{{function=\"{name}\"}} {st.creating}")
    lines.append("# TYPE dirigent_dp_inflight gauge")
    for dp in cluster.data_planes:
        total_inflight = sum(t.inflight for t in dp.tables.values())
        depth = sum(len(t.queue) for t in dp.tables.values())
        lines.append(f"dirigent_dp_inflight{{dp=\"{dp.dp_id}\","
                     f"alive=\"{dp.alive}\"}} {total_inflight}")
        lines.append(f"dirigent_dp_queue_depth{{dp=\"{dp.dp_id}\"}} {depth}")
        # C5 visibility: port-pool occupancy is the warm-path ceiling signal
        lines.append(f"dirigent_dp_ports_in_use{{dp=\"{dp.dp_id}\"}} "
                     f"{dp.ports_in_use}")
        if dp.conn_reuse:
            tags = f"{{dp=\"{dp.dp_id}\"}}"
            lines.append(f"dirigent_dp_conn_open{tags} {dp.conn_open}")
            lines.append(f"dirigent_dp_conn_hits_total{tags} {dp.conn_hits}")
            lines.append(f"dirigent_dp_conn_misses_total{tags} "
                         f"{dp.conn_misses}")
            lines.append(f"dirigent_dp_conn_expired_total{tags} "
                         f"{dp.conn_expired}")
            lines.append(f"dirigent_dp_time_wait_ports{tags} "
                         f"{dp.time_wait_ports}")
        if dp.hedge_after is not None:
            lines.append(f"dirigent_dp_hedged_total{{dp=\"{dp.dp_id}\"}} "
                         f"{dp.hedged}")
    if cluster.fn_dp_table:
        # fn→DP-set steering: which functions are spread, and how wide
        lines.append("# TYPE dirigent_fe_fn_dp_set_size gauge")
        for name, members in sorted(cluster.fn_dp_table.items()):
            lines.append(f"dirigent_fe_fn_dp_set_size"
                         f"{{function=\"{name}\"}} {len(members)}")
    lines.append("# TYPE dirigent_worker_alive gauge")
    alive = sum(1 for w in cluster.workers.values() if w.daemon_alive)
    lines.append(f"dirigent_workers_alive {alive}")
    lines.append(f"dirigent_workers_total {len(cluster.workers)}")
    lb = getattr(cluster, "live_backend", None)
    if lb is not None:
        # live execution mode: real replica population, the shared
        # executable cache's effectiveness (hits = creations that skipped
        # XLA compilation), and wall time spent in real payload execution
        lines.append("# TYPE dirigent_live_replicas gauge")
        lines.append(f"dirigent_live_replicas {lb.replicas_live}")
        lines.append("# TYPE dirigent_live_exec_cache_hits counter")
        lines.append(f"dirigent_live_exec_cache_hits {lb.exec_cache.hits}")
        lines.append("# TYPE dirigent_live_exec_cache_misses counter")
        lines.append(f"dirigent_live_exec_cache_misses "
                     f"{lb.exec_cache.misses}")
        lines.append("# TYPE dirigent_live_invoke_seconds counter")
        lines.append(f"dirigent_live_invoke_seconds "
                     f"{lb.invoke_seconds_total:.6f}")
        lines.append("# TYPE dirigent_live_invocations_total counter")
        lines.append(f"dirigent_live_invocations_total {lb.invokes}")
        lines.append("# TYPE dirigent_live_tokens_total counter")
        lines.append(f"dirigent_live_tokens_total {lb.tokens_total}")
    return "\n".join(lines) + "\n"


def render_event_log(cluster: "Cluster", since: float = 0.0) -> str:
    """Human-readable cluster event log (leader elections, failures,
    recoveries, evictions) — the debugging/latency-breakdown feed."""
    out = []
    for t, kind, detail in cluster.collector.events:
        if t >= since:
            out.append(f"{t:12.4f}s  {kind:<24} {detail}")
    return "\n".join(out) + ("\n" if out else "")
