"""Persistent state store (the "Redis in AOF mode" of the paper, §4).

Two implementations behind one interface:

  * ``SimStore`` — used inside the discrete-event simulation. Writes pay a
    serialized fsync latency plus synchronous replication to standbys; this is
    exactly the cost Dirigent keeps OFF the invocation critical path and the
    C3 ablation puts back on it. Two scale features, both default-off and
    bit-identical off:

      - **group commit** (``group_commit=True``): writers that queue behind an
        in-flight fsync are absorbed into one batch and committed by a single
        fsync + one replication round. Every member still consumes its own
        latency draws from the ``persist`` stream, in arrival order, so the
        RNG stays aligned with the serialized path; the batch settles at the
        slowest member's draw, which means a compaction stall on any one
        member holds the whole batch. ``write_many`` is the bulk-append face
        of the same machinery: a 100k-record boot costs O(batches), not
        O(records), of serialized fsync sim-time.
      - **checkpoints** (``checkpoint_enabled=True``): ``write_checkpoint``
        persists a compacted snapshot of the durable prefixes as one
        ``checkpoint/<epoch>`` record and resets the delta; ``read_checkpoint``
        hands recovery the snapshot plus only the post-checkpoint delta, so a
        new leader no longer replays the full ``worker/`` prefix. Snapshot
        bulk-load is costed per record (``snapshot_load_per_record``) and so
        is a full prefix scan (``read_per_record``) — both default 0.0, which
        keeps the legacy flat-latency reads exactly.

  * ``FileStore`` — a real append-only file store (length-prefixed records,
    replay-on-open, torn-tail truncation, log compaction) used by unit tests
    to validate the recovery semantics on an actual medium. ``SimStore``
    checkpoints and the ``FileStore`` log share one record framing
    (``encode_records``/``iter_records``), so the recovery tests validate
    both on the same format.

Keys are namespaced: ``function/<name>``, ``dataplane/<id>``, ``worker/<id>``.
A write with ``value=None`` is a tombstone (delete).
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import Dict, Generator, Iterator, List, Optional, Tuple

from repro.simcore import Environment, Resource


_REC_HDR = struct.Struct("<IHI")  # crc32, keylen, vallen (0xFFFFFFFF = tombstone)
_TOMBSTONE = 0xFFFFFFFF

# prefixes a leader checkpoint covers (everything recover_as_leader replays)
CHECKPOINT_PREFIXES = ("function/", "shardmap/", "worker/")


def _encode_record(key: str, value: Optional[bytes]) -> bytes:
    kb = key.encode()
    vb = b"" if value is None else value
    vlen = _TOMBSTONE if value is None else len(vb)
    body = kb + vb
    return _REC_HDR.pack(zlib.crc32(body), len(kb), vlen) + body


def iter_records(buf: bytes) -> Iterator[Tuple[str, Optional[bytes], int]]:
    """Yield ``(key, value_or_None, end_offset)`` per valid record, stopping
    at the first torn (short) or corrupt (bad crc) record — everything past
    that point is crash garbage."""
    off = 0
    while off + _REC_HDR.size <= len(buf):
        crc, klen, vlen = _REC_HDR.unpack_from(buf, off)
        body_off = off + _REC_HDR.size
        real_vlen = 0 if vlen == _TOMBSTONE else vlen
        if body_off + klen + real_vlen > len(buf):
            return  # torn tail write
        body = buf[body_off:body_off + klen + real_vlen]
        if zlib.crc32(body) != crc:
            return  # corrupt tail
        key = body[:klen].decode()
        val = None if vlen == _TOMBSTONE else body[klen:]
        off = body_off + klen + real_vlen
        yield key, val, off


def encode_records(records: Dict[str, bytes]) -> bytes:
    """Compacted snapshot payload: live records only, in the shared record
    framing. Used for ``SimStore`` ``checkpoint/<epoch>`` values and for
    ``FileStore`` log compaction."""
    return b"".join(_encode_record(k, v) for k, v in records.items())


def decode_records(buf: bytes) -> Dict[str, bytes]:
    out: Dict[str, bytes] = {}
    for key, val, _ in iter_records(buf):
        if val is None:
            out.pop(key, None)
        else:
            out[key] = val
    return out


class SimStore:
    """Replicated, strongly-consistent KV store with modeled write latency."""

    def __init__(self, env: Environment, fsync_latency: float,
                 replication_latency: float, read_latency: float,
                 n_replicas: int = 3, fsync_sigma: float = 0.4,
                 stall_prob: float = 0.002, stall: float = 0.120,
                 group_commit: bool = False, max_batch: int = 512,
                 read_per_record: float = 0.0,
                 snapshot_load_per_record: float = 0.0,
                 checkpoint_enabled: bool = False):
        self.env = env
        self.fsync_latency = fsync_latency
        self.replication_latency = replication_latency
        self.read_latency = read_latency
        self.fsync_sigma = fsync_sigma
        self.stall_prob = stall_prob
        self.stall = stall
        self.n_replicas = n_replicas
        self.group_commit = group_commit
        self.max_batch = max_batch
        self.read_per_record = read_per_record
        self.snapshot_load_per_record = snapshot_load_per_record
        self.checkpoint_enabled = checkpoint_enabled
        self.data: Dict[str, bytes] = {}
        # The WAL is serialized: one fsync at a time (the contended resource).
        self._wal = env.resource(capacity=1, name="store-wal")
        self._rng = env.rng("persist")
        # checkpoints draw from their own stream: a background snapshot must
        # not shift the per-write draws, or a checkpoint-on run's entire
        # write history diverges from its checkpoint-off twin and the
        # failover pairs stop being creation-for-creation comparable
        self._ckpt_rng = env.rng("persist-ckpt")
        self.write_count = 0
        self.read_count = 0
        # group-commit machinery + counters (idle unless group_commit)
        self._pending: List[Tuple[str, Optional[bytes], Optional[object]]] = []
        self._committing = False
        self.group_commits = 0
        self.group_commit_writes = 0
        self.last_batch_size = 0
        # checkpoint state: epoch of the latest snapshot and the keys written
        # since (the post-checkpoint delta recovery replays per-record);
        # _ckpt_prev_delta holds the superseded slice while a snapshot fsync
        # is in flight, _ckpt_io serializes checkpoints off the WAL path
        self.checkpoint_epoch = 0
        self.checkpoint_at: Optional[float] = None
        self._ckpt_delta: Dict[str, Optional[bytes]] = {}
        self._ckpt_prev_delta: Optional[Dict[str, Optional[bytes]]] = None
        self._ckpt_io = env.resource(capacity=1, name="store-ckpt-io")

    # -- write paths ----------------------------------------------------------------

    def write(self, key: str, value: Optional[bytes]) -> Generator:
        """Process-style write: ``yield from store.write(k, v)``."""
        if self.group_commit:
            yield from self._write_grouped(key, value)
            return
        yield self._wal.acquire()
        try:
            # real AOF fsync: lognormal latency + rare rewrite/compaction
            # stalls that hold the WAL (the p99-surge mechanism, C3)
            dt = self._rng.lognormal(self.fsync_latency, self.fsync_sigma)
            if self._rng.random() < self.stall_prob:
                dt += self.stall * (0.5 + self._rng.random())
            yield self.env.timeout(dt)
            if self.n_replicas > 1:
                yield self.env.timeout(self.replication_latency)
            self._apply(key, value)
        finally:
            self._wal.release()

    def write_many(self, items: List[Tuple[str, Optional[bytes]]]) -> Generator:
        """Bulk append. With group commit on, commits in ``max_batch`` chunks
        — one fsync + one replication round each — so bulk registration is
        O(batches) of serialized fsync time. With group commit off it
        degrades to the per-record serialized path, bit-identically."""
        if not self.group_commit:
            for key, value in items:
                yield from self.write(key, value)
            return
        if not items:
            return
        # FIFO commit order: the last record's completion implies the whole
        # bulk landed, so one completion event covers the call
        done = self.env.event()
        last = len(items) - 1
        for i, (key, value) in enumerate(items):
            self._pending.append((key, value, done if i == last else None))
        self._kick_committer()
        yield done

    def _write_grouped(self, key: str, value: Optional[bytes]) -> Generator:
        done = self.env.event()
        self._pending.append((key, value, done))
        self._kick_committer()
        yield done

    def _kick_committer(self) -> None:
        if not self._committing:
            self._committing = True
            self.env.process(self._commit_pending(), name="store-group-commit")

    def _commit_pending(self) -> Generator:
        """Batch committer: whoever is queued when the in-flight fsync
        finishes forms the next batch (classic group commit)."""
        yield self._wal.acquire()
        try:
            while self._pending:
                take = min(len(self._pending), self.max_batch)
                batch = self._pending[:take]
                del self._pending[:take]
                yield from self._commit_batch(batch)
        finally:
            # no yield between the emptiness check above and here, so no
            # writer can slip in unobserved before the committer retires
            self._committing = False
            self._wal.release()

    def _commit_batch(self, batch) -> Generator:
        # one fsync covers the whole batch, but every member still consumes
        # its per-write latency draws (same stream, same arrival order as the
        # serialized path); the batch settles at the slowest member's draw,
        # so a stall draw on ANY member holds every write in the batch
        dt = 0.0
        for _ in batch:
            d = self._rng.lognormal(self.fsync_latency, self.fsync_sigma)
            if self._rng.random() < self.stall_prob:
                d += self.stall * (0.5 + self._rng.random())
            if d > dt:
                dt = d
        yield self.env.timeout(dt)
        if self.n_replicas > 1:
            yield self.env.timeout(self.replication_latency)
        for key, value, _done in batch:
            self._apply(key, value)
        self.group_commits += 1
        self.group_commit_writes += len(batch)
        self.last_batch_size = len(batch)
        for _key, _value, done in batch:
            if done is not None:
                done.succeed(None)

    def _apply(self, key: str, value: Optional[bytes]) -> None:
        if value is None:
            self.data.pop(key, None)
        else:
            self.data[key] = value
        if self.checkpoint_enabled and key.startswith(CHECKPOINT_PREFIXES):
            self._ckpt_delta[key] = value
        self.write_count += 1

    # -- checkpoints ----------------------------------------------------------------

    def write_checkpoint(self) -> Generator:
        """Persist a compacted snapshot of the durable prefixes as one
        ``checkpoint/<epoch>`` record. Like a Redis BGSAVE next to the AOF,
        the snapshot runs on its own I/O path (own serialization resource,
        own RNG stream) and never holds the WAL: the single-threaded event
        loop makes the capture atomically consistent at one instant, and
        blocking writers — or even shifting their latency draws — would make
        a checkpoint-on run's entire write history diverge from its
        checkpoint-off twin. While the snapshot fsync is in flight the
        superseded delta is kept (``_ckpt_prev_delta``): a leader recovering
        mid-checkpoint still sees epoch N plus every write since snapshot N
        was captured."""
        yield self._ckpt_io.acquire()
        try:
            # atomic capture: snapshot + delta handoff at one sim instant
            snap = {k: v for k, v in self.data.items()
                    if k.startswith(CHECKPOINT_PREFIXES)}
            payload = encode_records(snap)
            self._ckpt_prev_delta = self._ckpt_delta
            self._ckpt_delta = {}
            dt = self._ckpt_rng.lognormal(self.fsync_latency,
                                          self.fsync_sigma)
            if self._ckpt_rng.random() < self.stall_prob:
                dt += self.stall * (0.5 + self._ckpt_rng.random())
            dt += self.snapshot_load_per_record * len(snap)
            yield self.env.timeout(dt)
            if self.n_replicas > 1:
                yield self.env.timeout(self.replication_latency)
            self.data.pop(f"checkpoint/{self.checkpoint_epoch}", None)
            self.checkpoint_epoch += 1
            self.data[f"checkpoint/{self.checkpoint_epoch}"] = payload
            self.checkpoint_at = self.env.now
            self._ckpt_prev_delta = None
            self.write_count += 1
        finally:
            self._ckpt_io.release()

    def read_checkpoint(self) -> Generator:
        """Recovery entry: ``(snapshot_records, delta)`` or ``None`` when no
        checkpoint exists yet. The snapshot costs ``snapshot_load_per_record``
        per record (bulk deserialization); the delta costs ``read_per_record``
        per record (per-record WAL-suffix scan)."""
        payload = self.data.get(f"checkpoint/{self.checkpoint_epoch}")
        if payload is None:
            yield self.env.timeout(self.read_latency)
            self.read_count += 1
            return None
        snap = decode_records(payload)
        # a checkpoint fsync may be in flight: the live epoch's delta is the
        # superseded slice plus everything written since the new capture
        delta = dict(self._ckpt_prev_delta or {})
        delta.update(self._ckpt_delta)
        yield self.env.timeout(self.read_latency
                               + self.snapshot_load_per_record * len(snap)
                               + self.read_per_record * len(delta))
        self.read_count += 1
        return snap, delta

    # -- reads ----------------------------------------------------------------------

    def read(self, key: str) -> Generator:
        yield self.env.timeout(self.read_latency)
        self.read_count += 1
        return self.data.get(key)

    def read_prefix(self, prefix: str) -> Generator:
        if self.read_per_record:
            # record-count-proportional scan (the honest model a 100k-record
            # ``worker/`` prefix needs); snapshot taken up front so the cost
            # can depend on the result size
            out = {k: v for k, v in self.data.items() if k.startswith(prefix)}
            yield self.env.timeout(self.read_latency
                                   + self.read_per_record * len(out))
            self.read_count += 1
            return out
        yield self.env.timeout(self.read_latency)
        self.read_count += 1
        return {k: v for k, v in self.data.items() if k.startswith(prefix)}

    # Synchronous views for assertions/tests (no cost):
    def peek(self, key: str) -> Optional[bytes]:
        return self.data.get(key)

    def peek_prefix(self, prefix: str) -> Dict[str, bytes]:
        return {k: v for k, v in self.data.items() if k.startswith(prefix)}


class FileStore:
    """Append-only file-backed store with replay-on-open recovery, torn-tail
    truncation, and snapshot compaction (the on-disk mirror of ``SimStore``
    checkpoints, same record framing)."""

    def __init__(self, path: str, fsync: bool = True,
                 compact_on_open: bool = False,
                 compact_threshold: Optional[int] = None):
        self.path = path
        self.fsync = fsync
        self.compact_threshold = compact_threshold
        self.data: Dict[str, bytes] = {}
        self._fh = None
        self._log_bytes = 0
        self.compactions = 0
        if os.path.exists(path):
            self._replay()
        self._live_bytes = sum(self._rec_size(k, v)
                               for k, v in self.data.items())
        if compact_on_open and self._log_bytes > self._live_bytes:
            self.compact()
        else:
            self._fh = open(path, "ab")

    @staticmethod
    def _rec_size(key: str, value: bytes) -> int:
        return _REC_HDR.size + len(key.encode()) + len(value)

    def _replay(self) -> None:
        with open(self.path, "rb") as fh:
            buf = fh.read()
        valid = 0
        for key, val, end in iter_records(buf):
            if val is None:
                self.data.pop(key, None)
            else:
                self.data[key] = val
            valid = end
        if valid < len(buf):
            # torn/corrupt tail: discarding it logically is not enough — the
            # file must shrink to the last valid record, or post-crash
            # appends land *behind* the garbage and silently vanish on the
            # next replay
            with open(self.path, "r+b") as fh:
                fh.truncate(valid)
        self._log_bytes = valid

    def write(self, key: str, value: Optional[bytes]) -> None:
        rec = _encode_record(key, value)
        self._fh.write(rec)
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        self._log_bytes += len(rec)
        old = self.data.get(key)
        if old is not None:
            self._live_bytes -= self._rec_size(key, old)
        if value is None:
            self.data.pop(key, None)
        else:
            self.data[key] = value
            self._live_bytes += len(rec)
        if (self.compact_threshold is not None
                and self._log_bytes >= self.compact_threshold
                and self._log_bytes >= 2 * self._live_bytes):
            self.compact()

    def compact(self) -> None:
        """Rewrite the log as a compacted snapshot of the live records
        (tombstones and superseded versions dropped): write-to-temp, fsync,
        atomic rename — a crash leaves either the old or the new log."""
        if self._fh:
            self._fh.close()
            self._fh = None
        payload = encode_records(self.data)
        tmp = self.path + ".compact"
        with open(tmp, "wb") as fh:
            fh.write(payload)
            fh.flush()
            if self.fsync:
                os.fsync(fh.fileno())
        os.replace(tmp, self.path)
        self._fh = open(self.path, "ab")
        self._log_bytes = len(payload)
        self._live_bytes = len(payload)
        self.compactions += 1

    def read(self, key: str) -> Optional[bytes]:
        return self.data.get(key)

    def read_prefix(self, prefix: str) -> Dict[str, bytes]:
        return {k: v for k, v in self.data.items() if k.startswith(prefix)}

    def close(self) -> None:
        if self._fh:
            self._fh.close()
            self._fh = None
