"""Persistent state store (the "Redis in AOF mode" of the paper, §4).

Two implementations behind one interface:

  * ``SimStore`` — used inside the discrete-event simulation. Writes pay a
    serialized fsync latency plus synchronous replication to standbys; this is
    exactly the cost Dirigent keeps OFF the invocation critical path and the
    C3 ablation puts back on it.
  * ``FileStore`` — a real append-only file store (length-prefixed records,
    replay-on-open) used by unit tests to validate the recovery semantics on
    an actual medium.

Keys are namespaced: ``function/<name>``, ``dataplane/<id>``, ``worker/<id>``.
A write with ``value=None`` is a tombstone (delete).
"""
from __future__ import annotations

import os
import struct
from typing import Dict, Generator, Optional

from repro.simcore import Environment, Resource


class SimStore:
    """Replicated, strongly-consistent KV store with modeled write latency."""

    def __init__(self, env: Environment, fsync_latency: float,
                 replication_latency: float, read_latency: float,
                 n_replicas: int = 3, fsync_sigma: float = 0.4,
                 stall_prob: float = 0.002, stall: float = 0.120):
        self.env = env
        self.fsync_latency = fsync_latency
        self.replication_latency = replication_latency
        self.read_latency = read_latency
        self.fsync_sigma = fsync_sigma
        self.stall_prob = stall_prob
        self.stall = stall
        self.n_replicas = n_replicas
        self.data: Dict[str, bytes] = {}
        # The WAL is serialized: one fsync at a time (the contended resource).
        self._wal = env.resource(capacity=1, name="store-wal")
        self._rng = env.rng("persist")
        self.write_count = 0
        self.read_count = 0

    def write(self, key: str, value: Optional[bytes]) -> Generator:
        """Process-style write: ``yield from store.write(k, v)``."""
        yield self._wal.acquire()
        try:
            # real AOF fsync: lognormal latency + rare rewrite/compaction
            # stalls that hold the WAL (the p99-surge mechanism, C3)
            dt = self._rng.lognormal(self.fsync_latency, self.fsync_sigma)
            if self._rng.random() < self.stall_prob:
                dt += self.stall * (0.5 + self._rng.random())
            yield self.env.timeout(dt)
            if self.n_replicas > 1:
                yield self.env.timeout(self.replication_latency)
            if value is None:
                self.data.pop(key, None)
            else:
                self.data[key] = value
            self.write_count += 1
        finally:
            self._wal.release()

    def read(self, key: str) -> Generator:
        yield self.env.timeout(self.read_latency)
        self.read_count += 1
        return self.data.get(key)

    def read_prefix(self, prefix: str) -> Generator:
        yield self.env.timeout(self.read_latency)
        self.read_count += 1
        return {k: v for k, v in self.data.items() if k.startswith(prefix)}

    # Synchronous views for assertions/tests (no cost):
    def peek(self, key: str) -> Optional[bytes]:
        return self.data.get(key)

    def peek_prefix(self, prefix: str) -> Dict[str, bytes]:
        return {k: v for k, v in self.data.items() if k.startswith(prefix)}


_REC_HDR = struct.Struct("<IHI")  # crc32, keylen, vallen (0xFFFFFFFF = tombstone)
_TOMBSTONE = 0xFFFFFFFF


class FileStore:
    """Append-only file-backed store with replay-on-open recovery."""

    def __init__(self, path: str, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        self.data: Dict[str, bytes] = {}
        self._fh = None
        if os.path.exists(path):
            self._replay()
        self._fh = open(path, "ab")

    def _replay(self) -> None:
        import zlib
        with open(self.path, "rb") as fh:
            buf = fh.read()
        off = 0
        while off + _REC_HDR.size <= len(buf):
            crc, klen, vlen = _REC_HDR.unpack_from(buf, off)
            off += _REC_HDR.size
            real_vlen = 0 if vlen == _TOMBSTONE else vlen
            if off + klen + real_vlen > len(buf):
                break  # torn tail write: discard
            key = buf[off:off + klen]
            val = buf[off + klen:off + klen + real_vlen]
            body = buf[off:off + klen + real_vlen]
            off += klen + real_vlen
            if zlib.crc32(body) != crc:
                break  # corrupt tail: discard rest
            if vlen == _TOMBSTONE:
                self.data.pop(key.decode(), None)
            else:
                self.data[key.decode()] = val

    def write(self, key: str, value: Optional[bytes]) -> None:
        import zlib
        kb = key.encode()
        vb = b"" if value is None else value
        vlen = _TOMBSTONE if value is None else len(vb)
        body = kb + vb
        rec = _REC_HDR.pack(zlib.crc32(body), len(kb), vlen) + body
        self._fh.write(rec)
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        if value is None:
            self.data.pop(key, None)
        else:
            self.data[key] = value

    def read(self, key: str) -> Optional[bytes]:
        return self.data.get(key)

    def read_prefix(self, prefix: str) -> Dict[str, bytes]:
        return {k: v for k, v in self.data.items() if k.startswith(prefix)}

    def close(self) -> None:
        if self._fh:
            self._fh.close()
            self._fh = None
