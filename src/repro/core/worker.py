"""Worker-node daemon model (paper §4 "Worker node software stack").

Implements the sandbox lifecycle with the two runtimes the paper evaluates:

  * ``containerd``  — lognormal creation latency; a per-node *kernel lock*
    resource serializes part of each creation (Linux net-stack/iptables
    contention — this is what caps the cluster at ~1750 creations/s, C2);
  * ``firecracker`` — microVM snapshot restore, 40 ms median, much smaller
    kernel-serialized section (the control plane becomes the bottleneck, C1).

Each node keeps a pool of pre-created recyclable network configurations
(paper §4): creations take a config from the pool (cheap) or pay the full
Linux network-stack cost when the pool is empty; a background process
recycles configs released by teardowns.

The daemon is distinct from the sandboxes: ``fail_daemon()`` stops heartbeats
and the control API while sandboxes keep serving (paper §5.4 "worker daemon
failure"); ``fail_node()`` additionally kills every sandbox.

Mechanism → paper section map (claim ids C1..C12 as in costmodel.py):

  * ``create_sandbox`` — §4 "Worker node software stack": lognormal runtime
    boot (``containerd_create_median`` ≈ 110 ms, Fig 7's 10–100 ms band;
    ``firecracker_create_median`` ≈ 40 ms snapshot restore, §5.2.3) behind
    the per-node kernel-lock slice (C2: containerd's serialized net-stack /
    iptables work caps 93 nodes at ~1750 creations/s).
  * netns pool (``netcfg_*``) — §4 pre-created recyclable network configs:
    pooled grab ≈ 1 ms on the boot path; an empty pool pays the full Linux
    network-stack cost (60 ms) — the burst cliff the pool exists to hide.
  * ``kill_sandbox`` / recycle — §4 sandbox teardown off the critical path:
    async dismantle (``sandbox_teardown``), config back to the pool.
  * health probes (``health_probe_period``) — §3.4 worker-local liveness:
    the daemon probes its sandboxes and reports losses to the CP, which is
    how sandbox state is *reconstructed* rather than trusted (Table 3).
  * heartbeats — §3.4 failure detection (C9 load side-effect): every beat
    also touches the owning CP shard's shared structures
    (``cp_heartbeat_lock_hold``), degrading creation throughput at 5000
    workers — the contention the sharded CP isolates per shard.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Generator, Optional

from repro.core.abstractions import Sandbox, SandboxState, WorkerNodeInfo
from repro.core.costmodel import DirigentCosts
from repro.simcore import Environment, Interrupt, Store


@dataclass
class SandboxRuntime:
    """A sandbox running on this node."""

    sandbox: Sandbox
    ready: bool = False
    # execution bookkeeping (the DP owns slot accounting; this is ground truth
    # used to fail in-flight requests on node death)
    executing: int = 0


class WorkerDaemon:
    def __init__(self, env: Environment, info: WorkerNodeInfo,
                 costs: DirigentCosts, runtime: str = "firecracker",
                 create_hook: Optional[Callable] = None):
        self.env = env
        self.info = info
        self.costs = costs
        self.runtime = runtime
        self.sandboxes: Dict[int, SandboxRuntime] = {}
        self.daemon_alive = True
        self.node_alive = True
        self.create_hook = create_hook  # live-mode: build the real replica
        self._kernel_lock = env.resource(capacity=1)
        self._netcfg_pool = env.store()
        self._netcfg_outstanding = costs.netcfg_pool_size
        for _ in range(costs.netcfg_pool_size):
            self._netcfg_pool.put(object())
        self._rng = env.rng(f"worker-{info.worker_id}")
        self.creations = 0
        self.slow_factor = 1.0     # straggler injection (tests/benchmarks)
        env.process(self._netcfg_replenisher(), name=f"netcfg-{info.worker_id}")

    def _netcfg_replenisher(self) -> Generator:
        """Background pre-creation keeps the recyclable config pool topped up
        (paper §4: pools of pre-created network configurations)."""
        while True:
            yield self.env.timeout(self.costs.netcfg_replenish_period)
            if self.node_alive and len(self._netcfg_pool) < self.costs.netcfg_pool_size:
                self._netcfg_pool.put(object())

    # -- sandbox lifecycle --------------------------------------------------
    def create_sandbox(self, sandbox: Sandbox) -> Generator:
        """Create + boot a sandbox; returns when it passes health probes."""
        if not (self.daemon_alive and self.node_alive):
            raise RuntimeError("worker daemon unavailable")
        c = self.costs
        rt = SandboxRuntime(sandbox=sandbox)
        self.sandboxes[sandbox.sandbox_id] = rt

        # 1) network configuration: pooled fast path vs full net-stack cost.
        if len(self._netcfg_pool):
            yield self._netcfg_pool.get()
            yield self.env.timeout(c.netcfg_pooled)
        else:
            yield self.env.timeout(c.netcfg_fresh)

        # 2) serialized kernel section (cgroups/netns/iptables updates).
        lock_hold = (c.containerd_kernel_lock if self.runtime == "containerd"
                     else c.firecracker_kernel_lock)
        yield self._kernel_lock.acquire()
        try:
            yield self.env.timeout(lock_hold)
        finally:
            self._kernel_lock.release()

        # 3) parallel portion of the boot (image start / snapshot load).
        if self.runtime == "containerd":
            boot = self._rng.lognormal(c.containerd_create_median
                                       - lock_hold, c.containerd_create_sigma)
        else:
            boot = self._rng.lognormal(c.firecracker_create_median
                                       - lock_hold, c.firecracker_create_sigma)
        yield self.env.timeout(max(boot, 1e-4))

        if self.create_hook is not None:
            self.create_hook(sandbox)

        # 4) health probe: daemon polls every probe period; first probe after
        #    boot completion passes.
        yield self.env.timeout(self._rng.uniform(0, c.health_probe_period))

        if not (self.daemon_alive and self.node_alive):
            raise RuntimeError("worker died during sandbox creation")
        rt.ready = True
        sandbox.state = SandboxState.READY
        self.creations += 1
        return sandbox

    def kill_sandbox(self, sandbox_id: int) -> Generator:
        """Teardown: dismantle fs, netns, cgroups; recycle the net config."""
        rt = self.sandboxes.pop(sandbox_id, None)
        if rt is None:
            return
        yield self.env.timeout(self.costs.sandbox_teardown)
        # recycle the network config back into the pool after a delay
        def recycle(env):
            yield env.timeout(self.costs.netcfg_recycle)
            self._netcfg_pool.put(object())
        self.env.process(recycle(self.env), name="netcfg-recycle")

    def list_sandboxes(self) -> list[Sandbox]:
        """Recovery API: CP reconstructs sandbox state from here (§3.4.1)."""
        return [rt.sandbox for rt in self.sandboxes.values() if rt.ready]

    # -- request execution -----------------------------------------------------
    def execute(self, sandbox_id: int, exec_time: float,
                payload: Optional[Callable] = None) -> Generator:
        """Execute one invocation inside a sandbox."""
        rt = self.sandboxes.get(sandbox_id)
        if rt is None or not rt.ready or not self.node_alive:
            raise RuntimeError("sandbox gone")
        c = self.costs
        rt.executing += 1
        try:
            yield self.env.timeout(c.worker_nat_hop + c.exec_slot_overhead)
            if payload is not None:
                # live mode: run real work; bill its wall time to the clock
                import time
                t0 = time.perf_counter()
                result = payload()
                yield self.env.timeout(time.perf_counter() - t0)
            else:
                result = None
                yield self.env.timeout(exec_time * self.slow_factor)
            if not self.node_alive:
                raise RuntimeError("node failed during execution")
            return result
        finally:
            rt.executing -= 1

    # -- failure injection --------------------------------------------------------
    def fail_daemon(self) -> None:
        self.daemon_alive = False

    def recover_daemon(self) -> None:
        self.daemon_alive = True

    def fail_node(self) -> None:
        self.daemon_alive = False
        self.node_alive = False
        for rt in self.sandboxes.values():
            rt.ready = False
        self.sandboxes.clear()
