"""Worker-node daemon model (paper §4 "Worker node software stack").

Implements the sandbox lifecycle with the two runtimes the paper evaluates:

  * ``containerd``  — lognormal creation latency; a per-node *kernel lock*
    resource serializes part of each creation (Linux net-stack/iptables
    contention — this is what caps the cluster at ~1750 creations/s, C2);
  * ``firecracker`` — microVM snapshot restore, 40 ms median, much smaller
    kernel-serialized section (the control plane becomes the bottleneck, C1).

Each node keeps a pool of pre-created recyclable network configurations
(paper §4): creations take a config from the pool (cheap) or pay the full
Linux network-stack cost when the pool is empty; a background process
recycles configs released by teardowns.

The pool replenisher is *demand-driven and grid-aligned*: instead of a
per-node poll every ``netcfg_replenish_period`` (which at 5000 nodes is
~97% of all simulator events while doing nothing), a refill callback is
scheduled only while the pool is below target, at exactly the instants the
polling loop would have refilled — the tick grid is advanced by the same
repeated float addition the polling loop's ``timeout(period)`` chain
performed, so refill times (and every downstream latency statistic) are
bit-identical while the idle ticks vanish (tests/test_simcore.py pins the
equivalence against a reference polling loop).

The daemon is distinct from the sandboxes: ``fail_daemon()`` stops heartbeats
and the control API while sandboxes keep serving (paper §5.4 "worker daemon
failure"); ``fail_node()`` additionally kills every sandbox.

Mechanism → paper section map (claim ids C1..C12 as in costmodel.py):

  * ``create_sandbox`` — §4 "Worker node software stack": lognormal runtime
    boot (``containerd_create_median`` ≈ 110 ms, Fig 7's 10–100 ms band;
    ``firecracker_create_median`` ≈ 40 ms snapshot restore, §5.2.3) behind
    the per-node kernel-lock slice (C2: containerd's serialized net-stack /
    iptables work caps 93 nodes at ~1750 creations/s).
  * netns pool (``netcfg_*``) — §4 pre-created recyclable network configs:
    pooled grab ≈ 1 ms on the boot path; an empty pool pays the full Linux
    network-stack cost (60 ms) — the burst cliff the pool exists to hide.
  * ``kill_sandbox`` / recycle — §4 sandbox teardown off the critical path:
    async dismantle (``sandbox_teardown``), config back to the pool.
  * health probes (``health_probe_period``) — §3.4 worker-local liveness:
    the daemon probes its sandboxes and reports losses to the CP, which is
    how sandbox state is *reconstructed* rather than trusted (Table 3).
  * heartbeats — §3.4 failure detection (C9 load side-effect): every beat
    also touches the owning CP shard's shared structures
    (``cp_heartbeat_lock_hold``), degrading creation throughput at 5000
    workers — the contention the sharded CP isolates per shard.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Generator, Optional

from repro.core.abstractions import Sandbox, SandboxState, WorkerNodeInfo
from repro.core.costmodel import DirigentCosts
from repro.simcore import Environment, Interrupt, Store


@dataclass
class SandboxRuntime:
    """A sandbox running on this node."""

    sandbox: Sandbox
    ready: bool = False
    # execution bookkeeping (the DP owns slot accounting; this is ground truth
    # used to fail in-flight requests on node death)
    executing: int = 0


class WorkerDaemon:
    def __init__(self, env: Environment, info: WorkerNodeInfo,
                 costs: DirigentCosts, runtime: str = "firecracker",
                 create_hook: Optional[Callable] = None,
                 teardown_hook: Optional[Callable] = None,
                 live_backend: Optional[object] = None):
        self.env = env
        self.info = info
        self.costs = costs
        self.runtime = runtime
        self.sandboxes: Dict[int, SandboxRuntime] = {}
        self.daemon_alive = True
        self.node_alive = True
        self.create_hook = create_hook  # live-mode: build the real replica
        # symmetric reclaim: called as teardown_hook(sandbox_id, drain) —
        # drain=True from graceful kill_sandbox (in-slot live requests
        # finish, the wall-side mirror of the CP's teardown_drain_grace),
        # drain=False from fail_node (in-slot requests fail)
        self.teardown_hook = teardown_hook
        # live-mode invoke path: admit/collect LiveRequests (repro.live)
        self.live_backend = live_backend
        self._kernel_lock = env.resource(
            capacity=1, name=f"kernel-lock-w{info.worker_id}")
        self._netcfg_pool = env.store(name=f"netcfg-w{info.worker_id}")
        for _ in range(costs.netcfg_pool_size):
            self._netcfg_pool.put(object())
        self._rng = env.rng(f"worker-{info.worker_id}")
        self.creations = 0
        self.slow_factor = 1.0     # straggler injection (tests/benchmarks)
        # demand-driven replenisher state: the tick-grid accumulator starts
        # where the old polling process started (daemon construction time)
        # and only ever advances by += period — the identical float-add chain
        # the polling loop's timeout(period) produced, so refill instants
        # match it bit for bit
        self._netcfg_next_tick = env.now
        self._netcfg_refill_pending = False

    def _arm_netcfg_refill(self) -> None:
        """Schedule the next pool refill, iff the pool is below target and no
        refill is already pending (paper §4: pools of pre-created network
        configurations). Costs one heap event per actual refill; a full pool
        costs nothing — the polling loop this replaces burned one event per
        node per 25 ms forever."""
        if self._netcfg_refill_pending or not self.node_alive:
            return
        if len(self._netcfg_pool) >= self.costs.netcfg_pool_size:
            return
        t = self._netcfg_next_tick
        period = self.costs.netcfg_replenish_period
        now = self.env.now
        while t <= now:                  # next grid instant strictly > now
            t += period
        self._netcfg_next_tick = t
        self._netcfg_refill_pending = True
        self.env.schedule_at(t, self._netcfg_refill_fire)

    def _netcfg_refill_fire(self) -> None:
        self._netcfg_refill_pending = False
        if not self.node_alive:
            return
        pool, size = self._netcfg_pool, self.costs.netcfg_pool_size
        if len(pool) < size:
            pool.put(object())
            if len(pool) < size:         # still short: keep walking the grid
                t = self._netcfg_next_tick + self.costs.netcfg_replenish_period
                self._netcfg_next_tick = t
                self._netcfg_refill_pending = True
                self.env.schedule_at(t, self._netcfg_refill_fire)

    # -- sandbox lifecycle --------------------------------------------------
    def create_sandbox(self, sandbox: Sandbox) -> Generator:
        """Create + boot a sandbox; returns when it passes health probes."""
        if not (self.daemon_alive and self.node_alive):
            raise RuntimeError("worker daemon unavailable")
        c = self.costs
        rt = SandboxRuntime(sandbox=sandbox)
        self.sandboxes[sandbox.sandbox_id] = rt

        # 1) network configuration: pooled fast path vs full net-stack cost.
        # Taking a config is the demand signal that arms the (grid-aligned)
        # refill timer; an empty pool is demand too — the polling loop would
        # have refilled at the next tick either way.
        if len(self._netcfg_pool):
            self._netcfg_pool.items.popleft()
            self._arm_netcfg_refill()
            yield self.env.timeout(c.netcfg_pooled)
        else:
            self._arm_netcfg_refill()
            yield self.env.timeout(c.netcfg_fresh)

        # 2) serialized kernel section (cgroups/netns/iptables updates).
        lock_hold = (c.containerd_kernel_lock if self.runtime == "containerd"
                     else c.firecracker_kernel_lock)
        yield self._kernel_lock.acquire()
        try:
            # simlint: ok(held-lock-timeout): modeled kernel critical section
            yield self.env.timeout(lock_hold)
        finally:
            self._kernel_lock.release()

        # 3) parallel portion of the boot (image start / snapshot load).
        if self.runtime == "containerd":
            boot = self._rng.lognormal(c.containerd_create_median
                                       - lock_hold, c.containerd_create_sigma)
        else:
            boot = self._rng.lognormal(c.firecracker_create_median
                                       - lock_hold, c.firecracker_create_sigma)
        yield self.env.timeout(max(boot, 1e-4))

        if self.create_hook is not None:
            self.create_hook(sandbox)

        # 4) health probe: daemon polls every probe period; first probe after
        #    boot completion passes.
        yield self.env.timeout(self._rng.uniform(0, c.health_probe_period))

        if not (self.daemon_alive and self.node_alive):
            raise RuntimeError("worker died during sandbox creation")
        rt.ready = True
        sandbox.state = SandboxState.READY
        self.creations += 1
        return sandbox

    def kill_sandbox(self, sandbox_id: int) -> Generator:
        """Teardown: dismantle fs, netns, cgroups; recycle the net config."""
        rt = self.sandboxes.pop(sandbox_id, None)
        if rt is None:
            return
        if self.teardown_hook is not None:
            # reclaim the live replica with drain semantics: the CP already
            # waited teardown_drain_grace, so remaining in-slot requests are
            # stragglers — finish them rather than fail them
            self.teardown_hook(sandbox_id, True)
        yield self.env.timeout(self.costs.sandbox_teardown)
        # recycle the network config back into the pool after a delay — a
        # plain scheduled callback (one heap event), not a process
        self.env.schedule_at(self.env.now + self.costs.netcfg_recycle,
                             self._netcfg_recycle_fire)

    def _netcfg_recycle_fire(self) -> None:
        self._netcfg_pool.put(object())

    def list_sandboxes(self) -> list[Sandbox]:
        """Recovery API: CP reconstructs sandbox state from here (§3.4.1)."""
        return [rt.sandbox for rt in self.sandboxes.values() if rt.ready]

    # -- request execution -----------------------------------------------------
    def execute(self, sandbox_id: int, exec_time: float,
                payload: Optional[Callable] = None,
                request: Optional[object] = None) -> Generator:
        """Execute one invocation inside a sandbox. ``request`` is a live
        ``LiveRequest`` routed into this sandbox's replica via the worker's
        ``live_backend`` (admit into a batcher slot, collect tokens)."""
        rt = self.sandboxes.get(sandbox_id)
        if rt is None or not rt.ready or not self.node_alive:
            raise RuntimeError("sandbox gone")
        c = self.costs
        rt.executing += 1
        try:
            ticket = None
            if request is not None and self.live_backend is not None:
                # admit BEFORE yielding the dispatch overhead: requests that
                # are concurrent in sim time land in the replica's batcher
                # slots together and share decode steps (the first collect
                # pumps for everyone admitted by then)
                ticket = self.live_backend.admit(sandbox_id, request)
            yield self.env.timeout(c.worker_nat_hop + c.exec_slot_overhead)
            if ticket is not None:
                # live mode: real inference; bill its wall time to the clock
                import time
                t0 = time.perf_counter()  # simlint: ok(wall-clock): live mode bills real work
                result = self.live_backend.collect(ticket)
                yield self.env.timeout(time.perf_counter() - t0)  # simlint: ok(wall-clock): live mode bills real work
                if result.failed:
                    raise RuntimeError(result.failure_reason
                                       or "live request failed")
            elif payload is not None:
                # live mode: run real work; bill its wall time to the clock
                import time
                t0 = time.perf_counter()  # simlint: ok(wall-clock): live mode bills real work
                result = payload()
                yield self.env.timeout(time.perf_counter() - t0)  # simlint: ok(wall-clock): live mode bills real work
            else:
                result = None
                yield self.env.timeout(exec_time * self.slow_factor)
            if not self.node_alive:
                raise RuntimeError("node failed during execution")
            return result
        finally:
            rt.executing -= 1

    # -- failure injection --------------------------------------------------------
    def fail_daemon(self) -> None:
        self.daemon_alive = False

    def recover_daemon(self) -> None:
        self.daemon_alive = True

    def fail_node(self) -> None:
        self.daemon_alive = False
        self.node_alive = False
        for rt in self.sandboxes.values():
            rt.ready = False
        if self.teardown_hook is not None:
            for sid in list(self.sandboxes):
                # node death: no drain — in-slot live requests fail
                self.teardown_hook(sid, False)
        self.sandboxes.clear()
