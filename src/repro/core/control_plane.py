"""Sharded monolithic control plane (paper §3, §5.2.2).

One process-level component containing the state manager, autoscaler, placer
and health monitor as modules that exchange information via in-memory
channels (modeled at ``channel_op`` cost, vs RPC+etcd round-trips in K8s).

Persistence policy (paper Table 3): ``Function``/``DataPlane``/``WorkerNode``
records are written to the replicated store *at registration time*;
``Sandbox`` state and function scheduling metrics are in-memory only and are
reconstructed after failover (from worker nodes / DP traffic). The ablation
flag ``persist_sandbox_state`` puts a durable write back on the cold-start
critical path — reproducing the paper's "Dirigent optimization breakdown".

Sharding (``cp_shards``). The paper identifies Dirigent's own ceiling at
~2500 sandbox creations/s as "access congestion on shared data structures
used for autoscaling" (C1), with heartbeat processing degrading creation
throughput further at 5000 workers (C9). PR 1 sharded the *placer*; this
module shards the control plane itself. The CP is partitioned into
``cp_shards`` internal shards (``ControlPlaneShard``), and each shard owns:

  * its own scale lock (the per-shard slice of the autoscaling structures),
  * its own autoscale loop over the functions it owns,
  * its own health monitor over the workers it owns, and
  * its own CP→DP endpoint-update flush queue.

Functions route to shards through an **indirection table**
(``fn_shard_table``): every installed function gets an entry, seeded with
``simcore.stable_hash(name) % cp_shards``, and the load-adaptive rebalancer
(below) may later repoint it. Workers map to the shard
``worker_id % cp_shards`` — the same partition the ``PartitionedPlacer``
uses, so a shard's sandbox creation scores only its own workers and a
placement never crosses shards on the hot path. Cross-shard concerns take
explicit fan-out paths, each paying ``cp_cross_shard_op`` per foreign shard
touched instead of one global critical section:

  * capacity spill — a shard whose own workers are full *steals* capacity
    from foreign placer shards, probing them least-loaded-first by the same
    per-shard load signal the rebalancer uses; shards that recently failed a
    probe are back-offed to the end of the order, so a saturated cluster
    degrades to the deterministic round-robin probe sequence;
  * worker eviction — the owning shard detects the missed heartbeats, then
    fans the affected functions' reconciles out to their owning shards;
  * function migration — the rebalancer's handoff (quiesce both shards →
    move function state + pending endpoint-flush entries → repoint the
    indirection table → persist the override off the critical path);
  * leader recovery — ``recover_as_leader`` rebuilds every shard's function
    and worker maps from the persisted records in one pass, **including the
    indirection table**: persisted ``shardmap/`` overrides are re-applied so
    a failover does not silently undo the rebalancer's work.

Load-adaptive rebalancing (``cp_rebalance_enabled``, default off). A static
``stable_hash % N`` partition convoys on one shard when function popularity
is skewed (an Azure-style Zipf mix — exactly the regime the paper's 2500
creations/s claim targets). Each shard exports a cheap load signal — an
EWMA of its recent scale-lock wait windows (folded by its health loop) plus
the expected wait implied by the current lock queue — and a periodic
rebalancer loop migrates the hottest functions (by per-function creation
heat) from the hottest shard to the coldest whenever the imbalance exceeds
``cp_rebalance_hot_factor``. Everything is deterministic; knobs live in
``DirigentCosts`` (``cp_rebalance_*``, ``cp_steal_backoff``) and are
documented in docs/operations.md.

Metric ingestion from DPs needs no lock in this model (autoscaler windows
are per-function); the urgent fast path reconciles under the function's
owning shard only. ``cp_shards=1`` (the default) degenerates to exactly the
pre-shard control plane — one lock, one autoscale loop, one health loop, one
flush queue, same event sequence — which tests pin bit-identically against
recorded fig7/fig8 goldens, and with rebalancing off (the default) the
indirection-table path itself is pinned bit-identical to the static-hash CP
at ``cp_shards=4`` (tests/test_cp_sharding.py).
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Generator, List, Optional, Tuple, TYPE_CHECKING

from repro.core.abstractions import (
    Function, Sandbox, SandboxState, WorkerNodeInfo,
)
from repro.core.autoscaler import FunctionAutoscalerState
from repro.core.costmodel import DirigentCosts
from repro.core.metrics import Collector
from repro.core.placement import PartitionedPlacer, make_placer
from repro.simcore import Environment, Interrupt, stable_hash

if TYPE_CHECKING:
    from repro.core.cluster import Cluster


@dataclass
class FunctionState:
    function: Function
    autoscaler: FunctionAutoscalerState
    sandboxes: Dict[int, Sandbox] = field(default_factory=dict)
    creating: int = 0
    # rebalancer signals: ``heat`` counts sandbox creations (the scale-lock
    # work a function charges its shard), halved each rebalance tick;
    # ``cooldown_until`` rate-limits re-migrating the same function
    heat: float = 0.0
    cooldown_until: float = 0.0

    @property
    def ready_count(self) -> int:
        return sum(1 for s in self.sandboxes.values()
                   if s.state == SandboxState.READY)


class ControlPlaneShard:
    """One internal CP shard: the state a single shard owner serializes.

    Everything the pre-shard CP guarded with the one global ``_scale_lock``
    lives here, per shard: the scale lock itself, the functions this shard
    autoscales, the last-heartbeat map for the workers it health-checks, and
    the coalescing CP→DP endpoint-update buffer (updates queued in the same
    event-loop turn ride one batched broadcast per shard).

    ``lock_wait_s`` accumulates time processes spent queued on this shard's
    scale lock — the direct measure of the C1 lock convoy that sharding
    removes (exported via monitoring and the churn benchmark).
    """

    __slots__ = ("shard_id", "functions", "worker_last_hb", "scale_lock",
                 "ep_updates", "ep_flush_scheduled", "lock_wait_s",
                 "lock_wait_snap", "load_ema", "steal_backoff_until")

    def __init__(self, env: Environment, shard_id: int):
        self.shard_id = shard_id
        self.functions: Dict[str, FunctionState] = {}
        self.worker_last_hb: Dict[int, float] = {}
        self.scale_lock = env.resource(capacity=1)
        self.ep_updates: Deque[Tuple[str, str, object, bool]] = deque()
        self.ep_flush_scheduled = False
        self.lock_wait_s = 0.0
        # load-signal window marker: lock wait accumulated before the last
        # rebalance tick is history, not current load
        self.lock_wait_snap = 0.0
        # exponentially-weighted lock-wait window (folded by the shard's
        # health loop every worker_heartbeat_period): bursty workloads make
        # a single window phase-noisy — a shard can look idle the tick after
        # its burst drained — so hot/cold ordering and the steal order use
        # this smoothed view
        self.load_ema = 0.0
        # work-stealing backoff: a failed capacity probe of this shard
        # demotes it to the end of the victim order until this instant
        self.steal_backoff_until = 0.0


class ControlPlane:
    def __init__(self, env: Environment, cp_id: int, costs: DirigentCosts,
                 cluster: "Cluster", store, collector: Collector,
                 persist_sandbox_state: bool = False,
                 placement_policy: str = "balanced",
                 cp_shards: int = 1,
                 rebalance_enabled: bool = False,
                 rebalance_period: Optional[float] = None,
                 rebalance_hot_factor: Optional[float] = None,
                 rebalance_max_moves: Optional[int] = None):
        self.env = env
        self.cp_id = cp_id
        self.costs = costs
        self.cluster = cluster
        self.store = store
        self.collector = collector
        self.persist_sandbox_state = persist_sandbox_state
        self.is_leader = False
        self.alive = True
        # global registry: every function the CP knows, across all shards.
        # Shards additionally hold their owned slice (same FunctionState
        # objects) for their autoscale loops.
        self.functions: Dict[str, FunctionState] = {}
        self.workers: Dict[int, WorkerNodeInfo] = {}
        self.placement_policy = placement_policy
        self.cp_shards = max(1, cp_shards)
        self.shards: List[ControlPlaneShard] = [
            ControlPlaneShard(env, k) for k in range(self.cp_shards)]
        # indirection table: function name -> owning shard id. Seeded with
        # ``stable_hash(name) % cp_shards`` at install; the rebalancer may
        # repoint entries (persisted as ``shardmap/<name>`` overrides).
        self.fn_shard_table: Dict[str, int] = {}
        self.placer = self._make_placer()
        self._sandbox_ids = itertools.count(1)
        self._loops = []
        self.no_downscale_until = 0.0
        # load-adaptive rebalancing knobs (resolved against the cost model;
        # a single shard has nothing to rebalance)
        self.rebalance_enabled = bool(rebalance_enabled) and self.cp_shards > 1
        self.rebalance_period = (costs.cp_rebalance_period
                                 if rebalance_period is None
                                 else rebalance_period)
        self.rebalance_hot_factor = (costs.cp_rebalance_hot_factor
                                     if rebalance_hot_factor is None
                                     else rebalance_hot_factor)
        self.rebalance_max_moves = (costs.cp_rebalance_max_moves
                                    if rebalance_max_moves is None
                                    else rebalance_max_moves)
        self._migration_inflight = False

    # -- shard routing ---------------------------------------------------------------
    def _default_shard_id(self, name: str) -> int:
        if self.cp_shards == 1:
            return 0
        return stable_hash(name) % self.cp_shards

    def _fn_shard_id(self, name: str) -> int:
        k = self.fn_shard_table.get(name)
        if k is None:
            k = self._default_shard_id(name)
        return k

    def _fn_shard(self, name: str) -> ControlPlaneShard:
        if self.cp_shards == 1:
            return self.shards[0]
        return self.shards[self._fn_shard_id(name)]

    def shard_load(self, shard: ControlPlaneShard) -> float:
        """Cheap per-shard load signal (seconds of scale-lock pressure):
        the EWMA of recent lock-wait windows plus the expected wait implied
        by the current lock queue. The window/EWMA maintenance rides the
        shard's health loop (always running on a leader, rebalancing on or
        off), so the work-stealing spill and the ``dirigent_cp_shard_load``
        gauge rank shards by *recent* load — not lifetime history. Shared by
        the rebalancer (hot/cold shard selection) and the work-stealing
        spill (least-loaded victim ordering)."""
        return (shard.load_ema
                + shard.scale_lock.queue_len * self.costs.cp_scale_lock_hold)

    def _worker_shard(self, worker_id: int) -> ControlPlaneShard:
        # same partition as PartitionedPlacer._shard, so the workers a shard
        # health-checks are the workers its placer slice scores
        if self.cp_shards == 1:
            return self.shards[0]
        return self.shards[worker_id % self.cp_shards]

    def _make_placer(self):
        if self.cp_shards > 1:
            # PartitionedPlacer normalizes policy="partitioned" itself
            return PartitionedPlacer(policy=self.placement_policy,
                                     n_shards=self.cp_shards)
        return make_placer(self.placement_policy)

    @property
    def worker_last_hb(self) -> Dict[int, float]:
        """Merged last-heartbeat view across shards (diagnostics/tests)."""
        if self.cp_shards == 1:
            return self.shards[0].worker_last_hb
        merged: Dict[int, float] = {}
        for shard in self.shards:
            merged.update(shard.worker_last_hb)
        return merged

    # -- lifecycle -----------------------------------------------------------------
    def start_leader(self) -> None:
        self.is_leader = True
        self._loops = []
        for shard in self.shards:
            self._loops.append(self.env.process(
                self._autoscale_loop(shard),
                name=f"cp{self.cp_id}-autoscale-{shard.shard_id}"))
            self._loops.append(self.env.process(
                self._health_loop(shard),
                name=f"cp{self.cp_id}-health-{shard.shard_id}"))
        if self.rebalance_enabled:
            self._loops.append(self.env.process(
                self._rebalance_loop(),
                name=f"cp{self.cp_id}-rebalance"))

    def stop(self) -> None:
        self.alive = False
        self.is_leader = False
        for p in self._loops:
            p.kill()
        self._loops = []
        for shard in self.shards:
            shard.ep_updates.clear()

    # -- user API --------------------------------------------------------------------
    def install_function(self, fn: Function) -> FunctionState:
        """Insert a function into the registry, the indirection table and its
        owning shard, with no modeled cost (registration bypass for
        benchmarks / recovery)."""
        st = FunctionState(function=fn,
                           autoscaler=FunctionAutoscalerState(fn.scaling))
        self.functions[fn.name] = st
        k = self.fn_shard_table.setdefault(fn.name,
                                           self._default_shard_id(fn.name))
        self.shards[k].functions[fn.name] = st
        return st

    def register_function(self, fn: Function) -> Generator:
        """Register: persist the spec, propagate metadata to DPs (paper: ~2 ms)."""
        yield self.env.timeout(self.costs.grpc_call)          # client -> CP
        yield from self.store.write(f"function/{fn.name}", fn.persisted_record())
        self.install_function(fn)
        # propagate to data planes: one batched broadcast covers every DP
        dps = self.cluster.data_planes_alive()
        if dps:
            yield self.env.timeout(self.costs.grpc_call)
            for dp in dps:
                dp.sync_functions([fn.name])
        return fn.name

    def deregister_function(self, name: str) -> Generator:
        yield from self.store.write(f"function/{name}", None)
        st = self.functions.pop(name, None)
        self._fn_shard(name).functions.pop(name, None)
        k = self.fn_shard_table.pop(name, None)
        if (self.rebalance_enabled and k is not None
                and k != self._default_shard_id(name)):
            # the function had been migrated: drop its durable override too
            yield from self.store.write(f"shardmap/{name}", None)
        if st:
            for sb in list(st.sandboxes.values()):
                yield from self._teardown_sandbox(st, sb)

    # -- component registration ---------------------------------------------------------
    def register_worker(self, info: WorkerNodeInfo) -> Generator:
        yield from self.store.write(f"worker/{info.worker_id}",
                                    info.persisted_record())
        self.workers[info.worker_id] = info
        self._worker_shard(info.worker_id).worker_last_hb[info.worker_id] = \
            self.env.now
        self.placer.add_node(info.worker_id, info.cpu_capacity_millis,
                             info.mem_capacity_mb)

    def register_data_plane(self, dp_info) -> Generator:
        yield from self.store.write(f"dataplane/{dp_info.dp_id}",
                                    dp_info.persisted_record())

    # -- metrics ingestion (from DPs) ------------------------------------------------------
    def receive_metric(self, dp_id: int, fn: str, inflight: int,
                       urgent: bool = False) -> Generator:
        yield self.env.timeout(self.costs.grpc_call)
        if not (self.alive and self.is_leader):
            return
        st = self.functions.get(fn)
        if st is None:
            return
        st.autoscaler.record_metric(self.env.now, float(inflight))
        if urgent:
            # Event-driven fast path: a queue formed with zero free slots.
            yield from self._reconcile_function(fn, st)

    def receive_metric_batch(self, dp_id: int, report: Dict[str, int]) -> Generator:
        yield self.env.timeout(self.costs.grpc_call)
        if not (self.alive and self.is_leader):
            return
        for fn, inflight in report.items():
            st = self.functions.get(fn)
            if st is not None:
                st.autoscaler.record_metric(self.env.now, float(inflight))

    def report_dead_sandbox(self, fn: str, sandbox_id: int) -> Generator:
        """A DP dispatched to a sandbox that is gone (killed behind our back,
        e.g. torn down by a deposed leader, or lost with its node). Reconcile
        it out of the cluster state so routing and capacity self-heal —
        sandbox state is reconstructed from cluster signals, never trusted
        blindly (paper §3.4)."""
        yield self.env.timeout(self.costs.grpc_call)   # DP -> CP report
        if not (self.alive and self.is_leader):
            return
        st = self.functions.get(fn)
        if st is None:
            return
        sb = st.sandboxes.pop(sandbox_id, None)
        if sb is None:
            return
        self.placer.release(sb.worker_id,
                            st.function.scaling.cpu_req_millis,
                            st.function.scaling.mem_req_mb)
        self._queue_endpoint_update("remove", fn, sandbox_id, drain=False)
        yield from self._reconcile_function(fn, st)

    def heartbeat(self, worker_id: int) -> None:
        """Worker heartbeat. Touches the owning shard's health/state slice.

        Contention model (C9): heartbeat processing holds the shard's state
        lock for ``cp_heartbeat_lock_hold``. The hold goes through the
        engine's lazy ``Resource.reserve`` — when the lock is free, the
        12 µs critical section costs *zero* heap events; only a beat that
        actually collides with a creation (or another beat) falls back to a
        real process with the same FIFO queueing and ``lock_wait_s``
        accounting the per-beat sub-process model had."""
        if not self.alive:
            return
        shard = self._worker_shard(worker_id)
        shard.worker_last_hb[worker_id] = self.env.now
        lock = shard.scale_lock
        if lock.reserve(self.env.now + self.costs.cp_heartbeat_lock_hold):
            return

        def hb(env):
            t0 = env.now
            yield lock.acquire()
            shard.lock_wait_s += env.now - t0
            try:
                yield env.timeout(self.costs.cp_heartbeat_lock_hold)
            finally:
                lock.release()
        self.env.process(hb(self.env), name="hb-touch")

    # -- autoscaling ------------------------------------------------------------------------
    def _autoscale_loop(self, shard: ControlPlaneShard) -> Generator:
        while True:
            yield self.env.timeout(self.costs.autoscale_period)
            for fn, st in list(shard.functions.items()):
                yield from self._reconcile_function(fn, st)

    def _reconcile_function(self, fn: str, st: FunctionState) -> Generator:
        """Compute desired scale and act on the difference."""
        yield self.env.timeout(self.costs.cp_sched_cpu)
        self.collector.reconciles += 1
        current = st.ready_count + st.creating
        desired = st.autoscaler.desired(self.env.now, current)
        if self.env.now < self.no_downscale_until:
            desired = max(desired, current)     # post-recovery hold (§3.4.1)
        if desired > current:
            for _ in range(desired - current):
                st.creating += 1
                self.env.process(self._create_sandbox(st),
                                 name=f"create-{fn}")
        elif desired < current:
            victims = self._pick_victims(st, current - desired)
            for sb in victims:
                yield from self._teardown_sandbox(st, sb)

    def _pick_victims(self, st: FunctionState, n: int) -> List[Sandbox]:
        ready = [s for s in st.sandboxes.values()
                 if s.state == SandboxState.READY]
        ready.sort(key=lambda s: -s.sandbox_id)    # newest first
        return ready[:n]

    # -- sandbox creation (the latency-critical path) --------------------------------------------
    def _place(self, shard: ControlPlaneShard, cpu: int, mem: int) -> Generator:
        """Pick a worker for ``shard``'s new sandbox.

        Single-shard CPs score the whole cluster (pre-shard behavior).
        Sharded CPs score their own placer partition — the workers this same
        shard health-checks — so the hot path never leaves the shard; only
        when the shard's workers are full does the placement spill to foreign
        partitions, paying ``cp_cross_shard_op`` per shard probed.

        The spill is *work stealing*: victims are probed least-loaded-first
        by ``shard_load`` (the rebalancer's signal), so a convoy never forms
        on one deterministic victim. A probe that finds no capacity back-offs
        its shard (``cp_steal_backoff``) to the end of the order; ties and
        fully backed-off clusters fall back to the round-robin offset order,
        so a saturated cluster degrades to the pre-steal probe sequence."""
        if self.cp_shards == 1:
            return self.placer.place(cpu, mem)
        k = shard.shard_id
        wid = self.placer.shards[k].place(cpu, mem)
        if wid is not None:
            return wid
        now = self.env.now
        shards = self.shards

        def steal_rank(off: int) -> Tuple[bool, float, int]:
            victim = shards[(k + off) % self.cp_shards]
            return (victim.steal_backoff_until > now,
                    self.shard_load(victim), off)

        for off in sorted(range(1, self.cp_shards), key=steal_rank):
            yield self.env.timeout(self.costs.cp_cross_shard_op)
            self.collector.steal_probes += 1
            victim_id = (k + off) % self.cp_shards
            wid = self.placer.shards[victim_id].place(cpu, mem)
            if wid is not None:
                self.collector.steals += 1
                return wid
            shards[victim_id].steal_backoff_until = \
                self.env.now + self.costs.cp_steal_backoff
        return None

    def _create_sandbox(self, st: FunctionState) -> Generator:
        fn = st.function
        # rebalancer heat: one creation = one scale-lock hold charged to the
        # owning shard on this function's behalf (decayed each rebalance tick)
        st.heat += 1.0
        try:
            # the shard's slice of the autoscaling/cluster-state structures
            # (C1 bottleneck; global when cp_shards == 1). A migration
            # handoff may repoint the function while we queue on the lock —
            # re-check ownership after acquiring and chase the function to
            # its new shard, so a creation never runs against a slice the
            # function left (once we hold the current owner's lock, a
            # further move is impossible: the handoff needs this lock too).
            while True:
                shard = self._fn_shard(fn.name)
                t0 = self.env.now
                yield shard.scale_lock.acquire()
                shard.lock_wait_s += self.env.now - t0
                if self._fn_shard(fn.name) is shard:
                    break
                shard.scale_lock.release()
            try:
                yield self.env.timeout(self.costs.cp_scale_lock_hold)
                wid = yield from self._place(shard, fn.scaling.cpu_req_millis,
                                             fn.scaling.mem_req_mb)
            finally:
                shard.scale_lock.release()
            if wid is None:
                return  # no capacity in the cluster

            sb = Sandbox(
                sandbox_id=next(self._sandbox_ids),
                function_name=fn.name,
                ip=self.workers[wid].ip, port=fn.port, worker_id=wid,
            )
            st.sandboxes[sb.sandbox_id] = sb

            if self.persist_sandbox_state:
                # ABLATION: durable write on the critical path (paper §5.2.1
                # "optimization breakdown") — this is what Dirigent removes.
                yield from self.store.write(f"sandbox/{sb.key}", sb.to_bytes())

            worker = self.cluster.worker_by_id(wid)
            yield self.env.timeout(self.costs.grpc_call)   # CP -> worker
            try:
                yield self.env.process(worker.create_sandbox(sb),
                                       name=f"boot-{sb.key}")
            except (RuntimeError, Interrupt):
                st.sandboxes.pop(sb.sandbox_id, None)
                self.placer.release(wid, fn.scaling.cpu_req_millis,
                                    fn.scaling.mem_req_mb)
                return
            yield self.env.timeout(self.costs.grpc_call)   # ready notification
            if not (self.alive and self.is_leader):
                # leadership lost while the worker booted: this replica's
                # in-memory view is dead weight — undo the placement commit
                # and drop the CREATING record so capacity stays exact
                st.sandboxes.pop(sb.sandbox_id, None)
                self.placer.release(wid, fn.scaling.cpu_req_millis,
                                    fn.scaling.mem_req_mb)
                return
            sb.state = SandboxState.READY
            self.collector.sandbox_creations += 1
            self.collector.event(self.env.now, "sandbox-created", fn.name)
            # in-memory state update; the endpoint rides the next coalesced
            # broadcast (one batched grpc_call for all DPs and all updates
            # queued this turn on this shard)
            yield self.env.timeout(self.costs.channel_op)
            self._queue_endpoint_update("add", fn.name, sb)
        finally:
            st.creating = max(0, st.creating - 1)

    def _teardown_sandbox(self, st: FunctionState, sb: Sandbox) -> Generator:
        # teardown runs in the asynchronous autoscaling loop, off the
        # latency-critical path (paper §4 "Sandbox teardown") — it does not
        # contend the scale lock
        yield self.env.timeout(self.costs.channel_op)
        if st.sandboxes.pop(sb.sandbox_id, None) is None:
            # a concurrent remover (dead-sandbox report, worker eviction,
            # another reconcile) already took it: releasing again would
            # free phantom capacity and overcommit the node
            return
        sb.state = SandboxState.TERMINATING
        if self.persist_sandbox_state:
            yield from self.store.write(f"sandbox/{sb.key}", None)
        self._queue_endpoint_update("remove", st.function.name, sb.sandbox_id)
        worker = self.cluster.worker_by_id(sb.worker_id)
        if worker is not None:
            # drain grace: in-flight requests already dispatched to this
            # sandbox finish before the worker dismantles it
            def drain_then_kill(env, worker=worker, sid=sb.sandbox_id):
                yield env.timeout(self.costs.teardown_drain_grace)
                yield from worker.kill_sandbox(sid)
            self.env.process(drain_then_kill(self.env),
                             name=f"kill-{sb.key}")
        self.placer.release(sb.worker_id,
                            st.function.scaling.cpu_req_millis,
                            st.function.scaling.mem_req_mb)
        self.collector.sandbox_teardowns += 1

    # -- CP -> DP endpoint propagation (coalesced, per shard) -------------------------------------
    def _queue_endpoint_update(self, op: str, fn: str, payload,
                               drain: bool = True) -> None:
        """Buffer an endpoint add/remove on the function's owning shard;
        every update queued on that shard in the same event-loop turn shares
        one batched broadcast to all DPs."""
        shard = self._fn_shard(fn)
        shard.ep_updates.append((op, fn, payload, drain))
        self._schedule_ep_flush(shard)

    def _schedule_ep_flush(self, shard: ControlPlaneShard) -> None:
        if not shard.ep_flush_scheduled:
            shard.ep_flush_scheduled = True
            self.env.process(
                self._flush_endpoint_updates(shard),
                name=f"cp{self.cp_id}-ep-flush-{shard.shard_id}")

    def _flush_endpoint_updates(self, shard: ControlPlaneShard) -> Generator:
        yield self.env.timeout(self.costs.grpc_call)   # one batched broadcast
        updates, shard.ep_updates = shard.ep_updates, deque()
        shard.ep_flush_scheduled = False
        if not self.alive:
            return
        dps = self.cluster.data_planes_alive()
        for op, fn, payload, drain in updates:
            if op == "add":
                # a dethroned leader must not introduce endpoints...
                if self.is_leader:
                    for dp in dps:
                        dp.add_endpoint(fn, payload)
            else:
                # ...but removes are always safe: the sandbox is being killed
                # regardless, and dropping them here would strand a dead
                # endpoint in the DP caches
                for dp in dps:
                    dp.remove_endpoint(fn, payload, drain=drain)

    # -- health monitoring (per shard) -------------------------------------------------------------
    def _health_loop(self, shard: ControlPlaneShard) -> Generator:
        c = self.costs
        while True:
            yield self.env.timeout(c.worker_heartbeat_period)
            # fold the lock-wait window into the shard's load EWMA here —
            # pure arithmetic piggybacked on an existing tick (no new
            # events, so cp_shards=1 stays bit-identical) that runs whether
            # or not the rebalancer is enabled: stealing and monitoring see
            # recent load, not lifetime history
            window = shard.lock_wait_s - shard.lock_wait_snap
            shard.lock_wait_snap = shard.lock_wait_s
            shard.load_ema = 0.7 * shard.load_ema + window
            now = self.env.now
            for wid, last in list(shard.worker_last_hb.items()):
                if now - last > c.worker_heartbeat_timeout:
                    yield from self._evict_worker(shard, wid)

    def _evict_worker(self, shard: ControlPlaneShard, wid: int) -> Generator:
        """Worker declared dead by its owning shard: stop routing, reschedule
        its sandboxes. The dead worker may host sandboxes of functions owned
        by *other* shards (cross-shard capacity spill), so replacing lost
        capacity is an explicit cross-shard fan-out: this shard reconciles
        its own functions inline, and hands each foreign shard that owned an
        affected function a targeted reconcile message (``cp_cross_shard_op``
        each)."""
        shard.worker_last_hb.pop(wid, None)
        self.placer.set_schedulable(wid, False)
        affected: List[tuple] = []
        for fn, st in self.functions.items():
            for sb in [s for s in st.sandboxes.values() if s.worker_id == wid]:
                st.sandboxes.pop(sb.sandbox_id, None)
                affected.append((fn, sb.sandbox_id))
        foreign: Dict[int, List[str]] = {}
        for fn, sid in affected:
            self._queue_endpoint_update("remove", fn, sid, drain=False)
            owner = self._fn_shard(fn)
            if owner is not shard and fn not in foreign.get(owner.shard_id, ()):
                foreign.setdefault(owner.shard_id, []).append(fn)
        self.collector.event(self.env.now, "worker-evicted", wid)
        # re-run autoscaling promptly to replace lost capacity: own functions
        # inline in the health loop (pre-shard behavior when cp_shards == 1)...
        for fn, st in list(shard.functions.items()):
            yield from self._reconcile_function(fn, st)
        # ...affected foreign-owned functions (cross-shard capacity spills)
        # via explicit targeted fan-out; everything else is covered by each
        # shard's own autoscale loop
        for shard_id, fns in foreign.items():
            self.env.process(
                self._cross_shard_reconcile(self.shards[shard_id], fns),
                name=f"cp{self.cp_id}-xshard-{shard_id}")

    def _cross_shard_reconcile(self, shard: ControlPlaneShard,
                               fns: List[str]) -> Generator:
        yield self.env.timeout(self.costs.cp_cross_shard_op)
        for fn in fns:
            st = shard.functions.get(fn)
            if st is None:
                continue
            # unlike the health/autoscale loops, fan-out processes are not in
            # self._loops, so stop() does not kill them — a deposed leader
            # must not keep scaling sandboxes on the shared workers
            if not (self.alive and self.is_leader):
                return
            yield from self._reconcile_function(fn, st)

    def restore_worker(self, wid: int) -> None:
        self._worker_shard(wid).worker_last_hb[wid] = self.env.now
        self.placer.set_schedulable(wid, True)

    # -- load-adaptive shard rebalancing -----------------------------------------------------
    def _rebalance_loop(self) -> Generator:
        """Periodic hot-shard rebalancer (``cp_rebalance_enabled``).

        Each tick: read every shard's smoothed load (the health loops fold
        lock-wait windows into a per-shard EWMA — bursty workloads make a
        single window phase-noisy), and — when the hottest shard's load
        exceeds ``cp_rebalance_hot_factor`` times the coldest's — migrate
        its hottest functions to the coldest shard. Function heat halves
        each tick so the signal tracks *recent* creations. Only one
        migration handoff is in flight at a time; everything is
        deterministic (ties break on shard id / function name)."""
        c = self.costs
        while True:
            yield self.env.timeout(self.rebalance_period)
            if self._migration_inflight:
                self._decay_heat()
                continue
            # the load EWMA itself is maintained by each shard's health loop
            loads = [(self.shard_load(s), s.shard_id) for s in self.shards]
            hot_load, hot_id = max(loads, key=lambda x: (x[0], -x[1]))
            cold_load, cold_id = min(loads)
            if (hot_id == cold_id or hot_load < c.cp_rebalance_min_load
                    or hot_load <= self.rebalance_hot_factor * cold_load):
                self._decay_heat()
                continue
            hot = self.shards[hot_id]
            total_heat = sum(st.heat for st in hot.functions.values())
            # second gate, in *heat* (creation-count) terms: lock wait is
            # superlinear near saturation, so the wait ratio alone can trip
            # on a small real load gap (classic with 2 shards) and migration
            # then just ping-pongs the hotspot. Heat is linear in load —
            # require the same factor there before moving anything.
            cold_heat = sum(st.heat for st in
                            self.shards[cold_id].functions.values())
            if total_heat <= self.rebalance_hot_factor * cold_heat:
                self._decay_heat()
                continue
            names: List[str] = []
            if total_heat > 0.0:
                # move hottest-first, but only functions whose projected load
                # share still closes the hot-cold gap — moving a function
                # whose share exceeds the remaining gap would just relocate
                # (or invert) the hotspot instead of spreading it
                gap = hot_load - cold_load
                movers = sorted(hot.functions.items(),
                                key=lambda kv: (-kv[1].heat, kv[0]))
                now = self.env.now
                moved_heat = 0.0
                for name, st in movers:
                    if len(names) >= self.rebalance_max_moves or st.heat <= 0:
                        break
                    if now < st.cooldown_until:
                        continue
                    fn_load = hot_load * st.heat / total_heat
                    if fn_load >= gap:
                        continue
                    names.append(name)
                    moved_heat += st.heat
                    gap -= 2.0 * fn_load
            self._decay_heat()
            if names:
                self._migration_inflight = True
                self.env.process(
                    self._migrate_functions(
                        hot, self.shards[cold_id], names,
                        ema_delta=hot.load_ema * moved_heat / total_heat),
                    name=f"cp{self.cp_id}-migrate-{hot_id}-{cold_id}")

    def _decay_heat(self) -> None:
        for shard in self.shards:
            for st in shard.functions.values():
                st.heat *= 0.5

    def _migrate_functions(self, src: ControlPlaneShard,
                           dst: ControlPlaneShard,
                           names: List[str],
                           ema_delta: float = 0.0) -> Generator:
        """Explicit migration handoff: quiesce → move → publish → persist.

        Quiesce takes *both* shards' scale locks (in shard-id order, so two
        concurrent handoffs cannot deadlock) — no creation can run against
        either slice while function state moves. The move carries the
        ``FunctionState`` and any endpoint-flush entries still queued for the
        function, then repoints the indirection table. The durable
        ``shardmap/`` override is written only after the locks are released —
        persistence stays off the critical path (paper §3.2), and
        ``recover_as_leader`` replays it so failover keeps the adapted
        partition. A deposed leader aborts without touching shared state."""
        moved: List[str] = []
        try:
            if not (self.alive and self.is_leader):
                return
            first, second = sorted((src, dst), key=lambda s: s.shard_id)
            t0 = self.env.now
            yield first.scale_lock.acquire()
            first.lock_wait_s += self.env.now - t0
            t0 = self.env.now
            yield second.scale_lock.acquire()
            second.lock_wait_s += self.env.now - t0
            try:
                # the handoff hop itself (one cross-shard message)
                yield self.env.timeout(self.costs.cp_cross_shard_op)
                if not (self.alive and self.is_leader):
                    return
                for name in names:
                    st = src.functions.pop(name, None)
                    if st is None:       # deregistered/moved since selection
                        continue
                    dst.functions[name] = st
                    self.fn_shard_table[name] = dst.shard_id
                    st.cooldown_until = (self.env.now
                                         + self.costs.cp_rebalance_cooldown)
                    moved.append(name)
                if moved:
                    # feed the move forward into the smoothed load signal so
                    # the next ticks don't keep draining the same (now
                    # lighter) shard while its EMA still carries the
                    # pre-migration convoy — scaled by what actually moved
                    # (a function deregistered while we queued on the locks
                    # transfers nothing)
                    if ema_delta > 0.0:
                        delta = ema_delta * len(moved) / len(names)
                        src.load_ema -= delta
                        dst.load_ema += delta
                    moved_set = set(moved)
                    carried = [u for u in src.ep_updates
                               if u[1] in moved_set]
                    if carried:
                        # pending endpoint-flush entries follow their function
                        src.ep_updates = deque(
                            u for u in src.ep_updates
                            if u[1] not in moved_set)
                        dst.ep_updates.extend(carried)
                        self._schedule_ep_flush(dst)
                    self.collector.fn_migrations += len(moved)
                    self.collector.event(
                        self.env.now, "fn-migrated",
                        (src.shard_id, dst.shard_id, tuple(moved)))
            finally:
                second.scale_lock.release()
                first.scale_lock.release()
            # durable indirection-table overrides, off the critical path. A
            # move back to the hash-default shard tombstones the override
            # instead (shardmap/ holds only true deviations, so deregister's
            # cleanup check stays exact); a function deregistered while we
            # persisted is skipped rather than resurrected as an orphan.
            for name in moved:
                if not (self.alive and self.is_leader):
                    return
                if name not in self.functions:
                    continue
                value = (None if dst.shard_id == self._default_shard_id(name)
                         else str(dst.shard_id).encode())
                yield from self.store.write(f"shardmap/{name}", value)
        finally:
            self._migration_inflight = False

    # -- failover recovery (new leader) ----------------------------------------------------------
    def recover_as_leader(self) -> Generator:
        """Paper §3.4.1: fetch persisted records, reconnect, reconstruct
        sandbox state from worker nodes asynchronously. Rebuilds every
        shard's function/worker maps from the persisted records — including
        the shard indirection table: install seeds hash defaults, then the
        persisted ``shardmap/`` overrides are replayed so a failover does not
        silently undo the rebalancer's migrations."""
        c = self.costs
        yield self.env.timeout(c.cp_recovery_db_fetch)
        func_records = yield from self.store.read_prefix("function/")
        worker_records = yield from self.store.read_prefix("worker/")
        self.functions = {}
        self.fn_shard_table = {}
        for shard in self.shards:
            shard.functions = {}
            shard.worker_last_hb = {}
        for key, rec in func_records.items():
            self.install_function(Function.from_record(rec))
        if self.rebalance_enabled:
            shardmap = yield from self.store.read_prefix("shardmap/")
            for key, rec in shardmap.items():
                name = key.split("/", 1)[1]
                st = self.functions.get(name)
                try:
                    dst = int(rec.decode())
                except (ValueError, AttributeError):
                    continue
                if st is None or not 0 <= dst < self.cp_shards:
                    continue
                cur = self._fn_shard_id(name)
                if dst != cur:
                    self.shards[cur].functions.pop(name, None)
                    self.shards[dst].functions[name] = st
                self.fn_shard_table[name] = dst
        self.workers = {}
        self.placer = self._make_placer()
        for key, rec in worker_records.items():
            info = WorkerNodeInfo.from_record(rec)
            self.workers[info.worker_id] = info
            self._worker_shard(info.worker_id).worker_last_hb[info.worker_id] \
                = self.env.now
            self.placer.add_node(info.worker_id, info.cpu_capacity_millis,
                                 info.mem_capacity_mb)
        # sync DP caches with the function list
        yield self.env.timeout(c.cp_recovery_dp_sync)
        names = list(self.functions.keys())
        for dp in self.cluster.data_planes_alive():
            dp.sync_functions(names)
        # post-recovery: hold downscaling for one autoscaling window
        self.no_downscale_until = self.env.now + c.recovery_no_downscale
        self.start_leader()
        # async: workers push their sandbox lists; merge as they arrive
        for wid in list(self.workers.keys()):
            self.env.process(self._merge_worker_sandboxes(wid),
                             name=f"merge-{wid}")

    def _merge_worker_sandboxes(self, wid: int) -> Generator:
        yield self.env.timeout(self.costs.grpc_call)
        worker = self.cluster.worker_by_id(wid)
        if worker is None or not worker.daemon_alive:
            return
        for sb in worker.list_sandboxes():
            st = self.functions.get(sb.function_name)
            if st is None:
                continue
            st.sandboxes[sb.sandbox_id] = sb
            self.placer.commit(wid, st.function.scaling.cpu_req_millis,
                               st.function.scaling.mem_req_mb)
            self._queue_endpoint_update("add", sb.function_name, sb)
