"""Sharded monolithic control plane (paper §3, §5.2.2).

One process-level component containing the state manager, autoscaler, placer
and health monitor as modules that exchange information via in-memory
channels (modeled at ``channel_op`` cost, vs RPC+etcd round-trips in K8s).

Persistence policy (paper Table 3): ``Function``/``DataPlane``/``WorkerNode``
records are written to the replicated store *at registration time*;
``Sandbox`` state and function scheduling metrics are in-memory only and are
reconstructed after failover (from worker nodes / DP traffic). The ablation
flag ``persist_sandbox_state`` puts a durable write back on the cold-start
critical path — reproducing the paper's "Dirigent optimization breakdown".

Sharding (``cp_shards``). The paper identifies Dirigent's own ceiling at
~2500 sandbox creations/s as "access congestion on shared data structures
used for autoscaling" (C1), with heartbeat processing degrading creation
throughput further at 5000 workers (C9). PR 1 sharded the *placer*; this
module shards the control plane itself. The CP is partitioned into
``cp_shards`` internal shards (``ControlPlaneShard``), and each shard owns:

  * its own scale lock (the per-shard slice of the autoscaling structures),
  * its own autoscale loop over the functions it owns,
  * its own health monitor over the workers it owns, and
  * its own CP→DP endpoint-update flush queue.

Functions route to shards through an **indirection table**
(``fn_shard_table``): every installed function gets an entry, seeded with
``simcore.stable_hash(name) % cp_shards``, and the load-adaptive rebalancer
(below) may later repoint it. Workers map to the shard
``worker_id % cp_shards`` — the same partition the ``PartitionedPlacer``
uses, so a shard's sandbox creation scores only its own workers and a
placement never crosses shards on the hot path. Cross-shard concerns take
explicit fan-out paths, each paying ``cp_cross_shard_op`` per foreign shard
touched instead of one global critical section:

  * capacity spill — a shard whose own workers are full *steals* capacity
    from foreign placer shards, probing them least-loaded-first by the same
    per-shard load signal the rebalancer uses; shards that recently failed a
    probe are back-offed to the end of the order, so a saturated cluster
    degrades to the deterministic round-robin probe sequence;
  * worker eviction — the owning shard detects the missed heartbeats, then
    fans the affected functions' reconciles out to their owning shards;
  * function migration — the rebalancer's handoff (quiesce both shards →
    move function state + pending endpoint-flush entries → repoint the
    indirection table → persist the override off the critical path);
  * leader recovery — ``recover_as_leader`` rebuilds every shard's function
    and worker maps from the persisted records in one pass, **including the
    indirection table**: persisted ``shardmap/`` overrides are re-applied so
    a failover does not silently undo the rebalancer's work.

Load-adaptive rebalancing (``cp_rebalance_enabled``, default off). A static
``stable_hash % N`` partition convoys on one shard when function popularity
is skewed (an Azure-style Zipf mix — exactly the regime the paper's 2500
creations/s claim targets). Each shard exports a cheap load signal — an
EWMA of its recent scale-lock wait windows (folded by its health loop) plus
the expected wait implied by the current lock queue — and a periodic
rebalancer loop migrates the hottest functions (by per-function creation
heat) from the hottest shard to the coldest whenever the imbalance exceeds
``cp_rebalance_hot_factor``. Everything is deterministic; knobs live in
``DirigentCosts`` (``cp_rebalance_*``, ``cp_steal_backoff``) and are
documented in docs/operations.md.

Per-function creation sharding (``cp_fn_split_enabled``, default off). The
rebalancer moves *whole* functions, so one function whose creation load
alone saturates a scale lock is an irreducible hotspot — no partition of
whole functions fixes it. The escalation generalizes ownership from
``fn→shard`` to ``fn→shard-set``: the indirection-table entry becomes a
tuple (home subshard first), and the function gets one ``FunctionSlice``
per subshard — its own sandbox set, creating count and heat — so each
subshard creates under **its own scale lock** on **its own worker
partition** and flushes endpoints through **its own queue**. The global
``FunctionState`` (one autoscaler state machine, one merged sandbox map)
stays the single source of truth: the home subshard computes the global
desired count once per instant and divides it into per-slice targets by
deterministic round-robin residual assignment (``autoscaler.split_shares``),
so scale-to-zero and eviction reconciles always see a coherent global
replica count. The rebalancer triggers a split when the hot shard's load is
dominated by one function a whole move cannot fix (projected share exceeds
the hot–cold gap), via the migration handoff generalized to shard-sets
(quiesce *all* members in id order → slice → publish the tuple → persist a
``shardmap/<fn>`` shard-set override off the critical path), and merges it
back when slice heat decays below ``cp_fn_split_min_load`` (cooldown on
both edges bounds flapping). ``recover_as_leader`` replays shard-set
overrides, so failover keeps splits.

Metric ingestion from DPs needs no lock in this model (autoscaler windows
are per-function); the urgent fast path reconciles under the function's
owning shard only (all subshards, for a split function). ``cp_shards=1``
(the default) degenerates to exactly the
pre-shard control plane — one lock, one autoscale loop, one health loop, one
flush queue, same event sequence — which tests pin bit-identically against
recorded fig7/fig8 goldens, and with rebalancing off (the default) the
indirection-table path itself is pinned bit-identical to the static-hash CP
at ``cp_shards=4`` (tests/test_cp_sharding.py).
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Generator, List, Optional, Tuple, TYPE_CHECKING

from repro.core.abstractions import (
    Function, Sandbox, SandboxState, WorkerNodeInfo,
)
from repro.core.autoscaler import FunctionAutoscalerState, split_shares
from repro.core.costmodel import DirigentCosts
from repro.core.metrics import Collector
from repro.core.placement import PartitionedPlacer, make_placer
from repro.simcore import Environment, Interrupt, stable_hash

if TYPE_CHECKING:
    from repro.core.cluster import Cluster


@dataclass
class FunctionSlice:
    """One subshard's slice of a *split* function (``cp_fn_split_enabled``).

    The global ``FunctionState`` keeps the authoritative sandbox map and the
    single autoscaler state machine; a slice tracks which of those sandboxes
    this subshard owns, its in-flight creations, its share of the desired
    count (``target``, assigned by the home subshard via round-robin
    residual shares) and its creation heat (the merge signal)."""

    shard_id: int
    sandbox_ids: set = field(default_factory=set)
    creating: int = 0
    heat: float = 0.0
    target: int = 0


@dataclass
class FunctionState:
    function: Function
    autoscaler: FunctionAutoscalerState
    sandboxes: Dict[int, Sandbox] = field(default_factory=dict)
    creating: int = 0
    # rebalancer signals: ``heat`` counts sandbox creations (the scale-lock
    # work a function charges its shard), halved each rebalance tick;
    # ``cooldown_until`` rate-limits re-migrating the same function
    heat: float = 0.0
    cooldown_until: float = 0.0
    # shard-set ownership (None = sole owner, the common case): subshard id
    # -> FunctionSlice while split. ``rr_cursor``/``targets_t`` drive the
    # round-robin residual target assignment; ``split_cooldown_until``
    # applies hysteresis to both the split and the merge edge.
    slices: Optional[Dict[int, FunctionSlice]] = None
    rr_cursor: int = 0
    targets_t: float = -1.0
    split_cooldown_until: float = 0.0

    @property
    def ready_count(self) -> int:
        return sum(1 for s in self.sandboxes.values()
                   if s.state == SandboxState.READY)

    def slice_of(self, sandbox_id: int) -> Optional[FunctionSlice]:
        """The slice owning ``sandbox_id`` (None when unsplit / unowned)."""
        if self.slices:
            for sl in self.slices.values():
                if sandbox_id in sl.sandbox_ids:
                    return sl
        return None

    def slice_ready(self, sl: FunctionSlice) -> int:
        sandboxes = self.sandboxes
        return sum(1 for sid in sl.sandbox_ids
                   if sid in sandboxes
                   and sandboxes[sid].state == SandboxState.READY)

    def drop_sandbox(self, sandbox_id: int) -> Optional[Sandbox]:
        """Remove a sandbox from the global map and its owning slice."""
        sb = self.sandboxes.pop(sandbox_id, None)
        if sb is not None and self.slices:
            for sl in self.slices.values():
                sl.sandbox_ids.discard(sandbox_id)
        return sb


class ControlPlaneShard:
    """One internal CP shard: the state a single shard owner serializes.

    Everything the pre-shard CP guarded with the one global ``_scale_lock``
    lives here, per shard: the scale lock itself, the functions this shard
    autoscales, the last-heartbeat map for the workers it health-checks, and
    the coalescing CP→DP endpoint-update buffer (updates queued in the same
    event-loop turn ride one batched broadcast per shard).

    ``lock_wait_s`` accumulates time processes spent queued on this shard's
    scale lock — the direct measure of the C1 lock convoy that sharding
    removes (exported via monitoring and the churn benchmark).
    """

    __slots__ = ("shard_id", "functions", "worker_last_hb", "scale_lock",
                 "ep_updates", "ep_flush_scheduled", "lock_wait_s",
                 "lock_wait_snap", "load_ema", "steal_backoff_until")

    def __init__(self, env: Environment, shard_id: int):
        self.shard_id = shard_id
        self.functions: Dict[str, FunctionState] = {}
        self.worker_last_hb: Dict[int, float] = {}
        self.scale_lock = env.resource(capacity=1,
                                       name=f"cp-scale-lock-{shard_id}")
        self.ep_updates: Deque[Tuple[str, str, object, bool]] = deque()
        self.ep_flush_scheduled = False
        self.lock_wait_s = 0.0
        # load-signal window marker: lock wait accumulated before the last
        # rebalance tick is history, not current load
        self.lock_wait_snap = 0.0
        # exponentially-weighted lock-wait window (folded by the shard's
        # health loop every worker_heartbeat_period): bursty workloads make
        # a single window phase-noisy — a shard can look idle the tick after
        # its burst drained — so hot/cold ordering and the steal order use
        # this smoothed view
        self.load_ema = 0.0
        # work-stealing backoff: a failed capacity probe of this shard
        # demotes it to the end of the victim order until this instant
        self.steal_backoff_until = 0.0


class ControlPlane:
    def __init__(self, env: Environment, cp_id: int, costs: DirigentCosts,
                 cluster: "Cluster", store, collector: Collector,
                 persist_sandbox_state: bool = False,
                 placement_policy: str = "balanced",
                 cp_shards: int = 1,
                 rebalance_enabled: bool = False,
                 rebalance_period: Optional[float] = None,
                 rebalance_hot_factor: Optional[float] = None,
                 rebalance_max_moves: Optional[int] = None,
                 fn_split_enabled: bool = False,
                 fn_split_max_shards: Optional[int] = None,
                 fn_split_min_load: Optional[float] = None,
                 fn_split_cooldown: Optional[float] = None,
                 ep_flush_coalesce: Optional[bool] = None,
                 incremental_recovery: bool = True,
                 vector_windows: bool = False,
                 batched_eviction: bool = True,
                 checkpoint_enabled: bool = False,
                 checkpoint_period: Optional[float] = None):
        self.env = env
        self.cp_id = cp_id
        self.costs = costs
        self.cluster = cluster
        self.store = store
        self.collector = collector
        self.persist_sandbox_state = persist_sandbox_state
        self.is_leader = False
        self.alive = True
        # global registry: every function the CP knows, across all shards.
        # Shards additionally hold their owned slice (same FunctionState
        # objects) for their autoscale loops.
        self.functions: Dict[str, FunctionState] = {}
        self.workers: Dict[int, WorkerNodeInfo] = {}
        self.placement_policy = placement_policy
        self.cp_shards = max(1, cp_shards)
        self.shards: List[ControlPlaneShard] = [
            ControlPlaneShard(env, k) for k in range(self.cp_shards)]
        # indirection table: function name -> owning shard id. Seeded with
        # ``stable_hash(name) % cp_shards`` at install; the rebalancer may
        # repoint entries (persisted as ``shardmap/<name>`` overrides).
        self.fn_shard_table: Dict[str, int] = {}
        self.placer = self._make_placer()
        # Share the cluster-wide sandbox id counter so ids stay unique across
        # leader epochs (a new leader must not reuse ids already adopted from
        # the deposed one). Standalone CPs fall back to a private counter.
        self._sandbox_ids = getattr(cluster, "_sandbox_ids", None) \
            or itertools.count(1)
        self._loops = []
        self.no_downscale_until = 0.0
        # load-adaptive rebalancing knobs (resolved against the cost model;
        # a single shard has nothing to rebalance)
        self.rebalance_enabled = bool(rebalance_enabled) and self.cp_shards > 1
        self.rebalance_period = (costs.cp_rebalance_period
                                 if rebalance_period is None
                                 else rebalance_period)
        self.rebalance_hot_factor = (costs.cp_rebalance_hot_factor
                                     if rebalance_hot_factor is None
                                     else rebalance_hot_factor)
        self.rebalance_max_moves = (costs.cp_rebalance_max_moves
                                    if rebalance_max_moves is None
                                    else rebalance_max_moves)
        # per-function creation sharding (fn -> shard-set escalation); like
        # rebalancing, meaningless with a single shard
        self.fn_split_enabled = bool(fn_split_enabled) and self.cp_shards > 1
        # clamp: a shard-set needs ≥ 2 members — below that the escalation
        # would select a dominant function every tick (suppressing whole
        # moves for it) yet never be able to split it
        self.fn_split_max_shards = max(2, costs.cp_fn_split_max_shards
                                       if fn_split_max_shards is None
                                       else fn_split_max_shards)
        self.fn_split_min_load = (costs.cp_fn_split_min_load
                                  if fn_split_min_load is None
                                  else fn_split_min_load)
        self.fn_split_cooldown = (costs.cp_fn_split_cooldown
                                  if fn_split_cooldown is None
                                  else fn_split_cooldown)
        self._split_fns: set = set()
        self._migration_inflight = False
        # cross-shard endpoint-flush coalescing: all shards' updates queued
        # in one flush window ride a single combined broadcast (M per-DP
        # deliveries per turn instead of N shards × M DPs). Off by default:
        # the combined flush is one process instead of one per shard, so
        # event counts — and the event-budget pins — shift.
        self.ep_flush_coalesce = (costs.cp_ep_flush_coalesce
                                  if ep_flush_coalesce is None
                                  else ep_flush_coalesce)
        self._ep_flush_pending: List[ControlPlaneShard] = []
        self._ep_flush_scheduled = False
        # incremental failover recovery (recover_as_leader): rebuild the CP
        # per shard, admitting each shard's traffic as its unit completes
        # instead of gating on the full serial replay. A single shard has
        # nothing to parallelize — it takes the serial path.
        self.incremental_recovery = bool(incremental_recovery)
        # array-backed (numpy) autoscaler windows: decision-identical to the
        # deque reference but not bit-identical (pairwise vs sequential
        # summation), so off by default (tests/test_vectorized.py)
        self.vector_windows = bool(vector_windows)
        # batched eviction reconcile: one pass over the functions the dead
        # worker actually hosted instead of every function the owning shard
        # autoscales (legacy path kept as the decision reference)
        self.batched_eviction = bool(batched_eviction)
        # checkpointed recovery: the leader periodically persists a compacted
        # ``checkpoint/<epoch>`` snapshot off the critical path, and
        # recover_as_leader loads snapshot + post-checkpoint delta instead of
        # re-reading the full worker/ prefix. Off by default (the legacy
        # full-prefix replay is what the recovery event-budget pin asserts).
        self.checkpoint_enabled = bool(checkpoint_enabled)
        self.checkpoint_period = (costs.cp_checkpoint_period
                                  if checkpoint_period is None
                                  else checkpoint_period)
        # shard ids still replaying after a failover: traffic to them is not
        # admitted yet (urgent reconciles are deferred to the shard's own
        # autoscale loop, which starts at admission)
        self._recovering_shards: set = set()
        self._recovery_barrier = None

    # -- shard routing ---------------------------------------------------------------
    def _default_shard_id(self, name: str) -> int:
        if self.cp_shards == 1:
            return 0
        return stable_hash(name) % self.cp_shards

    def _fn_shard_id(self, name: str) -> int:
        """Home shard id. Table entries are an ``int`` for a sole owner or a
        tuple (shard-set, home subshard first) for a split function; routing
        that needs *one* shard (ep updates with no slice context, urgent
        reconcile entry, eviction fan-out) goes to the home subshard."""
        k = self.fn_shard_table.get(name)
        if k is None:
            k = self._default_shard_id(name)
        elif type(k) is not int:
            k = k[0]
        return k

    def _fn_shard_ids(self, name: str) -> Tuple[int, ...]:
        """Full owning shard-set (home first); ``(home,)`` when unsplit."""
        k = self.fn_shard_table.get(name)
        if k is None:
            return (self._default_shard_id(name),)
        if type(k) is int:
            return (k,)
        return k

    def _fn_shard(self, name: str) -> ControlPlaneShard:
        if self.cp_shards == 1:
            return self.shards[0]
        return self.shards[self._fn_shard_id(name)]

    def shard_load(self, shard: ControlPlaneShard) -> float:
        """Cheap per-shard load signal (seconds of scale-lock pressure):
        the EWMA of recent lock-wait windows plus the expected wait implied
        by the current lock queue. The window/EWMA maintenance rides the
        shard's health loop (always running on a leader, rebalancing on or
        off), so the work-stealing spill and the ``dirigent_cp_shard_load``
        gauge rank shards by *recent* load — not lifetime history. Shared by
        the rebalancer (hot/cold shard selection) and the work-stealing
        spill (least-loaded victim ordering)."""
        return (shard.load_ema
                + shard.scale_lock.queue_len * self.costs.cp_scale_lock_hold)

    def _worker_shard(self, worker_id: int) -> ControlPlaneShard:
        # same partition as PartitionedPlacer._shard, so the workers a shard
        # health-checks are the workers its placer slice scores
        if self.cp_shards == 1:
            return self.shards[0]
        return self.shards[worker_id % self.cp_shards]

    def _make_placer(self):
        if self.cp_shards > 1:
            # PartitionedPlacer normalizes policy="partitioned" itself
            return PartitionedPlacer(policy=self.placement_policy,
                                     n_shards=self.cp_shards)
        return make_placer(self.placement_policy)

    @property
    def worker_last_hb(self) -> Dict[int, float]:
        """Merged last-heartbeat view across shards (diagnostics/tests)."""
        if self.cp_shards == 1:
            return self.shards[0].worker_last_hb
        merged: Dict[int, float] = {}
        for shard in self.shards:
            merged.update(shard.worker_last_hb)
        return merged

    # -- lifecycle -----------------------------------------------------------------
    def start_leader(self) -> None:
        self.is_leader = True
        self._loops = []
        self._recovering_shards = set()
        for shard in self.shards:
            self._start_shard_loops(shard)
        self._start_global_loops()

    def _start_shard_loops(self, shard: ControlPlaneShard) -> None:
        """Admit one shard: start its autoscale + health loops. Called for
        every shard by ``start_leader``, and per shard by the incremental
        recovery units as each finishes its replay."""
        self._loops.append(self.env.process(
            self._autoscale_loop(shard),
            name=f"cp{self.cp_id}-autoscale-{shard.shard_id}"))
        self._loops.append(self.env.process(
            self._health_loop(shard),
            name=f"cp{self.cp_id}-health-{shard.shard_id}"))

    def _start_global_loops(self) -> None:
        if self.rebalance_enabled or self.fn_split_enabled:
            # the split/merge escalation rides the rebalancer tick; enabling
            # either mechanism starts the loop (each stays gated inside it)
            self._loops.append(self.env.process(
                self._rebalance_loop(),
                name=f"cp{self.cp_id}-rebalance"))
        if self.checkpoint_enabled:
            self._loops.append(self.env.process(
                self._checkpoint_loop(),
                name=f"cp{self.cp_id}-checkpoint"))

    def _checkpoint_loop(self) -> Generator:
        """Leader-only: persist a compacted snapshot every checkpoint period.
        The write itself serializes on the store WAL like any other write —
        off the invocation critical path, but an honest WAL hold."""
        while True:
            yield self.env.timeout(self.checkpoint_period)
            if not (self.alive and self.is_leader):
                return
            yield from self.store.write_checkpoint()
            self.collector.event(self.env.now, "cp-checkpoint",
                                 self.store.checkpoint_epoch)

    def stop(self) -> None:
        self.alive = False
        self.is_leader = False
        for p in self._loops:
            p.kill()
        self._loops = []
        self._recovering_shards = set()
        barrier = getattr(self, "_recovery_barrier", None)
        if barrier is not None:
            self._recovery_barrier = None
            if not barrier.triggered:
                barrier.succeed(None)
        for shard in self.shards:
            shard.ep_updates.clear()
        self._ep_flush_pending.clear()

    # -- user API --------------------------------------------------------------------
    def install_function(self, fn: Function) -> FunctionState:
        """Insert a function into the registry, the indirection table and its
        owning shard, with no modeled cost (registration bypass for
        benchmarks / recovery)."""
        st = FunctionState(function=fn,
                           autoscaler=FunctionAutoscalerState(
                               fn.scaling, vectorized=self.vector_windows))
        k = self.fn_shard_table.setdefault(fn.name,
                                           self._default_shard_id(fn.name))
        if type(k) is not int:
            # re-registering a currently-split function: the fresh state is
            # unsplit, so collapse the shard-set back to its home subshard
            # (consistent table ↔ shard maps; the rebalancer may re-split)
            for sid in k:
                self.shards[sid].functions.pop(fn.name, None)
            self._split_fns.discard(fn.name)
            k = k[0]
            self.fn_shard_table[fn.name] = k
        self.functions[fn.name] = st
        self.shards[k].functions[fn.name] = st
        return st

    def register_function(self, fn: Function) -> Generator:
        """Register: persist the spec, propagate metadata to DPs (paper: ~2 ms)."""
        yield self.env.timeout(self.costs.grpc_call)          # client -> CP
        yield from self.store.write(f"function/{fn.name}", fn.persisted_record())
        self.install_function(fn)
        # propagate to data planes: one batched broadcast covers every DP
        dps = self.cluster.data_planes_alive()
        if dps:
            yield self.env.timeout(self.costs.grpc_call)
            for dp in dps:
                dp.sync_functions([fn.name])
        return fn.name

    def deregister_function(self, name: str) -> Generator:
        yield from self.store.write(f"function/{name}", None)
        st = self.functions.pop(name, None)
        for sid in self._fn_shard_ids(name):
            self.shards[sid].functions.pop(name, None)
        self._split_fns.discard(name)
        k = self.fn_shard_table.pop(name, None)
        if ((self.rebalance_enabled or self.fn_split_enabled)
                and k is not None
                and (type(k) is not int or k != self._default_shard_id(name))):
            # the function had been migrated or split: drop its durable
            # override too
            yield from self.store.write(f"shardmap/{name}", None)
        if st:
            for sb in list(st.sandboxes.values()):
                yield from self._teardown_sandbox(st, sb)

    # -- component registration ---------------------------------------------------------
    def register_worker(self, info: WorkerNodeInfo) -> Generator:
        yield from self.store.write(f"worker/{info.worker_id}",
                                    info.persisted_record())
        self.workers[info.worker_id] = info
        self._worker_shard(info.worker_id).worker_last_hb[info.worker_id] = \
            self.env.now
        self.placer.add_node(info.worker_id, info.cpu_capacity_millis,
                             info.mem_capacity_mb)

    def register_workers_bulk(self, infos: List[WorkerNodeInfo]) -> Generator:
        """Bulk boot registration (group-commit mode): the whole worker log
        lands through ``store.write_many`` in O(batches) group commits, then
        every worker is installed in the same order the serialized loop
        would have used — same workers-map, health-slice and placer insertion
        order, so the two boot paths are equivalence-testable record for
        record."""
        yield from self.store.write_many(
            [(f"worker/{info.worker_id}", info.persisted_record())
             for info in infos])
        for info in infos:
            self.workers[info.worker_id] = info
            self._worker_shard(info.worker_id).worker_last_hb[info.worker_id] \
                = self.env.now
            self.placer.add_node(info.worker_id, info.cpu_capacity_millis,
                                 info.mem_capacity_mb)

    def register_data_plane(self, dp_info) -> Generator:
        yield from self.store.write(f"dataplane/{dp_info.dp_id}",
                                    dp_info.persisted_record())

    # -- metrics ingestion (from DPs) ------------------------------------------------------
    def receive_metric(self, dp_id: int, fn: str, inflight: int,
                       urgent: bool = False) -> Generator:
        yield self.env.timeout(self.costs.grpc_call)
        if not (self.alive and self.is_leader):
            return
        st = self.functions.get(fn)
        if st is None:
            return
        st.autoscaler.record_metric(self.env.now, float(inflight))
        if urgent:
            if (self._recovering_shards
                    and self._fn_shard_id(fn) in self._recovering_shards):
                # mid-recovery: the owning shard has not been admitted yet
                # (its workers may still be replaying — acting now would
                # place against a partial view). Its autoscale loop starts
                # at admission and consumes the window recorded above.
                return
            # Event-driven fast path: a queue formed with zero free slots.
            yield from self._reconcile_function(fn, st)

    def receive_metric_batch(self, dp_id: int, report: Dict[str, int]) -> Generator:
        yield self.env.timeout(self.costs.grpc_call)
        if not (self.alive and self.is_leader):
            return
        for fn, inflight in report.items():
            st = self.functions.get(fn)
            if st is not None:
                st.autoscaler.record_metric(self.env.now, float(inflight))

    def report_dead_sandbox(self, fn: str, sandbox_id: int) -> Generator:
        """A DP dispatched to a sandbox that is gone (killed behind our back,
        e.g. torn down by a deposed leader, or lost with its node). Reconcile
        it out of the cluster state so routing and capacity self-heal —
        sandbox state is reconstructed from cluster signals, never trusted
        blindly (paper §3.4)."""
        yield self.env.timeout(self.costs.grpc_call)   # DP -> CP report
        if not (self.alive and self.is_leader):
            return
        st = self.functions.get(fn)
        if st is None:
            return
        sb = st.drop_sandbox(sandbox_id)
        if sb is None:
            return
        self.placer.release(sb.worker_id,
                            st.function.scaling.cpu_req_millis,
                            st.function.scaling.mem_req_mb)
        self._queue_endpoint_update("remove", fn, sandbox_id, drain=False)
        yield from self._reconcile_function(fn, st)

    def heartbeat(self, worker_id: int) -> None:
        """Worker heartbeat. Touches the owning shard's health/state slice.

        Contention model (C9): heartbeat processing holds the shard's state
        lock for ``cp_heartbeat_lock_hold``. The hold goes through the
        engine's lazy ``Resource.reserve`` — when the lock is free, the
        12 µs critical section costs *zero* heap events; only a beat that
        actually collides with a creation (or another beat) falls back to a
        real process with the same FIFO queueing and ``lock_wait_s``
        accounting the per-beat sub-process model had."""
        if not self.alive:
            return
        shard = self._worker_shard(worker_id)
        shard.worker_last_hb[worker_id] = self.env.now
        lock = shard.scale_lock
        if lock.reserve(self.env.now + self.costs.cp_heartbeat_lock_hold):
            return

        def hb(env):
            t0 = env.now
            yield lock.acquire()
            shard.lock_wait_s += env.now - t0
            try:
                # simlint: ok(held-lock-timeout): modeled C9 heartbeat
                yield env.timeout(self.costs.cp_heartbeat_lock_hold)
            finally:
                lock.release()
        self.env.process(hb(self.env), name="hb-touch")

    def heartbeat_batch(self, worker_ids: List[int]) -> None:
        """Cohort heartbeat (cluster ``hb_cohort_quantum``): the heartbeat
        wheel delivers every beat sharing one quantized deadline as a single
        call, in worker-id order. All ids belong to one CP shard (the wheel
        is per shard), so the C9 contention model becomes ONE contiguous
        lock hold of ``n × cp_heartbeat_lock_hold`` — the same total lock
        time a creation can collide with, without n individual reserves
        landing on the same instant and exploding into n fallback
        sub-processes."""
        if not self.alive or not worker_ids:
            return
        now = self.env.now
        shard = self._worker_shard(worker_ids[0])
        hb_map = shard.worker_last_hb
        for wid in worker_ids:
            hb_map[wid] = now
        hold = self.costs.cp_heartbeat_lock_hold * len(worker_ids)
        lock = shard.scale_lock
        if lock.reserve(now + hold):
            return

        def hb(env):
            t0 = env.now
            yield lock.acquire()
            shard.lock_wait_s += env.now - t0
            try:
                # simlint: ok(held-lock-timeout): modeled C9 cohort hold
                yield env.timeout(hold)
            finally:
                lock.release()
        self.env.process(hb(self.env), name="hb-batch")

    # -- autoscaling ------------------------------------------------------------------------
    def _autoscale_loop(self, shard: ControlPlaneShard) -> Generator:
        while True:
            yield self.env.timeout(self.costs.autoscale_period)
            for fn, st in list(shard.functions.items()):
                yield from self._reconcile_function(fn, st,
                                                    shard_id=shard.shard_id)

    def _reconcile_function(self, fn: str, st: FunctionState,
                            shard_id: Optional[int] = None) -> Generator:
        """Compute desired scale and act on the difference.

        ``shard_id`` is the calling subshard's context (a shard's autoscale
        loop or eviction fan-out); ``None`` means a global caller (urgent
        metric push, dead-sandbox reconcile). Sole owners ignore it; a split
        function acts only on the calling subshard's slice — or on every
        slice for a global caller."""
        yield self.env.timeout(self.costs.cp_sched_cpu)
        self.collector.reconciles += 1
        if st.slices is not None:
            yield from self._reconcile_split(fn, st, shard_id)
            return
        current = st.ready_count + st.creating
        desired = st.autoscaler.desired(self.env.now, current)
        if self.env.now < self.no_downscale_until:
            desired = max(desired, current)     # post-recovery hold (§3.4.1)
        if desired > current:
            for _ in range(desired - current):
                st.creating += 1
                self.env.process(self._create_sandbox(st),
                                 name=f"create-{fn}")
        elif desired < current:
            victims = self._pick_victims(st, current - desired)
            for sb in victims:
                yield from self._teardown_sandbox(st, sb)

    # -- split-function scaling (shard-set ownership) ------------------------------------
    def _split_current(self, st: FunctionState) -> int:
        """Coherent *global* replica count of a split function: every ready
        sandbox (the global map is authoritative) plus in-flight creations —
        per slice, plus any ``st.creating`` leftovers spawned while the
        function was a sole owner (they complete against the global state
        and get adopted into a slice on readiness)."""
        return (st.ready_count + st.creating
                + sum(sl.creating for sl in st.slices.values()))  # simlint: ok(dict-iteration): int sum, order-free

    def _split_targets(self, st: FunctionState) -> None:
        """Recompute per-slice desired shares, at most once per instant.

        One autoscaler state machine serves the whole shard-set: the global
        desired count is computed against the merged replica count (so the
        KPA panic/scale-to-zero logic behaves exactly as for a sole owner)
        and divided into per-slice targets by deterministic round-robin
        residual assignment (``autoscaler.split_shares``); the cursor
        advances by the residual so no subshard permanently carries it.
        Recomputed only by the home subshard's reconcile or a global caller
        — non-home subshards act on their stored target (at most one
        autoscale period stale), which keeps concurrent subshard loops from
        re-deciding the same tick against each other."""
        now = self.env.now
        if st.targets_t == now:
            return
        st.targets_t = now
        slices = st.slices
        current = self._split_current(st)
        desired = st.autoscaler.desired(now, current)
        if now < self.no_downscale_until:
            desired = max(desired, current)     # post-recovery hold (§3.4.1)
        order = sorted(slices)
        shares = split_shares(desired, len(order), st.rr_cursor)
        for i, sid in enumerate(order):
            slices[sid].target = shares[i]
        r = desired % len(order)
        if r:
            st.rr_cursor = (st.rr_cursor + r) % len(order)

    def _reconcile_split(self, fn: str, st: FunctionState,
                         shard_id: Optional[int]) -> Generator:
        home = self._fn_shard_id(fn)
        if shard_id is None or shard_id == home:
            self._split_targets(st)
        if shard_id is not None:
            sl = st.slices.get(shard_id)
            acts = [sl] if sl is not None else []
        else:
            acts = [st.slices[k] for k in sorted(st.slices)]
        desired = sum(s.target for s in st.slices.values())  # simlint: ok(dict-iteration): int sum, order-free
        for sl in acts:
            if st.slices is None or st.slices.get(sl.shard_id) is not sl:
                # the shard-set merged (or re-formed) while a teardown below
                # yielded — the remaining slices no longer exist; the sole-
                # owner path (or the new slices' own reconciles) takes over
                return
            current = st.slice_ready(sl) + sl.creating
            if sl.target > current:
                # cap at the global shortfall: residual rotation between
                # recomputes must not inflate the total replica count
                n = min(sl.target - current,
                        max(0, desired - self._split_current(st)))
                for _ in range(n):
                    sl.creating += 1
                    self.env.process(
                        self._create_sandbox(st, slice_id=sl.shard_id),
                        name=f"create-{fn}")
            elif sl.target < current:
                # symmetric cap: only shed true global excess, so a rotated
                # residual never tears down a replica another slice is
                # creating back
                n = min(current - sl.target,
                        max(0, self._split_current(st) - desired))
                for sb in self._pick_slice_victims(st, sl, n):
                    yield from self._teardown_sandbox(st, sb)

    def _pick_victims(self, st: FunctionState, n: int) -> List[Sandbox]:
        ready = [s for s in st.sandboxes.values()  # simlint: ok(dict-iteration): unique-key sort below erases order
                 if s.state == SandboxState.READY]
        ready.sort(key=lambda s: -s.sandbox_id)    # newest first
        return ready[:n]

    def _pick_slice_victims(self, st: FunctionState, sl: FunctionSlice,
                            n: int) -> List[Sandbox]:
        if n <= 0:
            return []
        # sorted: sandbox_ids is a set; the unique-key sort below erases the
        # iteration order, but a sorted sweep keeps the path replay-stable
        ready = [st.sandboxes[sid] for sid in sorted(sl.sandbox_ids)
                 if sid in st.sandboxes
                 and st.sandboxes[sid].state == SandboxState.READY]
        ready.sort(key=lambda s: -s.sandbox_id)    # newest first
        return ready[:n]

    # -- sandbox creation (the latency-critical path) --------------------------------------------
    def _place(self, shard: ControlPlaneShard, cpu: int, mem: int) -> Generator:
        """Pick a worker for ``shard``'s new sandbox.

        Single-shard CPs score the whole cluster (pre-shard behavior).
        Sharded CPs score their own placer partition — the workers this same
        shard health-checks — so the hot path never leaves the shard; only
        when the shard's workers are full does the placement spill to foreign
        partitions, paying ``cp_cross_shard_op`` per shard probed.

        The spill is *work stealing*: victims are probed least-loaded-first
        by ``shard_load`` (the rebalancer's signal), so a convoy never forms
        on one deterministic victim. A probe that finds no capacity back-offs
        its shard (``cp_steal_backoff``) to the end of the order; ties and
        fully backed-off clusters fall back to the round-robin offset order,
        so a saturated cluster degrades to the pre-steal probe sequence."""
        if self.cp_shards == 1:
            return self.placer.place(cpu, mem)
        k = shard.shard_id
        wid = self.placer.shards[k].place(cpu, mem)
        if wid is not None:
            return wid
        now = self.env.now
        shards = self.shards

        def steal_rank(off: int) -> Tuple[bool, float, int]:
            victim = shards[(k + off) % self.cp_shards]
            return (victim.steal_backoff_until > now,
                    self.shard_load(victim), off)

        for off in sorted(range(1, self.cp_shards), key=steal_rank):
            yield self.env.timeout(self.costs.cp_cross_shard_op)
            self.collector.steal_probes += 1
            victim_id = (k + off) % self.cp_shards
            wid = self.placer.shards[victim_id].place(cpu, mem)
            if wid is not None:
                self.collector.steals += 1
                return wid
            shards[victim_id].steal_backoff_until = \
                self.env.now + self.costs.cp_steal_backoff
        return None

    def _live_slice(self, st: FunctionState, slice_id: Optional[int],
                    sl: Optional[FunctionSlice]) -> bool:
        """Is ``sl`` still the live slice for ``slice_id``? False once the
        shard-set merged (or re-split: a new object under the same id)."""
        return (sl is not None and st.slices is not None
                and st.slices.get(slice_id) is sl)

    def _create_sandbox(self, st: FunctionState,
                        slice_id: Optional[int] = None) -> Generator:
        fn = st.function
        # slice context: a creation spawned for a split function runs against
        # its subshard's lock/partition. If the split dissolved before we got
        # scheduled, fall back to the sole-owner path (the merge already
        # folded our CREATING count into st.creating).
        sl = (st.slices.get(slice_id)
              if slice_id is not None and st.slices is not None else None)
        if sl is None:
            slice_id = None
        # rebalancer heat: one creation = one scale-lock hold charged to the
        # owning (sub)shard on this function's behalf (decayed each tick)
        if sl is None:
            st.heat += 1.0
        else:
            sl.heat += 1.0
        try:
            # the shard's slice of the autoscaling/cluster-state structures
            # (C1 bottleneck; global when cp_shards == 1). A migration
            # handoff may repoint the function while we queue on the lock —
            # re-check ownership after acquiring and chase the function to
            # its new shard, so a creation never runs against a slice the
            # function left (once we hold the current owner's lock, a
            # further move is impossible: the handoff needs this lock too).
            # A split creation re-checks its slice instead: a merge handoff
            # needs every subshard lock, so holding ours pins the slice.
            while True:
                if sl is not None and not self._live_slice(st, slice_id, sl):
                    sl, slice_id = None, None   # merged away while queued
                shard = (self.shards[slice_id] if sl is not None
                         else self._fn_shard(fn.name))
                t0 = self.env.now
                yield shard.scale_lock.acquire()
                shard.lock_wait_s += self.env.now - t0
                if sl is not None:
                    if self._live_slice(st, slice_id, sl):
                        break
                elif self._fn_shard(fn.name) is shard:
                    break
                shard.scale_lock.release()
            try:
                # simlint: ok(held-lock-timeout): modeled scale-lock hold
                yield self.env.timeout(self.costs.cp_scale_lock_hold)
                wid = yield from self._place(shard, fn.scaling.cpu_req_millis,
                                             fn.scaling.mem_req_mb)
            finally:
                shard.scale_lock.release()
            if wid is None:
                return  # no capacity in the cluster

            sb = Sandbox(
                sandbox_id=next(self._sandbox_ids),
                function_name=fn.name,
                ip=self.workers[wid].ip, port=fn.port, worker_id=wid,
            )
            st.sandboxes[sb.sandbox_id] = sb
            if sl is not None and self._live_slice(st, slice_id, sl):
                sl.sandbox_ids.add(sb.sandbox_id)

            if self.persist_sandbox_state:
                # ABLATION: durable write on the critical path (paper §5.2.1
                # "optimization breakdown") — this is what Dirigent removes.
                yield from self.store.write(f"sandbox/{sb.key}", sb.to_bytes())

            worker = self.cluster.worker_by_id(wid)
            yield self.env.timeout(self.costs.grpc_call)   # CP -> worker
            try:
                yield self.env.process(worker.create_sandbox(sb),
                                       name=f"boot-{sb.key}")
            except (RuntimeError, Interrupt):
                st.drop_sandbox(sb.sandbox_id)
                self.placer.release(wid, fn.scaling.cpu_req_millis,
                                    fn.scaling.mem_req_mb)
                return
            yield self.env.timeout(self.costs.grpc_call)   # ready notification
            if not (self.alive and self.is_leader):
                # leadership lost while the worker booted: this replica's
                # in-memory view is dead weight — undo the placement commit
                # and drop the CREATING record so capacity stays exact
                st.drop_sandbox(sb.sandbox_id)
                self.placer.release(wid, fn.scaling.cpu_req_millis,
                                    fn.scaling.mem_req_mb)
                return
            sb.state = SandboxState.READY
            if (st.slices is not None
                    and not self._live_slice(st, slice_id, sl)
                    and st.slice_of(sb.sandbox_id) is None):
                # a sole-owner leftover (or a creation whose slice dissolved
                # and re-split) finishing against a split function: adopt it
                # into a slice so per-slice accounting stays coherent —
                # unless a split handoff that ran mid-boot already
                # partitioned this (then-CREATING) sandbox into a slice
                self._adopt_sandbox(st, sb)
            self.collector.sandbox_creations += 1
            self.collector.event(self.env.now, "sandbox-created", fn.name)
            # in-memory state update; the endpoint rides the next coalesced
            # broadcast (one batched grpc_call for all DPs and all updates
            # queued this turn on this shard)
            yield self.env.timeout(self.costs.channel_op)
            # a split creation's endpoint flushes through the subshard that
            # created it (exactly-once per subshard); sole owners keep the
            # owning-shard routing
            self._queue_endpoint_update(
                "add", fn.name, sb,
                shard=shard if sl is not None else None)
        finally:
            if self._live_slice(st, slice_id, sl):
                sl.creating = max(0, sl.creating - 1)
            else:
                st.creating = max(0, st.creating - 1)

    def _adopt_sandbox(self, st: FunctionState, sb: Sandbox) -> None:
        """Attach an unowned sandbox of a split function to a slice: the
        subshard whose worker partition hosts it, else the home subshard."""
        sl = st.slices.get(sb.worker_id % self.cp_shards)
        if sl is None:
            sl = st.slices[self._fn_shard_id(st.function.name)]
        sl.sandbox_ids.add(sb.sandbox_id)

    def _teardown_sandbox(self, st: FunctionState, sb: Sandbox) -> Generator:
        # teardown runs in the asynchronous autoscaling loop, off the
        # latency-critical path (paper §4 "Sandbox teardown") — it does not
        # contend the scale lock
        yield self.env.timeout(self.costs.channel_op)
        owner_slice = st.slice_of(sb.sandbox_id)   # before the drop
        if st.sandboxes.pop(sb.sandbox_id, None) is None:
            # a concurrent remover (dead-sandbox report, worker eviction,
            # another reconcile) already took it: releasing again would
            # free phantom capacity and overcommit the node
            return
        if owner_slice is not None:
            owner_slice.sandbox_ids.discard(sb.sandbox_id)
        sb.state = SandboxState.TERMINATING
        if self.persist_sandbox_state:
            yield from self.store.write(f"sandbox/{sb.key}", None)
        # a split replica's removal rides its owning subshard's flush queue
        self._queue_endpoint_update(
            "remove", st.function.name, sb.sandbox_id,
            shard=(self.shards[owner_slice.shard_id]
                   if owner_slice is not None else None))
        worker = self.cluster.worker_by_id(sb.worker_id)
        if worker is not None:
            # drain grace: in-flight requests already dispatched to this
            # sandbox finish before the worker dismantles it
            def drain_then_kill(env, worker=worker, sid=sb.sandbox_id):
                yield env.timeout(self.costs.teardown_drain_grace)
                if not (self.alive and self.is_leader):
                    # the kill RPC was never sent: the CP died (or was
                    # deposed) during the drain grace. The sandbox stays up
                    # at the worker; the next leader re-adopts it from the
                    # worker push and owns its lifecycle from there.
                    return
                yield from worker.kill_sandbox(sid)
            self.env.process(drain_then_kill(self.env),
                             name=f"kill-{sb.key}")
        self.placer.release(sb.worker_id,
                            st.function.scaling.cpu_req_millis,
                            st.function.scaling.mem_req_mb)
        self.collector.sandbox_teardowns += 1

    # -- CP -> DP endpoint propagation (coalesced, per shard) -------------------------------------
    def _queue_endpoint_update(self, op: str, fn: str, payload,
                               drain: bool = True,
                               shard: Optional[ControlPlaneShard] = None,
                               ) -> None:
        """Buffer an endpoint add/remove on the function's owning shard —
        or, for a split function's replicas, on the subshard passed by the
        caller (each subshard flushes its own creations/teardowns exactly
        once); every update queued on a shard in the same event-loop turn
        shares one batched broadcast to all DPs."""
        if shard is None:
            shard = self._fn_shard(fn)
        shard.ep_updates.append((op, fn, payload, drain))
        self._schedule_ep_flush(shard)

    def _schedule_ep_flush(self, shard: ControlPlaneShard) -> None:
        if shard.ep_flush_scheduled:
            return
        shard.ep_flush_scheduled = True
        if self.ep_flush_coalesce:
            # cross-shard coalescing: park the shard on the pending list;
            # one combined flush per turn drains every pending shard, so N
            # shards × M DPs costs M per-DP deliveries, not N×M
            self._ep_flush_pending.append(shard)
            if not self._ep_flush_scheduled:
                self._ep_flush_scheduled = True
                self.env.process(
                    self._flush_endpoint_updates_combined(),
                    name=f"cp{self.cp_id}-ep-flush-all")
            return
        self.env.process(
            self._flush_endpoint_updates(shard),
            name=f"cp{self.cp_id}-ep-flush-{shard.shard_id}")

    def _flush_endpoint_updates(self, shard: ControlPlaneShard) -> Generator:
        yield self.env.timeout(self.costs.grpc_call)   # one batched broadcast
        updates, shard.ep_updates = shard.ep_updates, deque()
        shard.ep_flush_scheduled = False
        if not self.alive:
            return
        dps = self.cluster.data_planes_alive()
        self._apply_ep_updates(updates, dps)

    def _flush_endpoint_updates_combined(self) -> Generator:
        """Coalesced variant (``ep_flush_coalesce``): one broadcast carries
        every pending shard's updates, in shard scheduling order — the
        per-update apply order is identical to the per-shard flushes, they
        just share the wire."""
        yield self.env.timeout(self.costs.grpc_call)   # one combined broadcast
        pending, self._ep_flush_pending = self._ep_flush_pending, []
        self._ep_flush_scheduled = False
        batch: List[tuple] = []
        for shard in pending:
            updates, shard.ep_updates = shard.ep_updates, deque()
            shard.ep_flush_scheduled = False
            batch.extend(updates)
        if not self.alive:
            return
        dps = self.cluster.data_planes_alive()
        self._apply_ep_updates(batch, dps)

    def _apply_ep_updates(self, updates, dps) -> None:
        # leadership is stable for the whole batch (pure synchronous applies,
        # no yield): hoist the per-update check out of the per-creation loop
        is_leader = self.is_leader
        for op, fn, payload, drain in updates:
            if op == "add":
                # a dethroned leader must not introduce endpoints...
                if is_leader:
                    for dp in dps:
                        dp.add_endpoint(fn, payload)
            else:
                # ...but removes are always safe: the sandbox is being killed
                # regardless, and dropping them here would strand a dead
                # endpoint in the DP caches
                for dp in dps:
                    dp.remove_endpoint(fn, payload, drain=drain)

    # -- health monitoring (per shard) -------------------------------------------------------------
    def _health_loop(self, shard: ControlPlaneShard) -> Generator:
        c = self.costs
        while True:
            yield self.env.timeout(c.worker_heartbeat_period)
            # fold the lock-wait window into the shard's load EWMA here —
            # pure arithmetic piggybacked on an existing tick (no new
            # events, so cp_shards=1 stays bit-identical) that runs whether
            # or not the rebalancer is enabled: stealing and monitoring see
            # recent load, not lifetime history
            window = shard.lock_wait_s - shard.lock_wait_snap
            shard.lock_wait_snap = shard.lock_wait_s
            shard.load_ema = 0.7 * shard.load_ema + window
            now = self.env.now
            for wid, last in list(shard.worker_last_hb.items()):
                if now - last > c.worker_heartbeat_timeout:
                    yield from self._evict_worker(shard, wid)

    def _evict_worker(self, shard: ControlPlaneShard, wid: int) -> Generator:
        """Worker declared dead by its owning shard: stop routing, reschedule
        its sandboxes. The dead worker may host sandboxes of functions owned
        by *other* shards (cross-shard capacity spill), so replacing lost
        capacity is an explicit cross-shard fan-out: this shard reconciles
        its own functions inline, and hands each foreign shard that owned an
        affected function a targeted reconcile message (``cp_cross_shard_op``
        each)."""
        shard.worker_last_hb.pop(wid, None)
        self.placer.set_schedulable(wid, False)
        affected: List[tuple] = []
        for fn, st in self.functions.items():
            for sb in [s for s in st.sandboxes.values() if s.worker_id == wid]:
                owner_slice = st.slice_of(sb.sandbox_id)
                st.sandboxes.pop(sb.sandbox_id, None)
                if owner_slice is not None:
                    owner_slice.sandbox_ids.discard(sb.sandbox_id)
                affected.append((fn, sb.sandbox_id,
                                 None if owner_slice is None
                                 else owner_slice.shard_id))
        foreign: Dict[int, List[str]] = {}
        for fn, sid, slice_shard in affected:
            # a split function's lost replica is the owning *subshard's* to
            # handle — its endpoint removal rides that slice's flush queue,
            # and the reconcile fan-out targets the slice, not just the home
            owner = (self.shards[slice_shard] if slice_shard is not None
                     else self._fn_shard(fn))
            self._queue_endpoint_update(
                "remove", fn, sid, drain=False,
                shard=self.shards[slice_shard] if slice_shard is not None
                else None)
            if owner is not shard and fn not in foreign.get(owner.shard_id, ()):
                foreign.setdefault(owner.shard_id, []).append(fn)
        self.collector.event(self.env.now, "worker-evicted", wid)
        # re-run autoscaling promptly to replace lost capacity: own functions
        # inline in the health loop (pre-shard behavior when cp_shards == 1)...
        if self.batched_eviction:
            # ...batched: one pass over the own-shard functions that actually
            # lost a replica, in eviction-scan order. Unaffected functions
            # gain nothing from an early reconcile (their replica set did not
            # change; the shard's own autoscale loop covers them), and at
            # 20k+ workers an eviction storm must not re-reconcile every
            # function the shard owns once per dead worker.
            own_affected: List[str] = []
            seen_own: set = set()
            for fn, _sid, slice_shard in affected:
                owner_id = (slice_shard if slice_shard is not None
                            else self._fn_shard_id(fn))
                if owner_id == shard.shard_id and fn not in seen_own:
                    seen_own.add(fn)
                    own_affected.append(fn)
            for fn in own_affected:
                st = shard.functions.get(fn)
                if st is not None:
                    yield from self._reconcile_function(fn, st,
                                                        shard_id=shard.shard_id)
        else:
            # legacy reference path: reconcile every own-shard function
            # (tests/test_vectorized.py pins decision identity against it)
            for fn, st in list(shard.functions.items()):
                yield from self._reconcile_function(fn, st,
                                                    shard_id=shard.shard_id)
        # ...affected foreign-owned functions (cross-shard capacity spills)
        # via explicit targeted fan-out; everything else is covered by each
        # shard's own autoscale loop
        for shard_id, fns in foreign.items():
            self.env.process(
                self._cross_shard_reconcile(self.shards[shard_id], fns),
                name=f"cp{self.cp_id}-xshard-{shard_id}")

    def _cross_shard_reconcile(self, shard: ControlPlaneShard,
                               fns: List[str]) -> Generator:
        yield self.env.timeout(self.costs.cp_cross_shard_op)
        for fn in fns:
            st = shard.functions.get(fn)
            if st is None:
                continue
            # unlike the health/autoscale loops, fan-out processes are not in
            # self._loops, so stop() does not kill them — a deposed leader
            # must not keep scaling sandboxes on the shared workers
            if not (self.alive and self.is_leader):
                return
            yield from self._reconcile_function(fn, st,
                                                shard_id=shard.shard_id)

    def restore_worker(self, wid: int) -> None:
        self._worker_shard(wid).worker_last_hb[wid] = self.env.now
        self.placer.set_schedulable(wid, True)

    # -- load-adaptive shard rebalancing -----------------------------------------------------
    def _rebalance_loop(self) -> Generator:
        """Periodic hot-shard rebalancer (``cp_rebalance_enabled``).

        Each tick: read every shard's smoothed load (the health loops fold
        lock-wait windows into a per-shard EWMA — bursty workloads make a
        single window phase-noisy), and — when the hottest shard's load
        exceeds ``cp_rebalance_hot_factor`` times the coldest's — migrate
        its hottest functions to the coldest shard. Function heat halves
        each tick so the signal tracks *recent* creations. Only one
        migration handoff is in flight at a time; everything is
        deterministic (ties break on shard id / function name)."""
        c = self.costs
        while True:
            yield self.env.timeout(self.rebalance_period)
            if self._migration_inflight:
                self._decay_heat()
                continue
            # merge escalation first: a split function whose heat decayed
            # away folds back to its home shard regardless of the hot/cold
            # gates below (a cooled cluster never trips them)
            if self.fn_split_enabled and self._maybe_merge():
                self._decay_heat()
                continue
            # the load EWMA itself is maintained by each shard's health loop
            loads = [(self.shard_load(s), s.shard_id) for s in self.shards]
            hot_load, hot_id = max(loads, key=lambda x: (x[0], -x[1]))
            cold_load, cold_id = min(loads)
            if (hot_id == cold_id or hot_load < c.cp_rebalance_min_load
                    or hot_load <= self.rebalance_hot_factor * cold_load):
                self._decay_heat()
                continue
            hot = self.shards[hot_id]
            total_heat = sum(self._shard_fn_heat(st, hot_id)
                             for st in hot.functions.values())  # simlint: ok(dict-iteration): float sum; install order is deterministic
            # second gate, in *heat* (creation-count) terms: lock wait is
            # superlinear near saturation, so the wait ratio alone can trip
            # on a small real load gap (classic with 2 shards) and migration
            # then just ping-pongs the hotspot. Heat is linear in load —
            # require the same factor there before moving anything.
            cold_heat = sum(self._shard_fn_heat(st, cold_id)
                            for st in self.shards[cold_id].functions.values())  # simlint: ok(dict-iteration): float sum; install order is deterministic
            if total_heat <= self.rebalance_hot_factor * cold_heat:
                self._decay_heat()
                continue
            names: List[str] = []
            split_name: Optional[str] = None
            moved_heat = 0.0
            if total_heat > 0.0:
                gap = hot_load - cold_load
                movers = sorted(
                    ((name, st) for name, st in hot.functions.items()  # simlint: ok(dict-iteration): unique (heat, name) sort key erases order
                     if st.slices is None),   # split fns are already spread
                    key=lambda kv: (-kv[1].heat, kv[0]))
                now = self.env.now
                # split escalation: when the hot shard's heat is dominated
                # by its single hottest function, no whole-function move
                # fixes the convoy — either the projected share exceeds the
                # hot-cold gap outright (moving it inverts the hotspot), or
                # it holds the majority of the shard's heat (moving it to an
                # idle shard merely *relocates* ~all the load and the pair
                # ping-pongs on the cooldown). Split it across a shard-set
                # instead, and skip whole moves this tick: the dominant
                # function IS the imbalance.
                if self.fn_split_enabled and movers:
                    name0, st0 = movers[0]
                    fn_load0 = hot_load * st0.heat / total_heat
                    if (st0.heat > 0.0 and now >= st0.split_cooldown_until
                            and (fn_load0 >= gap
                                 or st0.heat >= 0.5 * total_heat)):
                        split_name = name0
                if split_name is None:
                    # move hottest-first, but only functions whose projected
                    # load share still closes the hot-cold gap — moving a
                    # function whose share exceeds the remaining gap would
                    # just relocate (or invert) the hotspot
                    for name, st in movers:
                        if (len(names) >= self.rebalance_max_moves
                                or st.heat <= 0):
                            break
                        if now < st.cooldown_until:
                            continue
                        fn_load = hot_load * st.heat / total_heat
                        if fn_load >= gap:
                            continue
                        names.append(name)
                        moved_heat += st.heat
                        gap -= 2.0 * fn_load
            self._decay_heat()
            if split_name is not None:
                # second escalation: the hot shard's load is dominated by
                # one function no whole move can fix — split it across its
                # home plus the coldest (k-1) sibling shards
                k = min(self.fn_split_max_shards, self.cp_shards)
                others = sorted((ld, sid) for ld, sid in loads
                                if sid != hot_id)
                shard_ids = ((hot_id,)
                             + tuple(sid for _, sid in others[:k - 1]))
                if len(shard_ids) >= 2:
                    self._migration_inflight = True
                    self.env.process(
                        self._split_function(split_name, shard_ids),
                        name=f"cp{self.cp_id}-split-{split_name}")
            elif self.rebalance_enabled and names:
                self._migration_inflight = True
                self.env.process(
                    self._migrate_functions(
                        hot, self.shards[cold_id], names,
                        ema_delta=hot.load_ema * moved_heat / total_heat),
                    name=f"cp{self.cp_id}-migrate-{hot_id}-{cold_id}")

    def _shard_fn_heat(self, st: FunctionState, shard_id: int) -> float:
        """Creation heat ``st`` charges shard ``shard_id``: the slice's heat
        for a split function (its global heat is spread over the set)."""
        if st.slices is not None:
            sl = st.slices.get(shard_id)
            return sl.heat if sl is not None else 0.0
        return st.heat

    def _decay_heat(self) -> None:
        for shard in self.shards:
            for st in shard.functions.values():
                if st.slices is not None:
                    sl = st.slices.get(shard.shard_id)
                    if sl is not None:
                        sl.heat *= 0.5
                else:
                    st.heat *= 0.5

    def _migrate_functions(self, src: ControlPlaneShard,
                           dst: ControlPlaneShard,
                           names: List[str],
                           ema_delta: float = 0.0) -> Generator:
        """Explicit migration handoff: quiesce → move → publish → persist.

        Quiesce takes *both* shards' scale locks (in shard-id order, so two
        concurrent handoffs cannot deadlock) — no creation can run against
        either slice while function state moves. The move carries the
        ``FunctionState`` and any endpoint-flush entries still queued for the
        function, then repoints the indirection table. The durable
        ``shardmap/`` override is written only after the locks are released —
        persistence stays off the critical path (paper §3.2), and
        ``recover_as_leader`` replays it so failover keeps the adapted
        partition. A deposed leader aborts without touching shared state."""
        moved: List[str] = []
        try:
            if not (self.alive and self.is_leader):
                return
            first, second = sorted((src, dst), key=lambda s: s.shard_id)
            t0 = self.env.now
            yield first.scale_lock.acquire()
            first.lock_wait_s += self.env.now - t0
            t0 = self.env.now
            yield second.scale_lock.acquire()
            second.lock_wait_s += self.env.now - t0
            try:
                # the handoff hop itself (one cross-shard message)
                # simlint: ok(held-lock-timeout): quiesce hold, id-sorted
                yield self.env.timeout(self.costs.cp_cross_shard_op)
                if not (self.alive and self.is_leader):
                    return
                for name in names:
                    st = src.functions.get(name)
                    if st is None or st.slices is not None:
                        # deregistered/moved since selection — or split into
                        # a shard-set, which only the merge handoff may undo
                        continue
                    src.functions.pop(name)
                    dst.functions[name] = st
                    self.fn_shard_table[name] = dst.shard_id
                    st.cooldown_until = (self.env.now
                                         + self.costs.cp_rebalance_cooldown)
                    moved.append(name)
                if moved:
                    # feed the move forward into the smoothed load signal so
                    # the next ticks don't keep draining the same (now
                    # lighter) shard while its EMA still carries the
                    # pre-migration convoy — scaled by what actually moved
                    # (a function deregistered while we queued on the locks
                    # transfers nothing)
                    if ema_delta > 0.0:
                        delta = ema_delta * len(moved) / len(names)
                        src.load_ema -= delta
                        dst.load_ema += delta
                    moved_set = set(moved)
                    carried = [u for u in src.ep_updates
                               if u[1] in moved_set]
                    if carried:
                        # pending endpoint-flush entries follow their function
                        src.ep_updates = deque(
                            u for u in src.ep_updates
                            if u[1] not in moved_set)
                        dst.ep_updates.extend(carried)
                        self._schedule_ep_flush(dst)
                    self.collector.fn_migrations += len(moved)
                    self.collector.event(
                        self.env.now, "fn-migrated",
                        (src.shard_id, dst.shard_id, tuple(moved)))
            finally:
                second.scale_lock.release()
                first.scale_lock.release()
            # durable indirection-table overrides, off the critical path. A
            # move back to the hash-default shard tombstones the override
            # instead (shardmap/ holds only true deviations, so deregister's
            # cleanup check stays exact); a function deregistered while we
            # persisted is skipped rather than resurrected as an orphan.
            for name in moved:
                if not (self.alive and self.is_leader):
                    return
                if name not in self.functions:
                    continue
                value = (None if dst.shard_id == self._default_shard_id(name)
                         else str(dst.shard_id).encode())
                yield from self.store.write(f"shardmap/{name}", value)
        finally:
            self._migration_inflight = False

    # -- per-function creation sharding (split / merge handoffs) ------------------------------
    def _maybe_merge(self) -> bool:
        """Fold one cooled-down split function per tick. Merge when the
        shard-set's summed slice heat decays below ``cp_fn_split_min_load``
        and the split cooldown elapsed (hysteresis against flap)."""
        now = self.env.now
        for name in sorted(self._split_fns):
            st = self.functions.get(name)
            if st is None or st.slices is None:
                self._split_fns.discard(name)
                return False          # stale entry reaped; retry next tick
            if now < st.split_cooldown_until:
                continue
            if (sum(sl.heat for sl in st.slices.values())  # simlint: ok(dict-iteration): slice-map insertion order is deterministic
                    >= self.fn_split_min_load):
                continue
            self._migration_inflight = True
            self.env.process(self._merge_function(name),
                             name=f"cp{self.cp_id}-merge-{name}")
            return True
        return False

    def _split_function(self, name: str,
                        shard_ids: Tuple[int, ...]) -> Generator:
        """Split handoff, the migration handoff generalized to a shard-set:
        quiesce *every* member shard's scale lock (in id order — concurrent
        handoffs cannot deadlock) → slice the ``FunctionState`` (existing
        sandboxes round-robin across the set, heat spread evenly, slice
        targets seeded to current ownership so nothing churns before the
        next autoscale decision) → publish the tuple in the indirection
        table and register the function with every member shard → persist
        the ``shardmap/<fn>`` shard-set override off the critical path.
        ``shard_ids`` is home-first. A deposed leader aborts without
        touching shared state."""
        try:
            if not (self.alive and self.is_leader):
                return
            members = [self.shards[k] for k in sorted(shard_ids)]
            for sh in members:
                t0 = self.env.now
                yield sh.scale_lock.acquire()
                sh.lock_wait_s += self.env.now - t0
            try:
                # one cross-shard hop per subshard recruited
                # simlint: ok(held-lock-timeout): quiesce hold, id-sorted
                yield self.env.timeout(
                    self.costs.cp_cross_shard_op * (len(shard_ids) - 1))
                if not (self.alive and self.is_leader):
                    return
                st = self.functions.get(name)
                if (st is None or st.slices is not None
                        or self._fn_shard_id(name) != shard_ids[0]):
                    return            # deregistered/moved/split since selection
                slices = {k: FunctionSlice(shard_id=k) for k in shard_ids}
                order = sorted(shard_ids)
                for i, sid in enumerate(sorted(st.sandboxes)):
                    slices[order[i % len(order)]].sandbox_ids.add(sid)
                for sl in slices.values():  # simlint: ok(dict-iteration): slice-map insertion order is deterministic
                    sl.target = len(sl.sandbox_ids)
                    sl.heat = st.heat / len(shard_ids)
                st.heat = 0.0
                st.rr_cursor = 0
                st.targets_t = -1.0
                st.slices = slices
                st.split_cooldown_until = (self.env.now
                                           + self.fn_split_cooldown)
                for k in shard_ids:
                    self.shards[k].functions[name] = st
                self.fn_shard_table[name] = tuple(shard_ids)
                self._split_fns.add(name)
                self.collector.fn_splits += 1
                self.collector.event(self.env.now, "fn-split",
                                     (name, tuple(shard_ids)))
            finally:
                for sh in reversed(members):
                    sh.scale_lock.release()
            # durable shard-set override, off the critical path; skipped if
            # the function vanished (or merged back) while we persisted
            if not (self.alive and self.is_leader):
                return
            st = self.functions.get(name)
            if st is None or st.slices is None:
                return
            value = ",".join(str(k) for k in shard_ids).encode()
            yield from self.store.write(f"shardmap/{name}", value)
        finally:
            self._migration_inflight = False

    def _merge_function(self, name: str) -> Generator:
        """Merge handoff: quiesce every subshard lock (id order) → fold the
        slices back into the global ``FunctionState`` (creating counts and
        heat sum; the sandbox map was global all along) → pending
        endpoint-flush entries still queued on non-home subshards move to
        the home queue exactly once → repoint the table to the home shard →
        persist the override (tombstoned when home is the hash default)."""
        try:
            if not (self.alive and self.is_leader):
                return
            st = self.functions.get(name)
            if st is None or st.slices is None:
                return
            home = self._fn_shard_id(name)
            member_ids = sorted(st.slices)
            members = [self.shards[k] for k in member_ids]
            for sh in members:
                t0 = self.env.now
                yield sh.scale_lock.acquire()
                sh.lock_wait_s += self.env.now - t0
            try:
                # simlint: ok(held-lock-timeout): quiesce hold, id-sorted
                yield self.env.timeout(
                    self.costs.cp_cross_shard_op * (len(member_ids) - 1))
                if not (self.alive and self.is_leader):
                    return
                st = self.functions.get(name)
                if st is None or st.slices is None:
                    return            # deregistered/merged since selection
                st.creating += sum(sl.creating for sl in st.slices.values())  # simlint: ok(dict-iteration): int sum, order-free
                st.heat += sum(sl.heat for sl in st.slices.values())  # simlint: ok(dict-iteration): slice-map insertion order is deterministic
                st.slices = None
                st.split_cooldown_until = (self.env.now
                                           + self.fn_split_cooldown)
                survivor = self.shards[home]
                carried: List[tuple] = []
                for k in member_ids:
                    if k == home:
                        continue
                    sh = self.shards[k]
                    sh.functions.pop(name, None)
                    mine = [u for u in sh.ep_updates if u[1] == name]
                    if mine:
                        sh.ep_updates = deque(u for u in sh.ep_updates
                                              if u[1] != name)
                        carried.extend(mine)
                if carried:
                    survivor.ep_updates.extend(carried)
                    self._schedule_ep_flush(survivor)
                self.fn_shard_table[name] = home
                self._split_fns.discard(name)
                self.collector.fn_merges += 1
                self.collector.event(self.env.now, "fn-merged", (name, home))
            finally:
                for sh in reversed(members):
                    sh.scale_lock.release()
            if not (self.alive and self.is_leader):
                return
            if name not in self.functions:
                return
            value = (None if home == self._default_shard_id(name)
                     else str(home).encode())
            yield from self.store.write(f"shardmap/{name}", value)
        finally:
            self._migration_inflight = False

    # -- failover recovery (new leader) ----------------------------------------------------------
    def recover_as_leader(self) -> Generator:
        """Paper §3.4.1: fetch persisted records, reconnect, reconstruct
        sandbox state from worker nodes asynchronously. Rebuilds every
        shard's function/worker maps from the persisted records — including
        the shard indirection table: install seeds hash defaults, then the
        persisted ``shardmap/`` overrides are replayed so a failover does not
        silently undo the rebalancer's migrations.

        The replay itself is *costed per record* (``cp_cross_shard_op`` per
        function / override / worker — each is an in-memory state-machine
        step): at 20k workers the rebuild is tens of milliseconds of real
        work, not a free dict comprehension. Two shapes:

        * **serial** (``incremental_recovery=False``, or a single shard):
          one pass replays everything, then every shard is admitted at once
          — the pre-incremental behavior, kept as the baseline the
          ``failover_scale`` sweep measures against.
        * **incremental** (default, ``cp_shards > 1``): the snapshot read
          below bounds the replay, then one recovery *unit per shard*
          replays that shard's slice of the snapshot concurrently and admits
          the shard (health + autoscale loops, worker merges) the moment its
          own slice is rebuilt — traffic to shard k never waits for shard
          j's replay. Function replay completes on every unit before any
          worker merge starts (a barrier), so pushed sandbox lists never
          race a half-built function table.
        """
        c = self.costs
        yield self.env.timeout(c.cp_recovery_db_fetch)
        # one consistent snapshot bounds the replay: everything written
        # after this point belongs to the new leader's own epoch and is
        # handled by the live loops, not the recovery units
        ckpt = None
        if self.checkpoint_enabled:
            ckpt = yield from self.store.read_checkpoint()
        if ckpt is not None:
            # checkpointed recovery: one compacted snapshot record + the
            # post-checkpoint delta, instead of full-prefix scans. Records
            # sourced from the snapshot bulk-load at
            # cp_snapshot_load_per_record in the units below; only the delta
            # pays the per-record state-machine replay.
            snap, delta = ckpt
            merged = dict(snap)
            for key, rec in delta.items():  # simlint: ok(dict-iteration): WAL write order is deterministic
                if rec is None:
                    merged.pop(key, None)
                else:
                    merged[key] = rec
            func_records = {k: v for k, v in merged.items()  # simlint: ok(dict-iteration): snapshot+delta order is deterministic
                            if k.startswith("function/")}
            shardmap: Dict[str, object] = {}
            if self.rebalance_enabled or self.fn_split_enabled:
                shardmap = {k: v for k, v in merged.items()  # simlint: ok(dict-iteration): snapshot+delta order is deterministic
                            if k.startswith("shardmap/")}
            worker_records = {k: v for k, v in merged.items()  # simlint: ok(dict-iteration): snapshot+delta order is deterministic
                              if k.startswith("worker/")}
            delta_keys = set(delta)
        else:
            func_records = yield from self.store.read_prefix("function/")
            shardmap = {}
            if self.rebalance_enabled or self.fn_split_enabled:
                shardmap = yield from self.store.read_prefix("shardmap/")
            worker_records = yield from self.store.read_prefix("worker/")
            delta_keys = None
        self.functions = {}
        self.fn_shard_table = {}
        self._split_fns = set()
        for shard in self.shards:
            shard.functions = {}
            shard.worker_last_hb = {}
        self.workers = {}
        self.placer = self._make_placer()
        # post-recovery: hold downscaling for one autoscaling window
        self.no_downscale_until = self.env.now + c.recovery_no_downscale
        if self.incremental_recovery and self.cp_shards > 1:
            yield from self._recover_incremental(func_records, shardmap,
                                                 worker_records, delta_keys)
        else:
            yield from self._recover_serial(func_records, shardmap,
                                            worker_records, delta_keys)

    def _replay_shardmap_override(self, key: str, rec) -> None:
        """Re-apply one persisted ``shardmap/<fn>`` override (an ``int`` sole
        owner or a comma-separated shard-set) to the freshly installed
        table. Malformed or out-of-range records are ignored — the hash
        default stands."""
        name = key.split("/", 1)[1]
        st = self.functions.get(name)
        if st is None:
            return
        try:
            text = rec.decode()
        except AttributeError:
            return
        if "," in text:
            # shard-set override: the function was split — rebuild the
            # slices (empty; sandboxes are adopted as the workers push them
            # back) so failover keeps the split
            try:
                ids = tuple(int(x) for x in text.split(","))
            except ValueError:
                return
            if (len(ids) < 2 or len(set(ids)) != len(ids)
                    or not all(0 <= k < self.cp_shards for k in ids)):
                return
            cur = self._fn_shard_id(name)
            self.shards[cur].functions.pop(name, None)
            st.slices = {k: FunctionSlice(shard_id=k) for k in ids}
            st.rr_cursor = 0
            st.targets_t = -1.0
            # slices replay with zero heat (real creations refill it);
            # without the cooldown the first rebalance tick would merge the
            # split right back — failover must KEEP splits, with the same
            # hysteresis a fresh split gets
            st.split_cooldown_until = self.env.now + self.fn_split_cooldown
            for k in ids:
                self.shards[k].functions[name] = st
            self.fn_shard_table[name] = ids
            self._split_fns.add(name)
            return
        try:
            dst = int(text)
        except ValueError:
            return
        if not 0 <= dst < self.cp_shards:
            return
        cur = self._fn_shard_id(name)
        if dst != cur:
            self.shards[cur].functions.pop(name, None)
            self.shards[dst].functions[name] = st
        self.fn_shard_table[name] = dst

    def _install_recovered_worker(self, info: WorkerNodeInfo) -> None:
        self.workers[info.worker_id] = info
        self._worker_shard(info.worker_id).worker_last_hb[info.worker_id] \
            = self.env.now
        self.placer.add_node(info.worker_id, info.cpu_capacity_millis,
                             info.mem_capacity_mb)

    def _recover_worker_replay_cost(self, n_workers: int,
                                    n_delta: int, from_ckpt: bool) -> float:
        """Worker-record rebuild cost. Worker records dominate the replay at
        scale (100k workers vs hundreds of functions), so they are the slice
        the checkpoint accelerates: snapshot-sourced records bulk-load at
        ``cp_snapshot_load_per_record`` (deserialize into the maps), only
        post-checkpoint delta records pay the full per-record
        ``cp_cross_shard_op`` state-machine step."""
        c = self.costs
        if not from_ckpt:
            return c.cp_cross_shard_op * n_workers
        return (c.cp_cross_shard_op * n_delta
                + c.cp_snapshot_load_per_record * (n_workers - n_delta))

    def _recover_serial(self, func_records, shardmap,
                        worker_records, delta_keys=None) -> Generator:
        """Single-pass replay: everything rebuilt, then every shard admitted
        at once (the pre-incremental shape, with the replay now costed)."""
        c = self.costs
        if delta_keys is None:
            # legacy full-prefix replay: the exact expression the recovery
            # event-budget pin was recorded against (same float arithmetic)
            n_replay = len(func_records) + len(shardmap) + len(worker_records)
            if n_replay:
                yield self.env.timeout(c.cp_cross_shard_op * n_replay)
        else:
            n_wrk_delta = sum(1 for k in worker_records if k in delta_keys)
            dt = (c.cp_cross_shard_op * (len(func_records) + len(shardmap))
                  + self._recover_worker_replay_cost(len(worker_records),
                                                     n_wrk_delta, True))
            if dt:
                yield self.env.timeout(dt)
        for key, rec in func_records.items():  # simlint: ok(dict-iteration): WAL write order is deterministic
            self.install_function(Function.from_record(rec))
        for key, rec in shardmap.items():  # simlint: ok(dict-iteration): WAL write order is deterministic
            self._replay_shardmap_override(key, rec)
        for key, rec in worker_records.items():  # simlint: ok(dict-iteration): WAL write order is deterministic
            self._install_recovered_worker(WorkerNodeInfo.from_record(rec))
        # sync DP caches with the function list
        yield self.env.timeout(c.cp_recovery_dp_sync)
        names = list(self.functions.keys())  # simlint: ok(dict-iteration): install order is deterministic
        for dp in self.cluster.data_planes_alive():
            dp.sync_functions(names)
        self.start_leader()
        self.collector.event(self.env.now, "cp-recovered", self.cp_id)
        # async: workers push their sandbox lists; merge as they arrive
        for wid in list(self.workers.keys()):  # simlint: ok(dict-iteration): registration order is deterministic
            self.env.process(self._merge_worker_sandboxes(wid),
                             name=f"merge-{wid}")

    def _recover_incremental(self, func_records, shardmap,
                             worker_records, delta_keys=None) -> Generator:
        """Per-shard recovery units over one bounded snapshot.

        The snapshot is bucketed by *post-override* owner up front (pure
        arithmetic; the per-record cost is charged inside each unit), so a
        unit replays exactly its own slice: its functions (overrides
        included), then — after the cross-unit function barrier — its
        workers, then admission. Leadership is taken immediately: creations
        the units trigger must pass the leadership checks, while urgent
        metric pushes for a still-recovering shard are deferred
        (``receive_metric``) until that shard is admitted."""
        # resolve final ownership before spawning units: an override's
        # destination unit must install the function, or a unit racing the
        # override replay could install then lose it mid-flight
        home_of: Dict[str, object] = {}
        fn_objs: List[Function] = []
        for key, rec in func_records.items():  # simlint: ok(dict-iteration): WAL write order is deterministic
            fn = Function.from_record(rec)
            fn_objs.append(fn)
            home_of[fn.name] = self._default_shard_id(fn.name)
        overrides_by_fn: Dict[str, object] = {}
        for key, rec in shardmap.items():  # simlint: ok(dict-iteration): WAL write order is deterministic
            name = key.split("/", 1)[1]
            if name not in home_of:
                continue
            parsed = self._parse_shardmap_override(rec)
            if parsed is None:
                continue
            if type(parsed) is int:
                if not 0 <= parsed < self.cp_shards:
                    continue
            elif not all(0 <= k < self.cp_shards for k in parsed):
                continue
            overrides_by_fn[name] = parsed
            home_of[name] = parsed if type(parsed) is int else parsed[0]
        fns_by_shard: List[List[Function]] = [[] for _ in self.shards]
        for fn in fn_objs:
            h = home_of[fn.name]
            fns_by_shard[h if type(h) is int else h[0]].append(fn)
        workers_by_shard: List[List[WorkerNodeInfo]] = [[] for _ in self.shards]
        for key, rec in worker_records.items():  # simlint: ok(dict-iteration): WAL write order is deterministic
            info = WorkerNodeInfo.from_record(rec)
            workers_by_shard[info.worker_id % self.cp_shards].append(info)
        self.is_leader = True
        self._loops = []
        self._recovering_shards = set(range(self.cp_shards))
        barrier_state = {"pending": self.cp_shards}
        barrier = self.env.event()
        # stop() releases the barrier: a leader deposed mid-replay has its
        # units killed, and the elector's thread (blocked below) must not
        # hang forever on a barrier no unit will ever complete
        self._recovery_barrier = barrier
        for shard in self.shards:
            self._loops.append(self.env.process(
                self._recover_shard_unit(
                    shard, fns_by_shard[shard.shard_id], overrides_by_fn,
                    workers_by_shard[shard.shard_id], barrier_state, barrier,
                    delta_keys),
                name=f"cp{self.cp_id}-recover-{shard.shard_id}"))
        # the leader's own thread waits for the function table to be whole,
        # then syncs the DP caches; worker replay + admission continue in
        # the units behind it
        yield barrier
        self._recovery_barrier = None
        if not (self.alive and self.is_leader):
            return      # deposed mid-replay: stop() released the barrier
        yield self.env.timeout(self.costs.cp_recovery_dp_sync)
        names = list(self.functions.keys())  # simlint: ok(dict-iteration): unit replay order is deterministic
        for dp in self.cluster.data_planes_alive():
            dp.sync_functions(names)

    @staticmethod
    def _parse_shardmap_override(rec):
        """Validated override payload: an ``int`` destination, a tuple
        shard-set, or ``None`` for a malformed record. Mirrors
        ``_replay_shardmap_override``'s acceptance rules (range checks need
        ``cp_shards`` and happen at apply time)."""
        try:
            text = rec.decode()
        except AttributeError:
            return None
        if "," in text:
            try:
                ids = tuple(int(x) for x in text.split(","))
            except ValueError:
                return None
            if len(ids) < 2 or len(set(ids)) != len(ids):
                return None
            return ids
        try:
            return int(text)
        except ValueError:
            return None

    def _recover_shard_unit(self, shard: ControlPlaneShard,
                            fns: List[Function], overrides_by_fn: Dict,
                            workers: List[WorkerNodeInfo],
                            barrier_state: Dict, barrier,
                            delta_keys=None) -> Generator:
        """One shard's recovery unit: replay functions homed here (overrides
        included), wait for every other unit's function replay, replay this
        shard's workers, then admit the shard."""
        c = self.costs
        n_fn_work = len(fns) + sum(1 for fn in fns
                                   if fn.name in overrides_by_fn)
        if n_fn_work:
            yield self.env.timeout(c.cp_cross_shard_op * n_fn_work)
        for fn in fns:
            st = FunctionState(function=fn,
                               autoscaler=FunctionAutoscalerState(
                                   fn.scaling,
                                   vectorized=self.vector_windows))
            self.functions[fn.name] = st
            # overrides_by_fn entries were range-validated at bucketing time
            ov = overrides_by_fn.get(fn.name)
            if ov is not None and type(ov) is not int:
                st.slices = {k: FunctionSlice(shard_id=k) for k in ov}
                st.rr_cursor = 0
                st.targets_t = -1.0
                st.split_cooldown_until = (self.env.now
                                           + self.fn_split_cooldown)
                for k in ov:
                    self.shards[k].functions[fn.name] = st
                self.fn_shard_table[fn.name] = ov
                self._split_fns.add(fn.name)
                continue
            dst = ov if ov is not None else self._default_shard_id(fn.name)
            self.fn_shard_table[fn.name] = dst
            self.shards[dst].functions[fn.name] = st
        # barrier: worker merges (pushed sandbox lists) anywhere must see a
        # complete function table, or recovered replicas of a function homed
        # on a slower shard would be silently skipped and re-created
        barrier_state["pending"] -= 1
        if barrier_state["pending"] == 0:
            barrier.succeed(None)
        else:
            yield barrier
        if workers:
            if delta_keys is None:
                yield self.env.timeout(c.cp_cross_shard_op * len(workers))
            else:
                n_delta = sum(1 for info in workers
                              if f"worker/{info.worker_id}" in delta_keys)
                dt = self._recover_worker_replay_cost(len(workers),
                                                      n_delta, True)
                if dt:
                    yield self.env.timeout(dt)
        for info in workers:
            self._install_recovered_worker(info)
        # admit this shard: health + autoscale loops from here on
        self._start_shard_loops(shard)
        self._recovering_shards.discard(shard.shard_id)
        self.collector.event(self.env.now, "cp-shard-recovered",
                             (self.cp_id, shard.shard_id))
        for info in workers:
            self.env.process(self._merge_worker_sandboxes(info.worker_id),
                             name=f"merge-{info.worker_id}")
        if not self._recovering_shards:
            self._start_global_loops()
            self.collector.event(self.env.now, "cp-recovered", self.cp_id)

    def _merge_worker_sandboxes(self, wid: int) -> Generator:
        yield self.env.timeout(self.costs.grpc_call)
        worker = self.cluster.worker_by_id(wid)
        if worker is None or not worker.daemon_alive:
            return
        for sb in worker.list_sandboxes():
            st = self.functions.get(sb.function_name)
            if st is None:
                continue
            st.sandboxes[sb.sandbox_id] = sb
            if st.slices is not None:
                # replayed shard-set override: attach the recovered replica
                # to its subshard so per-slice accounting is coherent
                self._adopt_sandbox(st, sb)
            self.placer.commit(wid, st.function.scaling.cpu_req_millis,
                               st.function.scaling.mem_req_mb)
            self._queue_endpoint_update("add", sb.function_name, sb)
