"""Monolithic control plane (paper §3).

One process-level component containing the state manager, autoscaler, placer
and health monitor as modules that exchange information via in-memory
channels (modeled at ``channel_op`` cost, vs RPC+etcd round-trips in K8s).

Persistence policy (paper Table 3): ``Function``/``DataPlane``/``WorkerNode``
records are written to the replicated store *at registration time*;
``Sandbox`` state and function scheduling metrics are in-memory only and are
reconstructed after failover (from worker nodes / DP traffic). The ablation
flag ``persist_sandbox_state`` puts a durable write back on the cold-start
critical path — reproducing the paper's "Dirigent optimization breakdown".

The shared ``_scale_lock`` models the "shared data structures used for
autoscaling" that the paper identifies as Dirigent's own bottleneck at
~2500 sandbox creations/s (C1); heartbeat processing touches the same
structures, which is what degrades throughput at 5000 workers (C9).
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Generator, List, Optional, Tuple, TYPE_CHECKING

from repro.core.abstractions import (
    Function, Sandbox, SandboxState, WorkerNodeInfo,
)
from repro.core.autoscaler import FunctionAutoscalerState
from repro.core.costmodel import DirigentCosts
from repro.core.metrics import Collector
from repro.core.placement import make_placer
from repro.simcore import Environment, Interrupt

if TYPE_CHECKING:
    from repro.core.cluster import Cluster


@dataclass
class FunctionState:
    function: Function
    autoscaler: FunctionAutoscalerState
    sandboxes: Dict[int, Sandbox] = field(default_factory=dict)
    creating: int = 0

    @property
    def ready_count(self) -> int:
        return sum(1 for s in self.sandboxes.values()
                   if s.state == SandboxState.READY)


class ControlPlane:
    def __init__(self, env: Environment, cp_id: int, costs: DirigentCosts,
                 cluster: "Cluster", store, collector: Collector,
                 persist_sandbox_state: bool = False,
                 placement_policy: str = "balanced"):
        self.env = env
        self.cp_id = cp_id
        self.costs = costs
        self.cluster = cluster
        self.store = store
        self.collector = collector
        self.persist_sandbox_state = persist_sandbox_state
        self.is_leader = False
        self.alive = True
        self.functions: Dict[str, FunctionState] = {}
        self.workers: Dict[int, WorkerNodeInfo] = {}
        self.worker_last_hb: Dict[int, float] = {}
        self.placement_policy = placement_policy
        self.placer = make_placer(placement_policy)
        self._scale_lock = env.resource(capacity=1)
        self._sandbox_ids = itertools.count(1)
        self._loops = []
        self.no_downscale_until = 0.0
        # coalescing CP -> DP endpoint-update buffer: updates queued in the
        # same event-loop turn ride one batched broadcast (vs one serial
        # grpc_call per DP per update on the creation critical path)
        self._ep_updates: Deque[Tuple[str, str, object, bool]] = deque()
        self._ep_flush_scheduled = False

    # -- lifecycle -----------------------------------------------------------------
    def start_leader(self) -> None:
        self.is_leader = True
        self._loops = [
            self.env.process(self._autoscale_loop(), name=f"cp{self.cp_id}-autoscale"),
            self.env.process(self._health_loop(), name=f"cp{self.cp_id}-health"),
        ]

    def stop(self) -> None:
        self.alive = False
        self.is_leader = False
        for p in self._loops:
            p.kill()
        self._loops = []
        self._ep_updates.clear()

    # -- user API --------------------------------------------------------------------
    def register_function(self, fn: Function) -> Generator:
        """Register: persist the spec, propagate metadata to DPs (paper: ~2 ms)."""
        yield self.env.timeout(self.costs.grpc_call)          # client -> CP
        yield from self.store.write(f"function/{fn.name}", fn.persisted_record())
        self.functions[fn.name] = FunctionState(
            function=fn, autoscaler=FunctionAutoscalerState(fn.scaling))
        # propagate to data planes: one batched broadcast covers every DP
        dps = self.cluster.data_planes_alive()
        if dps:
            yield self.env.timeout(self.costs.grpc_call)
            for dp in dps:
                dp.sync_functions([fn.name])
        return fn.name

    def deregister_function(self, name: str) -> Generator:
        yield from self.store.write(f"function/{name}", None)
        st = self.functions.pop(name, None)
        if st:
            for sb in list(st.sandboxes.values()):
                yield from self._teardown_sandbox(st, sb)

    # -- component registration ---------------------------------------------------------
    def register_worker(self, info: WorkerNodeInfo) -> Generator:
        yield from self.store.write(f"worker/{info.worker_id}",
                                    info.persisted_record())
        self.workers[info.worker_id] = info
        self.worker_last_hb[info.worker_id] = self.env.now
        self.placer.add_node(info.worker_id, info.cpu_capacity_millis,
                             info.mem_capacity_mb)

    def register_data_plane(self, dp_info) -> Generator:
        yield from self.store.write(f"dataplane/{dp_info.dp_id}",
                                    dp_info.persisted_record())

    # -- metrics ingestion (from DPs) ------------------------------------------------------
    def receive_metric(self, dp_id: int, fn: str, inflight: int,
                       urgent: bool = False) -> Generator:
        yield self.env.timeout(self.costs.grpc_call)
        if not (self.alive and self.is_leader):
            return
        st = self.functions.get(fn)
        if st is None:
            return
        st.autoscaler.record_metric(self.env.now, float(inflight))
        if urgent:
            # Event-driven fast path: a queue formed with zero free slots.
            yield from self._reconcile_function(fn, st)

    def receive_metric_batch(self, dp_id: int, report: Dict[str, int]) -> Generator:
        yield self.env.timeout(self.costs.grpc_call)
        if not (self.alive and self.is_leader):
            return
        for fn, inflight in report.items():
            st = self.functions.get(fn)
            if st is not None:
                st.autoscaler.record_metric(self.env.now, float(inflight))

    def report_dead_sandbox(self, fn: str, sandbox_id: int) -> Generator:
        """A DP dispatched to a sandbox that is gone (killed behind our back,
        e.g. torn down by a deposed leader, or lost with its node). Reconcile
        it out of the cluster state so routing and capacity self-heal —
        sandbox state is reconstructed from cluster signals, never trusted
        blindly (paper §3.4)."""
        yield self.env.timeout(self.costs.grpc_call)   # DP -> CP report
        if not (self.alive and self.is_leader):
            return
        st = self.functions.get(fn)
        if st is None:
            return
        sb = st.sandboxes.pop(sandbox_id, None)
        if sb is None:
            return
        self.placer.release(sb.worker_id,
                            st.function.scaling.cpu_req_millis,
                            st.function.scaling.mem_req_mb)
        self._queue_endpoint_update("remove", fn, sandbox_id, drain=False)
        yield from self._reconcile_function(fn, st)

    def heartbeat(self, worker_id: int) -> None:
        """Worker heartbeat. Touches the shared health/state structures."""
        if not self.alive:
            return
        self.worker_last_hb[worker_id] = self.env.now
        # contention: heartbeat processing holds the shared state lock
        def hb(env):
            yield self._scale_lock.acquire()
            try:
                yield env.timeout(self.costs.cp_heartbeat_lock_hold)
            finally:
                self._scale_lock.release()
        self.env.process(hb(self.env), name="hb-touch")

    # -- autoscaling ------------------------------------------------------------------------
    def _autoscale_loop(self) -> Generator:
        while True:
            yield self.env.timeout(self.costs.autoscale_period)
            for fn, st in list(self.functions.items()):
                yield from self._reconcile_function(fn, st)

    def _reconcile_function(self, fn: str, st: FunctionState) -> Generator:
        """Compute desired scale and act on the difference."""
        yield self.env.timeout(self.costs.cp_sched_cpu)
        current = st.ready_count + st.creating
        desired = st.autoscaler.desired(self.env.now, current)
        if self.env.now < self.no_downscale_until:
            desired = max(desired, current)     # post-recovery hold (§3.4.1)
        if desired > current:
            for _ in range(desired - current):
                st.creating += 1
                self.env.process(self._create_sandbox(st),
                                 name=f"create-{fn}")
        elif desired < current:
            victims = self._pick_victims(st, current - desired)
            for sb in victims:
                yield from self._teardown_sandbox(st, sb)

    def _pick_victims(self, st: FunctionState, n: int) -> List[Sandbox]:
        ready = [s for s in st.sandboxes.values()
                 if s.state == SandboxState.READY]
        ready.sort(key=lambda s: -s.sandbox_id)    # newest first
        return ready[:n]

    # -- sandbox creation (the latency-critical path) --------------------------------------------
    def _create_sandbox(self, st: FunctionState) -> Generator:
        fn = st.function
        try:
            # shared autoscaling/cluster-state structures (C1 bottleneck)
            yield self._scale_lock.acquire()
            try:
                yield self.env.timeout(self.costs.cp_scale_lock_hold)
                wid = self.placer.place(fn.scaling.cpu_req_millis,
                                        fn.scaling.mem_req_mb)
            finally:
                self._scale_lock.release()
            if wid is None:
                return  # no capacity in the cluster

            sb = Sandbox(
                sandbox_id=next(self._sandbox_ids),
                function_name=fn.name,
                ip=self.workers[wid].ip, port=fn.port, worker_id=wid,
            )
            st.sandboxes[sb.sandbox_id] = sb

            if self.persist_sandbox_state:
                # ABLATION: durable write on the critical path (paper §5.2.1
                # "optimization breakdown") — this is what Dirigent removes.
                yield from self.store.write(f"sandbox/{sb.key}", sb.to_bytes())

            worker = self.cluster.worker_by_id(wid)
            yield self.env.timeout(self.costs.grpc_call)   # CP -> worker
            try:
                yield self.env.process(worker.create_sandbox(sb),
                                       name=f"boot-{sb.key}")
            except (RuntimeError, Interrupt):
                st.sandboxes.pop(sb.sandbox_id, None)
                self.placer.release(wid, fn.scaling.cpu_req_millis,
                                    fn.scaling.mem_req_mb)
                return
            yield self.env.timeout(self.costs.grpc_call)   # ready notification
            if not (self.alive and self.is_leader):
                # leadership lost while the worker booted: this replica's
                # in-memory view is dead weight — undo the placement commit
                # and drop the CREATING record so capacity stays exact
                st.sandboxes.pop(sb.sandbox_id, None)
                self.placer.release(wid, fn.scaling.cpu_req_millis,
                                    fn.scaling.mem_req_mb)
                return
            sb.state = SandboxState.READY
            self.collector.sandbox_creations += 1
            self.collector.event(self.env.now, "sandbox-created", fn.name)
            # in-memory state update; the endpoint rides the next coalesced
            # broadcast (one batched grpc_call for all DPs and all updates
            # queued this turn)
            yield self.env.timeout(self.costs.channel_op)
            self._queue_endpoint_update("add", fn.name, sb)
        finally:
            st.creating = max(0, st.creating - 1)

    def _teardown_sandbox(self, st: FunctionState, sb: Sandbox) -> Generator:
        # teardown runs in the asynchronous autoscaling loop, off the
        # latency-critical path (paper §4 "Sandbox teardown") — it does not
        # contend the scale lock
        yield self.env.timeout(self.costs.channel_op)
        if st.sandboxes.pop(sb.sandbox_id, None) is None:
            # a concurrent remover (dead-sandbox report, worker eviction,
            # another reconcile) already took it: releasing again would
            # free phantom capacity and overcommit the node
            return
        sb.state = SandboxState.TERMINATING
        if self.persist_sandbox_state:
            yield from self.store.write(f"sandbox/{sb.key}", None)
        self._queue_endpoint_update("remove", st.function.name, sb.sandbox_id)
        worker = self.cluster.worker_by_id(sb.worker_id)
        if worker is not None:
            # drain grace: in-flight requests already dispatched to this
            # sandbox finish before the worker dismantles it
            def drain_then_kill(env, worker=worker, sid=sb.sandbox_id):
                yield env.timeout(self.costs.teardown_drain_grace)
                yield from worker.kill_sandbox(sid)
            self.env.process(drain_then_kill(self.env),
                             name=f"kill-{sb.key}")
        self.placer.release(sb.worker_id,
                            st.function.scaling.cpu_req_millis,
                            st.function.scaling.mem_req_mb)
        self.collector.sandbox_teardowns += 1

    # -- CP -> DP endpoint propagation (coalesced) ------------------------------------------------
    def _queue_endpoint_update(self, op: str, fn: str, payload,
                               drain: bool = True) -> None:
        """Buffer an endpoint add/remove; every update queued in the same
        event-loop turn shares one batched broadcast to all DPs."""
        self._ep_updates.append((op, fn, payload, drain))
        if not self._ep_flush_scheduled:
            self._ep_flush_scheduled = True
            self.env.process(self._flush_endpoint_updates(),
                             name=f"cp{self.cp_id}-ep-flush")

    def _flush_endpoint_updates(self) -> Generator:
        yield self.env.timeout(self.costs.grpc_call)   # one batched broadcast
        updates, self._ep_updates = self._ep_updates, deque()
        self._ep_flush_scheduled = False
        if not self.alive:
            return
        dps = self.cluster.data_planes_alive()
        for op, fn, payload, drain in updates:
            if op == "add":
                # a dethroned leader must not introduce endpoints...
                if self.is_leader:
                    for dp in dps:
                        dp.add_endpoint(fn, payload)
            else:
                # ...but removes are always safe: the sandbox is being killed
                # regardless, and dropping them here would strand a dead
                # endpoint in the DP caches
                for dp in dps:
                    dp.remove_endpoint(fn, payload, drain=drain)

    # -- health monitoring -----------------------------------------------------------------------
    def _health_loop(self) -> Generator:
        c = self.costs
        while True:
            yield self.env.timeout(c.worker_heartbeat_period)
            now = self.env.now
            for wid, last in list(self.worker_last_hb.items()):
                if now - last > c.worker_heartbeat_timeout:
                    yield from self._evict_worker(wid)

    def _evict_worker(self, wid: int) -> Generator:
        """Worker declared dead: stop routing, reschedule its sandboxes."""
        self.worker_last_hb.pop(wid, None)
        self.placer.set_schedulable(wid, False)
        affected: List[tuple] = []
        for fn, st in self.functions.items():
            for sb in [s for s in st.sandboxes.values() if s.worker_id == wid]:
                st.sandboxes.pop(sb.sandbox_id, None)
                affected.append((fn, sb.sandbox_id))
        for fn, sid in affected:
            self._queue_endpoint_update("remove", fn, sid, drain=False)
        self.collector.event(self.env.now, "worker-evicted", wid)
        # re-run autoscaling promptly to replace lost capacity
        for fn, st in list(self.functions.items()):
            yield from self._reconcile_function(fn, st)

    def restore_worker(self, wid: int) -> None:
        self.worker_last_hb[wid] = self.env.now
        self.placer.set_schedulable(wid, True)

    # -- failover recovery (new leader) ----------------------------------------------------------
    def recover_as_leader(self) -> Generator:
        """Paper §3.4.1: fetch persisted records, reconnect, reconstruct
        sandbox state from worker nodes asynchronously."""
        c = self.costs
        yield self.env.timeout(c.cp_recovery_db_fetch)
        func_records = yield from self.store.read_prefix("function/")
        worker_records = yield from self.store.read_prefix("worker/")
        self.functions = {}
        for key, rec in func_records.items():
            fn = Function.from_record(rec)
            self.functions[fn.name] = FunctionState(
                function=fn, autoscaler=FunctionAutoscalerState(fn.scaling))
        self.workers = {}
        self.placer = make_placer(self.placement_policy)
        for key, rec in worker_records.items():
            info = WorkerNodeInfo.from_record(rec)
            self.workers[info.worker_id] = info
            self.worker_last_hb[info.worker_id] = self.env.now
            self.placer.add_node(info.worker_id, info.cpu_capacity_millis,
                                 info.mem_capacity_mb)
        # sync DP caches with the function list
        yield self.env.timeout(c.cp_recovery_dp_sync)
        names = list(self.functions.keys())
        for dp in self.cluster.data_planes_alive():
            dp.sync_functions(names)
        # post-recovery: hold downscaling for one autoscaling window
        self.no_downscale_until = self.env.now + c.recovery_no_downscale
        self.start_leader()
        # async: workers push their sandbox lists; merge as they arrive
        for wid in list(self.workers.keys()):
            self.env.process(self._merge_worker_sandboxes(wid),
                             name=f"merge-{wid}")

    def _merge_worker_sandboxes(self, wid: int) -> Generator:
        yield self.env.timeout(self.costs.grpc_call)
        worker = self.cluster.worker_by_id(wid)
        if worker is None or not worker.daemon_alive:
            return
        for sb in worker.list_sandboxes():
            st = self.functions.get(sb.function_name)
            if st is None:
                continue
            st.sandboxes[sb.sandbox_id] = sb
            self.placer.commit(wid, st.function.scaling.cpu_req_millis,
                               st.function.scaling.mem_req_mb)
            self._queue_endpoint_update("add", sb.function_name, sb)
