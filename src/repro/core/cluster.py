"""Dirigent cluster wiring: CP replicas + DP replicas + workers + front-end LB.

``Cluster`` is the top-level façade used by benchmarks, tests and examples:

    cluster = Cluster(env, n_workers=93, runtime="firecracker")
    cluster.start()
    env.run_until_event(cluster.register(Function(...)))
    cluster.invoke("fn", exec_time=0.01)
    env.run(until=300)
    cluster.collector.summary()

Failure injection: ``fail_control_plane_leader()``, ``fail_data_plane(i)``,
``fail_worker_daemon(wid)``, ``fail_worker_node(wid)`` — each with the
corresponding recovery path from paper §3.4.

Scaling knobs: ``cp_shards`` partitions the control plane itself into N
internal shards (per-shard scale lock, autoscale loop, health monitor and
endpoint-flush queue — see core/control_plane.py); the default of 1
reproduces the paper's single-lock CP bit-identically. ``placement_policy``
selects node scoring (core/policies.py); with ``cp_shards > 1`` the CP
always composes a ``PartitionedPlacer`` whose partitions align with the CP
shards so placements stay shard-local on the hot path.

Load-adaptive sharding knobs (``cp_rebalance_*``): with
``cp_rebalance_enabled=True`` the leader CP runs a periodic rebalancer that
migrates hot functions off the hottest shard via an explicit handoff,
keeping a skewed (Zipf-popularity) function mix from convoying on one scale
lock; ``cp_rebalance_period`` / ``cp_rebalance_hot_factor`` /
``cp_rebalance_max_moves`` override the ``DirigentCosts`` defaults. The
default (off) keeps the static hash partition bit-identically.

Per-function creation sharding (``cp_fn_split_*``): with
``cp_fn_split_enabled=True`` the rebalancer escalates past whole-function
moves — a single function whose creation load dominates its shard (a load no
move can fix) is *split* across a shard-set, every subshard creating for it
under its own scale lock on its own worker partition, and merged back when
its heat decays (``cp_fn_split_max_shards`` / ``cp_fn_split_min_load`` /
``cp_fn_split_cooldown`` override the ``DirigentCosts`` defaults). Operator
guidance for all of these lives in docs/operations.md.

Multi-data-plane serving (``dp_spread_*`` / ``dp_conn_reuse``): the DP-side
twin of the CP scale-out above. With ``dp_spread_enabled=True`` the front
end generalizes its ``stable_hash(fn) % n_dps`` steering to a **fn→DP-set**
table (the fn→shard-set pattern one layer down): a function whose arrival
rate crosses ``dp_spread_min_rate`` is spread round-robin across
``dp_spread_width`` consecutive rotation members (home DP first), dividing
its connection load — and therefore the paper's C5 per-DP ephemeral-port
ceiling — across the set, while cold functions stay sticky to one DP and
keep centralized in-flight accounting. ``dp_conn_reuse`` adds a keep-alive
connection pool on the DP invoke path (port per connection, not per
request). Both default off; the sticky one-connection-per-request front end
stays bit-identical.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Dict, Generator, List, Optional

from repro.core.abstractions import DataPlaneInfo, Function, WorkerNodeInfo
from repro.core.control_plane import ControlPlane
from repro.core.costmodel import CostModel, DEFAULT_COSTS
from repro.core.data_plane import DataPlane
from repro.core.leader_election import LeaderElector
from repro.core.metrics import Collector
from repro.core.persistence import SimStore
from repro.core.request import Invocation, InvocationMode
from repro.core.worker import WorkerDaemon
from repro.simcore import Environment, Event, Interrupt, grid_ceil, stable_hash


def fn_dp_set(fn: str, backends: List[int], width: int) -> tuple:
    """The DP-set for a spread function: ``width`` consecutive members of the
    LB rotation starting at the function's home slot (``stable_hash(fn) %
    len(backends)``), home first. Pure and process-independent — any front
    end (or test) computes the same set from the same rotation, exactly like
    the CP's fn→shard-set. Width is clamped to the rotation size; width 1
    degrades to the sole-DP sticky pick."""
    n = len(backends)
    width = max(1, min(width, n))
    home = stable_hash(fn) % n
    return tuple(backends[(home + i) % n] for i in range(width))


class _HeartbeatWheel:
    """Per-CP-shard worker-heartbeat aggregator.

    The paper's C9 load side-effect (every worker beat touches the owning CP
    shard's shared structures) used to be modeled with one generator process
    per worker, each beat spawning a sub-process to acquire the shard lock —
    ~5 heap events per beat, O(n_workers) event tax at 5000 workers. The
    wheel replaces all of a shard's per-worker processes with one process and
    a deadline heap: each worker's beat instants are *identical* (same
    ``hb-{wid}`` RNG phase draw, same accumulated ``+= period`` float chain),
    beats due at the same instant run in worker-id order, and the lock touch
    itself goes through ``Resource.reserve`` — zero events unless a creation
    actually collides with the beat (see control_plane.heartbeat).
    """

    __slots__ = ("heap", "proc", "sleep_until")

    def __init__(self):
        self.heap: List[tuple] = []     # (beat deadline, wid)
        self.proc = None                # the wheel's driver Process
        self.sleep_until: Optional[float] = None


class Cluster:
    def __init__(self, env: Environment, n_workers: int = 93,
                 n_data_planes: int = 3, n_control_planes: int = 3,
                 runtime: str = "firecracker",
                 costs: Optional[CostModel] = None,
                 persist_sandbox_state: bool = False,
                 enable_ha_sim: bool = False,
                 sandbox_concurrency: int = 1,
                 hedge_after: Optional[float] = None,
                 lb_policy: str = "least_loaded",
                 placement_policy: str = "balanced",
                 cp_shards: int = 1,
                 cp_rebalance_enabled: bool = False,
                 cp_rebalance_period: Optional[float] = None,
                 cp_rebalance_hot_factor: Optional[float] = None,
                 cp_rebalance_max_moves: Optional[int] = None,
                 cp_fn_split_enabled: bool = False,
                 cp_fn_split_max_shards: Optional[int] = None,
                 cp_fn_split_min_load: Optional[float] = None,
                 cp_fn_split_cooldown: Optional[float] = None,
                 cp_ep_flush_coalesce: Optional[bool] = None,
                 dp_spread_enabled: bool = False,
                 dp_spread_width: Optional[int] = None,
                 dp_spread_min_rate: Optional[float] = None,
                 dp_conn_reuse: Optional[bool] = None,
                 dp_conn_idle_timeout: Optional[float] = None,
                 cp_incremental_recovery: bool = True,
                 cp_vector_windows: bool = False,
                 cp_batched_eviction: bool = True,
                 hb_cohort_quantum: Optional[float] = None,
                 persist_group_commit: Optional[bool] = None,
                 persist_read_per_record: Optional[float] = None,
                 cp_checkpoint_enabled: bool = False,
                 cp_checkpoint_period: Optional[float] = None,
                 create_hook: Optional[Callable] = None,
                 teardown_hook: Optional[Callable] = None,
                 live_backend: Optional[object] = None):
        self.env = env
        # live execution mode (repro.live.LiveBackend): the backend supplies
        # the worker hooks and the invoke-path admit/collect unless explicit
        # hooks override it; None (default) keeps the DES path bit-identical
        self.live_backend = live_backend
        if live_backend is not None:
            create_hook = create_hook or live_backend.create_hook
            teardown_hook = teardown_hook or live_backend.teardown_hook
        self.costs = (costs or DEFAULT_COSTS).dirigent
        self.collector = Collector()
        self._persist_group_commit = (
            self.costs.persist_group_commit if persist_group_commit is None
            else persist_group_commit)
        self.store = SimStore(
            env, fsync_latency=self.costs.persist_write,
            replication_latency=self.costs.persist_replication,
            read_latency=self.costs.persist_read,
            n_replicas=n_control_planes,
            fsync_sigma=self.costs.persist_write_sigma,
            stall_prob=self.costs.persist_stall_prob,
            stall=self.costs.persist_stall,
            group_commit=self._persist_group_commit,
            max_batch=self.costs.persist_max_batch,
            read_per_record=(
                self.costs.persist_read_per_record
                if persist_read_per_record is None
                else persist_read_per_record),
            snapshot_load_per_record=self.costs.cp_snapshot_load_per_record,
            checkpoint_enabled=cp_checkpoint_enabled)
        # Sandbox ids are allocated from one cluster-wide counter shared by
        # every CP replica: a freshly elected leader must not reissue ids the
        # deposed leader already handed to workers, or its new sandboxes would
        # silently shadow adopted ones in ``worker.sandboxes``.
        self._sandbox_ids = itertools.count(1)
        self.control_planes: List[ControlPlane] = [
            ControlPlane(env, i, self.costs, self, self.store, self.collector,
                         persist_sandbox_state=persist_sandbox_state,
                         placement_policy=placement_policy,
                         cp_shards=cp_shards,
                         rebalance_enabled=cp_rebalance_enabled,
                         rebalance_period=cp_rebalance_period,
                         rebalance_hot_factor=cp_rebalance_hot_factor,
                         rebalance_max_moves=cp_rebalance_max_moves,
                         fn_split_enabled=cp_fn_split_enabled,
                         fn_split_max_shards=cp_fn_split_max_shards,
                         fn_split_min_load=cp_fn_split_min_load,
                         fn_split_cooldown=cp_fn_split_cooldown,
                         ep_flush_coalesce=cp_ep_flush_coalesce,
                         incremental_recovery=cp_incremental_recovery,
                         vector_windows=cp_vector_windows,
                         batched_eviction=cp_batched_eviction,
                         checkpoint_enabled=cp_checkpoint_enabled,
                         checkpoint_period=cp_checkpoint_period)
            for i in range(n_control_planes)
        ]
        self.data_planes: List[DataPlane] = [
            DataPlane(env, i, self.costs, self, self.collector,
                      concurrency=sandbox_concurrency,
                      hedge_after=hedge_after, lb_policy=lb_policy,
                      conn_reuse=dp_conn_reuse,
                      conn_idle_timeout=dp_conn_idle_timeout)
            for i in range(n_data_planes)
        ]
        self.workers: Dict[int, WorkerDaemon] = {}
        for wid in range(n_workers):
            # three-octet address plan: the old (10, 0, wid // 250, wid % 250)
            # overflowed an octet at 64k workers — the 100k cells need the
            # full 10.0.0.0/8 space
            info = WorkerNodeInfo(
                worker_id=wid, name=f"w{wid}",
                ip=(10, (wid >> 16) & 255, (wid >> 8) & 255, wid & 255),
                port=9000)
            self.workers[wid] = WorkerDaemon(env, info, self.costs,
                                             runtime=runtime,
                                             create_hook=create_hook,
                                             teardown_hook=teardown_hook,
                                             live_backend=live_backend)
        self.elector = LeaderElector(env, self, self.costs,
                                     enable_hb_sim=enable_ha_sim)
        self.enable_ha_sim = enable_ha_sim
        self._inv_ids = itertools.count(1)
        # one heartbeat wheel per CP shard (the same wid % cp_shards
        # partition the CP health monitors use)
        self._cp_shards = max(1, cp_shards)
        self._hb_wheels = [_HeartbeatWheel() for _ in range(self._cp_shards)]
        # cohort mode: beat deadlines rounded UP onto a shared grid, whole
        # same-deadline cohorts delivered per heap event (heartbeat_batch).
        # None (default) keeps per-worker exact deadlines bit-identically;
        # the quantum must be a power-of-two fraction of the heartbeat
        # period so ``t + period`` stays on-grid exactly (see grid_ceil)
        self._hb_cohort_quantum = hb_cohort_quantum
        if hb_cohort_quantum is not None:
            ratio = self.costs.worker_heartbeat_period / hb_cohort_quantum
            assert ratio == int(ratio), (
                "hb_cohort_quantum must divide worker_heartbeat_period "
                "exactly, or cohorts drift off-grid after one beat")
        self._started = False
        # front-end LB rotation: dead DPs keep receiving traffic until the
        # keepalived health check removes them (paper §5.4 DP failover)
        self._lb_backends = [dp.dp_id for dp in self.data_planes]
        # fn→DP-set steering (multi-DP serving; off by default). The table
        # maps a hot function to its DP-set tuple (home first); functions
        # absent from the table take the sticky hash pick unchanged.
        c = self.costs
        self._dp_spread_enabled = dp_spread_enabled
        self._dp_spread_width = (c.dp_spread_width if dp_spread_width is None
                                 else dp_spread_width)
        self._dp_spread_min_rate = (
            c.dp_spread_min_rate if dp_spread_min_rate is None
            else dp_spread_min_rate)
        self.fn_dp_table: Dict[str, tuple] = {}
        self._dp_rr: Dict[str, int] = {}        # per-fn round-robin cursor
        self._fe_counts: Dict[str, int] = {}    # arrivals this window
        self._fe_window_start = env.now
        self._dp_last_over: Dict[str, float] = {}   # last instant over rate

    # -- topology ------------------------------------------------------------------
    def control_planes_alive(self) -> List[ControlPlane]:
        return [cp for cp in self.control_planes if cp.alive]

    def control_plane_by_id(self, cp_id: Optional[int]) -> Optional[ControlPlane]:
        if cp_id is None:
            return None
        cp = self.control_planes[cp_id]
        return cp if cp.alive else None

    def control_plane_leader(self) -> Optional[ControlPlane]:
        return self.control_plane_by_id(self.elector.leader_id)

    def data_planes_alive(self) -> List[DataPlane]:
        return [dp for dp in self.data_planes if dp.alive]

    def worker_by_id(self, wid: int) -> Optional[WorkerDaemon]:
        return self.workers.get(wid)

    # -- startup ------------------------------------------------------------------
    def start(self) -> None:
        """Elect a leader, register components, start heartbeats."""
        assert not self._started
        self._started = True
        self.elector.bootstrap()
        leader = self.control_plane_leader()
        done = self.env.event()

        def boot(env):
            for dp in self.data_planes:
                info = DataPlaneInfo(dp_id=dp.dp_id,
                                     ip=(10, 1, 0, dp.dp_id), port=8080)
                yield from leader.register_data_plane(info)
            if self._persist_group_commit:
                # bulk boot: the whole registration log lands through
                # write_many in O(batches) group commits instead of
                # O(n_workers) serialized fsyncs; every registration commits
                # at the same instant, so heartbeats (started afterwards, in
                # the same worker order and off the same hb-{wid} streams)
                # never race a still-draining boot log
                yield from leader.register_workers_bulk(
                    [w.info for w in self.workers.values()])
                for wid in self.workers:
                    self._hb_wheel_add(wid)
            else:
                for wid, w in self.workers.items():
                    yield from leader.register_worker(w.info)
                    # the daemon starts heartbeating the moment it registers.
                    # Starting these only after the WHOLE boot loop used to
                    # let early-registered workers exceed the heartbeat
                    # timeout while later registrations' persistence writes
                    # were still draining (boot is O(n_workers) fsyncs of sim
                    # time), silently evicting ~a quarter of a 1000-worker
                    # fleet before first beat.
                    self._hb_wheel_add(wid)
            done.succeed(None)

        self.env.process(boot(self.env), name="cluster-boot")
        self.env.run_until_event(done)

    # -- heartbeat wheel ------------------------------------------------------
    def _hb_wheel_add(self, wid: int) -> None:
        """Enroll a worker in its shard's heartbeat wheel, beating from now.

        The first beat lands at ``(now + phase) + period`` — the same float
        arithmetic, in the same order, as the retired per-worker generator
        (process start, ``timeout(phase)``, then ``timeout(period)`` per
        beat), with the phase drawn from the same ``hb-{wid}`` stream, so
        every beat instant is bit-identical to the per-process model."""
        c = self.costs
        phase = self.env.rng(f"hb-{wid}").uniform(0, c.worker_heartbeat_period)
        first = (self.env.now + phase) + c.worker_heartbeat_period
        if self._hb_cohort_quantum is not None:
            # cohort mode: the first beat snaps UP to the grid; every later
            # beat adds the (grid-multiple) period, so the worker stays in
            # its cohort forever. A beat moves at most one quantum later
            # than its exact instant — keep the quantum well under
            # ``worker_heartbeat_timeout - 2*period`` so quantization alone
            # can never push a live worker past the eviction deadline.
            first = grid_ceil(first, self._hb_cohort_quantum)
        wheel = self._hb_wheels[wid % self._cp_shards]
        heapq.heappush(wheel.heap, (first, wid))
        if wheel.proc is None or not wheel.proc.is_alive:
            wheel.proc = self.env.process(
                self._hb_wheel_run(wheel),
                name=f"hb-wheel-{wid % self._cp_shards}")
        elif wheel.sleep_until is not None and first < wheel.sleep_until:
            # the wheel is parked past the new worker's first beat: preempt
            wheel.proc.interrupt("earlier-deadline")

    def _hb_wheel_run(self, wheel: _HeartbeatWheel) -> Generator:
        env, heap = self.env, wheel.heap
        period = self.costs.worker_heartbeat_period
        cohorts = self._hb_cohort_quantum is not None
        while True:
            while heap and heap[0][0] <= env.now:
                if cohorts:
                    # cohort mode: drain EVERY beat sharing this quantized
                    # deadline in one go — heap pops with equal deadlines
                    # come out in worker-id order (tuple comparison), and
                    # the whole cohort becomes one heartbeat_batch call
                    # instead of n lock reserves on the same instant
                    t = heap[0][0]
                    live: List[int] = []
                    while heap and heap[0][0] == t:
                        _, wid = heapq.heappop(heap)
                        w = self.workers.get(wid)
                        if w is not None and w.daemon_alive:
                            live.append(wid)
                        heapq.heappush(heap, (t + period, wid))
                    if live:
                        cp = self.control_plane_leader()
                        if cp is not None:
                            cp.heartbeat_batch(live)
                    continue
                # due beats run in (deadline, worker-id) order — bit-identical
                # instants, deterministic tie order
                t, wid = heapq.heappop(heap)
                w = self.workers.get(wid)
                if w is not None and w.daemon_alive:
                    cp = self.control_plane_leader()
                    if cp is not None:
                        cp.heartbeat(wid)
                # next beat continues this worker's own float-add chain
                heapq.heappush(heap, (t + period, wid))
            wheel.sleep_until = heap[0][0]
            try:
                # absolute-deadline sleep: the beat must run at the heap
                # instant bit-exactly (now + (t - now) != t in float)
                yield env.timeout_at(wheel.sleep_until)
            except Interrupt:
                pass        # a newly added worker beats earlier: re-aim
            wheel.sleep_until = None

    # -- user API -------------------------------------------------------------------
    def register(self, fn: Function) -> Event:
        """Returns an event that fires when registration completes."""
        leader = self.control_plane_leader()
        done = self.env.event()

        def reg(env):
            yield from leader.register_function(fn)
            done.succeed(fn.name)

        self.env.process(reg(self.env), name=f"register-{fn.name}")
        return done

    def register_sync(self, fn: Function) -> None:
        self.env.run_until_event(self.register(fn))

    def invoke(self, function_name: str, exec_time: float,
               mode: InvocationMode = InvocationMode.SYNC,
               payload: Optional[Callable] = None,
               request: Optional[object] = None) -> Invocation:
        """Submit an invocation at env.now; returns the Invocation record.
        ``request`` (a ``LiveRequest``) rides the invocation to whatever
        sandbox the DP picks and is executed there by the live backend."""
        inv = Invocation(inv_id=next(self._inv_ids),
                         function_name=function_name,
                         arrival=self.env.now, exec_time=exec_time,
                         mode=mode, payload=payload, request=request)
        self.env.process(self._front_end(inv), name=f"inv-{inv.inv_id}")
        return inv

    # -- fn→DP-set steering (multi-DP serving) --------------------------------
    def spread_function(self, fn: str, width: Optional[int] = None) -> tuple:
        """Install (or re-derive) a DP-set for ``fn`` explicitly. Used by the
        auto-widener and by operators/tests pre-spreading a known-hot
        function before its first burst."""
        members = fn_dp_set(fn, self._lb_backends,
                            self._dp_spread_width if width is None else width)
        self.fn_dp_table[fn] = members
        self._dp_rr.setdefault(fn, 0)
        self._dp_last_over[fn] = self.env.now
        self.collector.event(self.env.now, "fn-dp-spread", (fn, members))
        return members

    def _note_arrival(self, fn: str) -> None:
        """Count front-end arrivals per window; widen a function's DP-set the
        moment it crosses the spread threshold mid-window (waiting for the
        window edge would eat a full burst on one DP's port pool)."""
        c = self.costs
        now = self.env.now
        if now - self._fe_window_start >= c.dp_spread_window:
            self._roll_spread_window(now)
        n = self._fe_counts.get(fn, 0) + 1
        self._fe_counts[fn] = n
        if (fn not in self.fn_dp_table and len(self._lb_backends) > 1
                and n >= self._dp_spread_min_rate * c.dp_spread_window):
            self.spread_function(fn)

    def _roll_spread_window(self, now: float) -> None:
        c = self.costs
        half = 0.5 * self._dp_spread_min_rate * c.dp_spread_window
        for fn, cnt in self._fe_counts.items():
            if fn in self.fn_dp_table and cnt >= half:
                self._dp_last_over[fn] = now
        stale = [fn for fn, members in self.fn_dp_table.items()
                 if len(members) > 1
                 and now - self._dp_last_over.get(fn, now) >= c.dp_spread_cooldown]
        for fn in stale:
            # cooled off: fold back to the sticky sole-DP path
            del self.fn_dp_table[fn]
            self._dp_rr.pop(fn, None)
            self._dp_last_over.pop(fn, None)
            self.collector.event(now, "fn-dp-narrow", fn)
        self._fe_counts.clear()
        self._fe_window_start = now

    def _steer(self, fn: str) -> "DataPlane":
        """Pick the DP for one invocation. Default path: the sticky hash pick,
        arithmetic-identical to the pre-spread front end. Spread path: round-
        robin over the function's DP-set, skipping members evicted from the
        rotation (a *dead* member still in rotation is returned as-is — the
        caller models the connection-refused window, same as sticky)."""
        if self._dp_spread_enabled:
            self._note_arrival(fn)
            members = self.fn_dp_table.get(fn)
            if members is not None:
                live = [d for d in members if d in self._lb_backends]
                if live:
                    cur = self._dp_rr.get(fn, 0)
                    self._dp_rr[fn] = cur + 1
                    return self.data_planes[live[cur % len(live)]]
        idx = stable_hash(fn) % len(self._lb_backends)
        return self.data_planes[self._lb_backends[idx]]

    def _front_end(self, inv: Invocation) -> Generator:
        """HAProxy front-end: function-hash steering across the LB rotation
        (which may briefly include a crashed DP until keepalived reacts).
        With ``dp_spread_enabled``, hot functions steer via the fn→DP-set
        table instead (see ``_steer``)."""
        yield self.env.timeout(self.costs.lb_hop)
        if not self._lb_backends:
            inv.failed = True
            inv.failure_reason = "no data plane"
            inv.t_done = self.env.now
            self.collector.done(inv)
            return
        dp = self._steer(inv.function_name)
        if not dp.alive:
            inv.failed = True
            inv.failure_reason = "connection refused (dead DP in rotation)"
            inv.t_done = self.env.now
            self.collector.done(inv)
            return
        if inv.mode == InvocationMode.ASYNC:
            # async: persist to the durable queue, ack client, deliver with
            # at-least-once retry (paper §3.4.2)
            yield from self.store.write(f"asyncq/{inv.inv_id}", b"1")
            self.env.process(self._async_deliver(inv, dp),
                             name=f"async-{inv.inv_id}")
            return
        yield from dp.handle(inv)

    def _async_deliver(self, inv: Invocation, dp: DataPlane,
                       timeout: float = 60.0, max_retries: int = 3) -> Generator:
        for attempt in range(max_retries + 1):
            inv.retries = attempt
            done = self.env.event()

            def run(env, inv=inv, dp=dp, done=done):
                yield from dp.handle(inv)
                if not done.triggered:
                    done.succeed("ok")

            self.env.process(run(self.env), name=f"async-try-{inv.inv_id}")
            idx, _ = yield self.env.any_of([done, self.env.timeout(timeout)])
            if idx == 0 and not inv.failed:
                break
            # retry: reset failure state, re-deliver (at-least-once)
            alive = self.data_planes_alive()
            if not alive:
                break
            dp = alive[stable_hash(inv.function_name) % len(alive)]
            inv.failed = False
        yield from self.store.write(f"asyncq/{inv.inv_id}", None)

    # -- failure injection (paper §5.4) ----------------------------------------------
    def fail_control_plane_leader(self) -> None:
        leader = self.control_plane_leader()
        if leader:
            leader.stop()
            self.collector.event(self.env.now, "cp-failed", leader.cp_id)

    def fail_data_plane(self, dp_id: int) -> None:
        dp = self.data_planes[dp_id]
        dp.fail()
        self.collector.event(self.env.now, "dp-failed", dp_id)

        def lb_evict(env):
            # keepalived health-check detection, then rotation update
            yield env.timeout(self.costs.lb_health_check)
            if dp_id in self._lb_backends:
                self._lb_backends.remove(dp_id)
        self.env.process(lb_evict(self.env), name=f"lb-evict-{dp_id}")
        self.env.process(self._recover_data_plane(dp_id), name=f"dp-recover-{dp_id}")

    def _recover_data_plane(self, dp_id: int) -> Generator:
        """systemd restart -> re-register with CP -> pull caches -> LB reload."""
        c = self.costs
        yield self.env.timeout(c.systemd_restart_delay)
        yield self.env.timeout(c.dp_resync_cost)
        dp = self.data_planes[dp_id]
        leader = self.control_plane_leader()
        functions, endpoints = [], {}
        if leader is not None:
            # both snapshots iterate insertion-ordered dicts whose writes
            # (install_function / sandbox adoption) happen in deterministic
            # event order, so the recovered DP tables replay byte-identically
            # (regression: test_fault_tolerance.py::test_dp_recovery_snapshot_order)
            functions = list(leader.functions.keys())  # simlint: ok(dict-iteration): install order is deterministic
            endpoints = {fn: [s for s in st.sandboxes.values()]  # simlint: ok(dict-iteration): creation order is deterministic
                         for fn, st in leader.functions.items()}  # simlint: ok(dict-iteration): install order is deterministic
        dp.recover(functions, endpoints)
        yield self.env.timeout(c.lb_reconfigure)
        if dp_id not in self._lb_backends:
            self._lb_backends.append(dp_id)
            self._lb_backends.sort()
        self.collector.event(self.env.now, "dp-recovered", dp_id)

    def fail_worker_daemon(self, wid: int) -> None:
        self.workers[wid].fail_daemon()
        self.collector.event(self.env.now, "worker-daemon-failed", wid)

    def recover_worker_daemon(self, wid: int) -> None:
        self.workers[wid].recover_daemon()
        leader = self.control_plane_leader()
        if leader:
            leader.restore_worker(wid)
        self.collector.event(self.env.now, "worker-daemon-recovered", wid)

    def fail_worker_node(self, wid: int) -> None:
        self.workers[wid].fail_node()
        self.collector.event(self.env.now, "worker-node-failed", wid)
