"""Concurrency-based autoscaling policy (Knative KPA defaults).

The paper (§4) states Dirigent implements and uses *Knative's default*
scheduling policies so the comparison is apples-to-apples; both our Dirigent
model and the Knative baseline share this exact implementation.

Algorithm (KPA): desired = ceil(avg_concurrency / target). Two sliding
windows — a 60 s *stable* window and a 6 s *panic* window. If the panic
desired count is >= 2x the current ready count, the autoscaler enters panic
mode and never scales down while panicking. Scale-to-zero happens only after
the stable window average is zero for the scale-to-zero grace period.

Mechanism → paper section map (claim ids C1..C12 as in costmodel.py):

  * ``ConcurrencyWindow`` — the KPA stable/panic averaging windows, fed by
    the DP metric pushes (§3.2: periodic every 250 ms + urgent on queue
    formation). Sampling is per-function, which is why metric ingestion
    needs no CP lock.
  * ``FunctionAutoscalerState.desired`` — §4 "Scheduling policies": the
    per-function decision the control plane's reconcile loop acts on every
    ``autoscale_period`` (2 s). Acting on the decision — not computing it —
    is what serializes on the CP scale lock (C1); under a skewed function
    mix that lock pressure is what the load-adaptive sharded CP rebalances
    (control_plane.py).
  * ``no_downscale_until`` — §3.4.1 post-recovery hold: a recovering leader
    must not scale down on a partial view (``recovery_no_downscale``, 60 s).
  * ``max_scale`` / panic no-downscale — Knative semantics kept exactly so
    the Dirigent model and the Knative baseline share one implementation
    (apples-to-apples, §5 methodology).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List

import numpy as np

from repro.core.abstractions import ScalingConfig


def split_shares(desired: int, k: int, cursor: int) -> List[int]:
    """Divide a desired replica count across ``k`` subshards of a split
    function (control_plane.py ``cp_fn_split_enabled``): everyone gets
    ``desired // k``, and the ``r = desired % k`` residual replicas land on
    the subshards at positions ``(cursor + i) % k``. The caller advances
    ``cursor`` by ``r`` after each assignment, so over successive autoscale
    decisions the residual rotates deterministically — no subshard
    permanently carries the remainder, and two runs with the same event
    sequence produce the same shares (the split path stays seed-exact)."""
    base, r = divmod(desired, k)
    return [base + (1 if (i - cursor) % k < r else 0) for i in range(k)]


@dataclass
class ConcurrencyWindow:
    """Time-bucketed average of a concurrency signal.

    Times and values live in parallel deques (not one deque of tuples) so
    ``average`` is a C-speed ``sum`` over plain floats — same addition order,
    bit-identical result, no per-sample generator frame. A cold burst parks
    thousands of samples in the window and re-averages on every urgent
    reconcile; this sum was one of the hottest loops in the churn benchmark."""

    horizon: float
    times: Deque[float] = field(default_factory=deque)
    values: Deque[float] = field(default_factory=deque)

    def record(self, t: float, value: float) -> None:
        self.times.append(t)
        self.values.append(value)
        self._evict(t)

    def _evict(self, t: float) -> None:
        times, values = self.times, self.values
        cut = t - self.horizon
        while times and times[0] < cut:
            times.popleft()
            values.popleft()

    def average(self, t: float) -> float:
        self._evict(t)
        if not self.values:
            return 0.0
        return sum(self.values) / len(self.values)

    def max(self, t: float) -> float:
        self._evict(t)
        if not self.values:
            return 0.0
        return max(self.values)


class VectorWindow:
    """Array-backed ``ConcurrencyWindow``: same sliding-window semantics on a
    numpy ring buffer.

    A cold burst at 20k+ workers parks tens of thousands of samples per
    function and re-averages on every urgent reconcile; the deque window pays
    a Python-level popleft per evicted sample plus a C ``sum`` per average.
    Here eviction is one ``np.searchsorted`` (sample times are monotone
    non-decreasing — the DES clock only moves forward) and the average is one
    ``ndarray.sum`` over a contiguous slice.

    NOT bit-identical to the deque reference: numpy uses pairwise summation,
    so the average can differ from sequential ``sum`` in the last float bits.
    The autoscaler only consumes the average through ``math.ceil(avg /
    target)``, which is insensitive to last-bit noise except exactly at
    integer boundaries, so this class is *decision-identical* in practice and
    is gated behind ``vectorized=True`` (default off; tests/test_vectorized.py
    asserts decision identity on randomized streams)."""

    __slots__ = ("horizon", "_t", "_v", "_lo", "_hi")

    _INIT_CAP = 64

    def __init__(self, horizon: float):
        self.horizon = horizon
        self._t = np.empty(self._INIT_CAP, dtype=np.float64)
        self._v = np.empty(self._INIT_CAP, dtype=np.float64)
        self._lo = 0
        self._hi = 0

    def _compact(self, need: int) -> None:
        n = self._hi - self._lo
        cap = self._t.shape[0]
        if n + need > cap:
            new_cap = max(cap * 2, n + need, self._INIT_CAP)
            nt = np.empty(new_cap, dtype=np.float64)
            nv = np.empty(new_cap, dtype=np.float64)
            nt[:n] = self._t[self._lo:self._hi]
            nv[:n] = self._v[self._lo:self._hi]
            self._t, self._v = nt, nv
        else:
            self._t[:n] = self._t[self._lo:self._hi]
            self._v[:n] = self._v[self._lo:self._hi]
        self._lo, self._hi = 0, n

    def record(self, t: float, value: float) -> None:
        if self._hi == self._t.shape[0]:
            self._compact(1)
        self._t[self._hi] = t
        self._v[self._hi] = value
        self._hi += 1
        self._evict(t)

    def _evict(self, t: float) -> None:
        cut = t - self.horizon
        # samples strictly older than the cut drop out, matching the deque
        # reference's ``times[0] < cut`` loop
        self._lo += int(np.searchsorted(self._t[self._lo:self._hi], cut,
                                        side="left"))

    def __len__(self) -> int:
        return self._hi - self._lo

    def average(self, t: float) -> float:
        self._evict(t)
        n = self._hi - self._lo
        if n == 0:
            return 0.0
        return float(self._v[self._lo:self._hi].sum()) / n

    def max(self, t: float) -> float:
        self._evict(t)
        if self._hi == self._lo:
            return 0.0
        return float(self._v[self._lo:self._hi].max())


class FunctionAutoscalerState:
    """Per-function autoscaler state machine."""

    def __init__(self, scaling: ScalingConfig, vectorized: bool = False):
        self.scaling = scaling
        win = VectorWindow if vectorized else ConcurrencyWindow
        self.stable = win(scaling.stable_window)
        self.panic = win(scaling.panic_window)
        self.in_panic_since: float | None = None
        self.max_panic_desired = 0
        self.zero_since: float | None = None
        self.no_downscale_until: float = 0.0  # recovery hold (paper §3.4.1)

    def record_metric(self, t: float, concurrency: float) -> None:
        self.stable.record(t, concurrency)
        self.panic.record(t, concurrency)

    def desired(self, t: float, ready: int) -> int:
        s = self.scaling
        stable_avg = self.stable.average(t)
        panic_avg = self.panic.average(t)
        desired_stable = math.ceil(stable_avg / s.target_concurrency)
        desired_panic = math.ceil(panic_avg / s.target_concurrency)

        # Panic entry: short-window demand at least 2x what we have ready.
        if desired_panic >= s.panic_threshold * max(ready, 1) and desired_panic > 0:
            self.in_panic_since = t
            self.max_panic_desired = max(self.max_panic_desired, desired_panic)
        # Panic exit after a full stable window without re-triggering.
        if self.in_panic_since is not None and t - self.in_panic_since > s.stable_window:
            self.in_panic_since = None
            self.max_panic_desired = 0

        if self.in_panic_since is not None:
            d = max(desired_panic, self.max_panic_desired, ready)
        else:
            d = desired_stable

        d = min(d, s.max_scale)

        # Scale-to-zero only after the grace period of zero load.
        if d == 0:
            if self.zero_since is None:
                self.zero_since = t
            if t - self.zero_since < s.scale_to_zero_grace:
                d = min(ready, 1) if ready > 0 else 0
        else:
            self.zero_since = None

        # Post-recovery hold: never downscale before no_downscale_until.
        if t < self.no_downscale_until:
            d = max(d, ready)
        return d
