"""Calibrated service-time constants for the cluster-manager simulations.

Every constant is traceable either to a number stated in the paper or to a
calibration target (a paper claim C1..C12, see DESIGN.md §1 and
docs/benchmarks.md). The *loaded* behaviour — saturation throughput, tail
blow-ups — is NOT encoded here; it emerges from queueing at the modeled
resources.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields


@dataclass
class DirigentCosts:
    """Dirigent mechanism constants and the paper measurements they model.

    Key calibration anchors (claim ids C1..C12 are cross-referenced from the
    benchmarks; see docs/benchmarks.md for the figure mapping):

    * ``cp_scale_lock_hold`` — the C1 bottleneck. The paper attributes
      Dirigent's ~2500 sandbox creations/s ceiling (93 nodes, Fig 7) to
      "access congestion on shared data structures used for autoscaling":
      0.36 ms of serialized state-update work per creation ≈ 2778/s through
      one lock. With ``cp_shards > 1`` each control-plane shard holds its own
      lock over its slice, so the modeled ceiling scales with the shard count
      (benchmarks/churn_scale.py ``cp_shard_sweep``).
    * ``cp_heartbeat_lock_hold`` — C9: heartbeat processing touches the same
      shared structures, which is what degrades creation throughput at 5000
      workers (5000 workers × 2 hb/s × 12 µs ≈ 12% of one lock).
    * ``cp_cross_shard_op`` — sharded-CP fan-out hop: the in-memory handoff
      one shard pays per foreign shard it touches (work-stealing capacity
      spill, post-eviction reconcile fan-out, function-migration handoff).
      Modeled like ``channel_op`` (a Go channel/atomic handoff, no network),
      slightly dearer for the extra synchronization; it only exists when
      ``cp_shards > 1``.
    * ``cp_rebalance_*`` / ``cp_steal_backoff`` — load-adaptive sharding
      policy knobs (hot-shard rebalancing + work-stealing spill); no paper
      anchor (the paper's CP is the static single-shard configuration).
      Operator guidance: docs/operations.md.
    * ``grpc_call`` / ``channel_op`` — paper §3: Dirigent components talk
      gRPC across processes but exchange information through in-memory
      channels inside the monolithic CP (vs RPC+etcd round-trips in K8s).
    * ``persist_write`` (+ sigma/stall) — C3: fsync'd Redis AOF append; with
      sandbox state persisted on the critical path (the ablation) creation
      throughput caps at ~1000/s and p99 surges from AOF-rewrite stalls.
    * ``containerd_create_median`` / ``firecracker_create_median`` — Fig 7
      regimes: containerd cold boots in the 100 ms band and is kernel-lock
      bound at ~1750/s on 93 nodes (C2); Firecracker snapshot restores at
      ~40 ms p50 (paper §5.2.3).
    * ``raft_*`` / ``cp_recovery_*`` — C10: detect + elect + fetch + DP sync
      ≈ 10 ms control-plane failover.
    * ``lb_reconfigure`` / ``lb_health_check`` — C11: keepalived/HAProxy
      failover ≈ 2 s end to end.
    """

    # -- networking --------------------------------------------------------
    grpc_call: float = 0.3e-3          # one gRPC hop (paper §4: components talk gRPC)
    lb_hop: float = 0.2e-3             # HAProxy front-end hop
    channel_op: float = 2e-6           # in-memory Go channel handoff (monolith)
    worker_nat_hop: float = 0.2e-3     # iptables NAT on the worker node
    hop_jitter_sigma: float = 0.35     # lognormal jitter on network hops (p99)

    # -- data plane ---------------------------------------------------------
    dp_proxy_cpu: float = 0.15e-3      # per-request CPU in the DP proxy
    dp_cores: int = 10                 # xl170: 10 physical cores
    dp_port_pool: int = 28_000         # ephemeral ports per DP node
    dp_port_hold: float = 20.0         # TIME_WAIT-ish hold per connection
    metrics_report_period: float = 0.25  # DP -> CP autoscaling metric push

    # -- multi-data-plane serving (dp_spread_* / dp_conn_*) ------------------
    # The DP-side twin of the cp_* scaling knobs: the paper's C5 ceiling
    # (one DP's ephemeral ports cap the warm path; 28k ports / 20 s
    # TIME_WAIT ≈ 1400 conn/s sustained) is a *per-DP* limit, so a single
    # hot function — sticky to one DP under function-hash steering — hits
    # it no matter how many DPs exist. No paper anchor (the paper's front
    # end is sticky, one-connection-per-request); all of these only take
    # effect via ``Cluster(dp_spread_enabled=True)`` / ``dp_conn_reuse`` —
    # the defaults keep the sticky no-reuse front end bit-identically.
    # Operator guidance: docs/operations.md.
    dp_spread_width: int = 3           # DP-set size for a spread function:
    #                                    members divide its connection load,
    #                                    but each extra member dilutes the
    #                                    in-flight signal one DP aggregates
    dp_spread_min_rate: float = 1000.0  # front-end arrivals/s before a
    #                                    function is spread — below the
    #                                    ~1400 conn/s port ceiling so the
    #                                    set widens before ports convoy
    dp_spread_window: float = 1.0      # arrival-rate measurement window
    dp_spread_cooldown: float = 10.0   # a spread function folds back to its
    #                                    sole DP only after staying under
    #                                    half of min_rate this long (bounds
    #                                    widen/narrow flapping on bursts)
    dp_conn_reuse: bool = False        # keep-alive connection pool on the
    #                                    invoke path: a port is acquired per
    #                                    *connection* and reused across
    #                                    requests to the same endpoint,
    #                                    instead of one port + TIME_WAIT
    #                                    hold per request
    dp_conn_idle_timeout: float = 60.0  # idle keep-alive expiry; a timed-out
    #                                    conn closes client-side, so its
    #                                    port pays the dp_port_hold
    #                                    TIME_WAIT (endpoint-teardown closes
    #                                    are server-side FINs: port freed
    #                                    immediately)
    cp_ep_flush_coalesce: bool = False  # batch the CP->DP endpoint broadcast
    #                                    across CP shards per DP: all shards'
    #                                    updates queued in one flush window
    #                                    ride one combined broadcast (M per-DP
    #                                    deliveries per turn instead of
    #                                    N shards x M DPs)

    # -- control plane ------------------------------------------------------
    cp_sched_cpu: float = 0.05e-3      # autoscale+place decision compute ("fast")
    cp_heartbeat_lock_hold: float = 12e-6  # heartbeat touch of shared health
    #                                    structures (C9: degrades creation
    #                                    throughput at 5000 workers)
    cp_scale_lock_hold: float = 0.36e-3  # shared autoscaling state lock per
    #                                    sandbox create/destroy. C1: caps the CP
    #                                    at ~2500 creations/s (paper: "access
    #                                    congestion on shared data structures
    #                                    used for autoscaling").
    cp_cross_shard_op: float = 4e-6    # sharded-CP fan-out hop per foreign
    #                                    shard touched (capacity spill,
    #                                    post-eviction reconcile); in-memory,
    #                                    ~2x channel_op for the extra sync.
    #                                    Unused when cp_shards == 1.
    autoscale_period: float = 2.0      # autoscaler evaluation tick (KPA default)
    recovery_no_downscale: float = 60.0  # paper §3.4.1

    # -- load-adaptive sharding (cp_rebalance_* / work stealing) -------------
    # These are policy knobs for the load-adaptive sharded CP (the follow-on
    # to C1/C9 this repo adds; see docs/operations.md for operator guidance).
    # They model no paper measurement — the paper's CP is the cp_shards=1 /
    # rebalancing-off configuration — so they only take effect when
    # ``Cluster(cp_rebalance_enabled=True)`` (rebalancer) or cp_shards > 1
    # (work-stealing spill) is selected.
    cp_rebalance_period: float = 1.0   # rebalancer tick: long enough to
    #                                    smooth burst noise, short enough to
    #                                    react within a few autoscale periods
    cp_rebalance_hot_factor: float = 2.0  # migrate only when the hottest
    #                                    shard's load signal exceeds this
    #                                    multiple of the coldest's
    cp_rebalance_max_moves: int = 8    # max functions migrated per handoff
    cp_rebalance_min_load: float = 1e-3  # hot-shard floor (seconds of lock
    #                                    wait per tick): below it, imbalance
    #                                    is noise and migration pure overhead
    cp_rebalance_cooldown: float = 5.0  # per-function re-migration holdoff:
    #                                    bounds ping-ponging of a function
    #                                    whose load dominates every shard
    cp_steal_backoff: float = 0.05     # a capacity probe that found a victim
    #                                    shard full demotes it to the end of
    #                                    the steal order for this long, so a
    #                                    saturated cluster degrades to the
    #                                    deterministic round-robin probe

    # -- per-function creation sharding (cp_fn_split_*) ----------------------
    # Escalation past whole-function rebalancing: one function whose creation
    # load alone exceeds the hot-cold gap cannot be *moved* anywhere useful —
    # it saturates whichever single scale lock owns it. With
    # ``Cluster(cp_fn_split_enabled=True)`` the rebalancer instead *splits*
    # such a function across a shard-set: per-subshard FunctionState slices,
    # each creating on its own scale lock and worker partition (the
    # Archipelago per-service semi-global partitioning idea applied to one
    # function). No paper anchor; operator guidance in docs/operations.md.
    cp_fn_split_max_shards: int = 4    # ceiling on a shard-set's size: each
    #                                    extra subshard adds an autoscale
    #                                    reconcile + a quiesce participant
    cp_fn_split_min_load: float = 4.0  # merge threshold, in heat units
    #                                    (creations charged to the slices,
    #                                    halved each rebalance tick): when a
    #                                    split function's summed slice heat
    #                                    decays below this, it folds back to
    #                                    its home shard
    cp_fn_split_cooldown: float = 10.0  # hysteresis on both edges: a freshly
    #                                    split function stays split at least
    #                                    this long, and a freshly merged one
    #                                    cannot re-split before it elapses —
    #                                    bounds split/merge flapping on a
    #                                    bursty function

    # -- persistence (Redis, AOF fsync always) -------------------------------
    persist_write: float = 0.85e-3     # fsync'd append median (C3 ablation:
    #                                    caps at ~1000 creations/s when sandbox
    #                                    state is persisted on the critical path)
    persist_write_sigma: float = 0.4   # lognormal fsync jitter
    persist_stall_prob: float = 0.002  # AOF-rewrite stalls (Redis): rare but
    persist_stall: float = 0.120       # long WAL holds -> p99 surge at ~500/s
    persist_read: float = 0.2e-3
    persist_replication: float = 0.5e-3  # sync replication to standbys
    persist_group_commit: bool = False  # WAL group commit: writers queued
    #                                    behind an in-flight fsync are absorbed
    #                                    into one batch committed by a single
    #                                    fsync + one replication round (and
    #                                    ``write_many`` bulk-appends the boot
    #                                    registration log in batches). Default
    #                                    OFF: the serialized per-write path is
    #                                    the paper's model and the event-budget
    #                                    pins assert it bit-identically;
    #                                    ``Cluster(persist_group_commit=True)``
    #                                    opts a run in (the 100k-worker boot
    #                                    needs it — see docs/operations.md).
    persist_max_batch: int = 512       # group-commit batch ceiling: one fsync
    #                                    covers at most this many queued writes
    persist_read_per_record: float = 0.0  # per-record cost of a prefix scan
    #                                    (``read_prefix``). 0.0 keeps the
    #                                    legacy flat ``persist_read`` latency
    #                                    (bit-identical); the 100k recovery
    #                                    benches set ~1e-6 s/record so a full
    #                                    ``worker/`` scan is honestly linear.
    cp_checkpoint_period: float = 5.0  # leader snapshot cadence when
    #                                    ``Cluster(cp_checkpoint_enabled=True)``
    #                                    — a compacted ``checkpoint/<epoch>``
    #                                    record written off the critical path
    cp_snapshot_load_per_record: float = 0.4e-6  # bulk snapshot deserialize,
    #                                    per record: ~10× cheaper than a
    #                                    ``cp_cross_shard_op`` replay step —
    #                                    loading a memcpy'd snapshot vs
    #                                    replaying WAL records through the
    #                                    state machine

    # -- worker node ---------------------------------------------------------
    containerd_create_median: float = 0.110  # s; "10-100s of ms" regime
    containerd_create_sigma: float = 0.30
    containerd_kernel_lock: float = 0.052  # serialized per-node kernel time:
    #                                  caps a 93-node cluster at ~1750/s (C2)
    firecracker_create_median: float = 0.040  # p50 snapshot restore (paper §5.2.3)
    firecracker_create_sigma: float = 0.25
    firecracker_kernel_lock: float = 0.010
    netcfg_pool_size: int = 64          # pre-created network configs per node
    netcfg_replenish_period: float = 0.025  # background pre-creation rate
    netcfg_pooled: float = 1.0e-3       # grab a recycled netns+iptables entry
    netcfg_fresh: float = 0.060         # Linux net-stack cost when pool empty
    netcfg_recycle: float = 0.020       # background recycle time
    health_probe_period: float = 0.010  # worker daemon -> sandbox probe
    sandbox_teardown: float = 0.030     # dismantle fs/netns/cgroups (async)
    teardown_drain_grace: float = 0.5   # let dispatched requests finish
    exec_slot_overhead: float = 0.05e-3

    # -- heartbeats / failure detection --------------------------------------
    worker_heartbeat_period: float = 0.5
    worker_heartbeat_timeout: float = 1.5
    worker_hb_cohort_quantum: float = 0.0078125  # = period/64 (2^-7, exact
    #                                    binary float): the grid beat
    #                                    deadlines snap to when the cluster
    #                                    opts into cohort heartbeats
    #                                    (``Cluster(hb_cohort_quantum=...)``,
    #                                    off by default). At 50k workers / 8
    #                                    shards a full cohort's contiguous
    #                                    lock hold is ~6250/64 × 12 µs ≈
    #                                    1.2 ms — bounded latency distortion
    #                                    — while beats collapse ~64× fewer
    #                                    heap events per period. Must divide
    #                                    worker_heartbeat_period exactly and
    #                                    stay far under
    #                                    worker_heartbeat_timeout - 2×period.
    raft_heartbeat_period: float = 0.002
    raft_election_timeout: float = 0.006   # C10: ~10 ms total CP failover
    raft_election_cost: float = 0.001
    cp_recovery_db_fetch: float = 0.002
    cp_recovery_dp_sync: float = 0.001
    systemd_restart_delay: float = 0.8     # detect+restart a crashed process
    dp_resync_cost: float = 0.2            # pull functions+endpoints from CP
    lb_reconfigure: float = 1.0            # keepalived/HAProxy reload (C11: ~2s)
    lb_health_check: float = 0.6           # keepalived failure detection

    # -- misc -----------------------------------------------------------------
    registration_persist_ops: int = 1      # one record write + DP broadcast
    worker_kill_detect: float = 0.05


@dataclass
class KnativeCosts:
    """K8s/Knative mechanism constants (baseline simulator).

    Calibration targets: ≤2 cold starts/s steady-state saturation (C1),
    ~770 ms unloaded function registration (C8), ~400 ms sandbox boot with a
    sequential sidecar + ~500 ms readiness-probe wait (Fig 1), warm-path p50
    ≈7 ms capping at ~1200/s (C5), DP recovery ≈15 s dominated by the Istio
    gateway (C11).
    """

    # -- API server / etcd -----------------------------------------------------
    apiserver_cores: int = 4            # effective parallelism before lock
    #                                     contention (10-core node, Go runtime)
    serialize_per_kb: float = 1.2e-3    # CPU to (de)serialize+validate 1 KB of
    #                                     nested-YAML object state
    object_kb: float = 17.0             # average K8s object size (paper §2.2)
    small_object_kb: float = 4.0        # endpoints/lease-ish updates
    etcd_fsync: float = 2.0e-3          # serialized WAL append+fsync
    etcd_read: float = 0.5e-3
    rpc: float = 0.5e-3                 # controller <-> API server hop
    watch_propagation: float = 5.0e-3   # informer cache lag
    # Asynchronous per-creation API-server work OFF the sequential chain but
    # ON the same CPU: Event objects, status updates, informer resyncs, istio
    # xDS pushes. This is what saturates the API server at ~2 cold starts/s
    # (C1) while unloaded chain latency stays a few hundred ms (Fig 1).
    bg_cpu_per_creation: float = 1.7
    bg_chunk: float = 0.1
    bg_spread: float = 30.0             # the async work trickles in over ~30 s

    # -- controller machinery ----------------------------------------------------
    # Sequential reconcile chain for one sandbox (Deployment -> ReplicaSet ->
    # Pod -> scheduler Binding -> kubelet status -> Endpoints -> SKS/Route),
    # each step = watch wakeup + read + RMW write of a large object.
    creation_chain_ops: int = 10
    controller_qps: float = 20.0        # kube-controller-manager --kube-api-qps
    controller_burst: int = 30
    workqueue_workers: int = 8          # concurrent reconciles per controller
    scheduler_bind: float = 0.008       # ~125 binds/s scheduler throughput
    conflict_window: float = 0.050      # optimistic-concurrency conflict if two
    #                                     RMWs to the same object overlap
    conflict_backoff: float = 0.020
    reconcile_resync: float = 10.0      # periodic resync scan period

    # -- sandbox / pod startup -----------------------------------------------------
    user_container_create: float = 0.200
    sidecar_create: float = 0.200       # queue-proxy, created sequentially
    readiness_probe_wait: float = 0.500  # both containers pass probes (Fig 1)
    kubelet_sync_period: float = 0.100

    # -- warm path -------------------------------------------------------------
    activator_cpu: float = 2.2e-3       # per-request CPU in activator path
    activator_cores: int = 3            # activator replicas: caps warm path at
    #                                     ~1200-1400 req/s (C5)
    queue_proxy_hop: float = 1.5e-3
    istio_hop: float = 2.0e-3
    pod_hop: float = 0.5e-3             # activator -> pod network hop
    lb_hop: float = 0.2e-3

    # -- registration -----------------------------------------------------------
    registration_objects: int = 10      # service, config, revision, route, SKS,
    #                                     deployment, cert, istio VirtualService...
    registration_xds_sync: float = 0.030  # ingress/xDS sync per object
    registration_growth: float = 5.6e-3  # extra CPU per pre-existing function
    #                                     (ingress/route table resync) -> "18 min
    #                                     for 500 functions" (C8)

    # -- failure recovery ----------------------------------------------------------
    pod_restart_delay: float = 2.0      # k8s notices + restarts a component pod
    component_recover_spread: float = 4.0
    istio_gateway_recover: float = 13.0  # slowest component (C11)
    worker_eviction_timeout: float = 5.0

    # -- autoscaler ------------------------------------------------------------
    autoscale_period: float = 2.0
    metrics_report_period: float = 1.0
    scale_up_decision_lag: float = 2.0  # KPA tick + activator stat lag


@dataclass
class CostModel:
    dirigent: DirigentCosts = field(default_factory=DirigentCosts)
    knative: KnativeCosts = field(default_factory=KnativeCosts)


DEFAULT_COSTS = CostModel()


def live_calibrated_candidate(start_log, invoke_walls) -> dict:
    """Turn live-mode measurements into a calibrated ``DirigentCosts``
    candidate: a {field: seconds} dict (only fields the live run actually
    observed) that the bench records next to the modeled defaults so the
    DES and live modes can be cross-checked.

    Mapping (live phase -> modeled constant):

      * warm process-mode creation -> ``firecracker_create_median`` — a
        replica built against a hot executable cache is the snapshot-restore
        analogue: pre-built state, per-instance construction only;
      * cold container-mode creation -> ``containerd_create_median`` — a
        spawned worker paying import + compile is the full container boot;
      * median invoke payload wall -> the workload's real ``exec_time``.

    ``start_log`` is ``LiveBackend.start_log``; ``invoke_walls`` a list of
    per-invoke payload wall seconds."""
    import statistics

    def _med(rows):
        return round(statistics.median(rows), 6) if rows else None

    out = {}
    warm_proc = [r["wall_s"] for r in start_log
                 if r["mode"] == "process" and not r["cold"]]
    cold_cont = [r["wall_s"] for r in start_log
                 if r["mode"] == "container" and r["cold"]]
    if warm_proc:
        out["firecracker_create_median"] = _med(warm_proc)
    if cold_cont:
        out["containerd_create_median"] = _med(cold_cont)
    if invoke_walls:
        out["exec_time_median"] = _med(list(invoke_walls))
    known = {f.name for f in fields(DirigentCosts)}
    out["fields_in_model"] = sorted(k for k in out if k in known)
    return out
