"""Dirigent core: the paper's contribution as a composable library.

Public surface:
    Cluster            — wire up a full Dirigent deployment (sim or live)
    Function           — user-facing registration record
    ScalingConfig      — per-function autoscaling knobs
    InvocationMode     — sync / async
    CostModel          — calibrated service-time constants
    KnativeCluster     — the K8s/Knative baseline (core.baseline_knative)
"""
from repro.core.abstractions import (
    DataPlaneInfo,
    Function,
    FunctionMetrics,
    Sandbox,
    SandboxState,
    ScalingConfig,
    WorkerNodeInfo,
)
from repro.core.cluster import Cluster
from repro.core.costmodel import CostModel, DEFAULT_COSTS, DirigentCosts, KnativeCosts
from repro.core.metrics import Collector, geomean, percentile
from repro.core.request import Invocation, InvocationMode

__all__ = [
    "Cluster",
    "Collector",
    "CostModel",
    "DEFAULT_COSTS",
    "DataPlaneInfo",
    "DirigentCosts",
    "Function",
    "FunctionMetrics",
    "Invocation",
    "InvocationMode",
    "KnativeCosts",
    "Sandbox",
    "SandboxState",
    "ScalingConfig",
    "WorkerNodeInfo",
    "geomean",
    "percentile",
]
