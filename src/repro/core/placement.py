"""Sandbox placement policy (kube-scheduler default semantics, paper §4).

"The placement policy favors nodes with the least utilized resources while
aiming to balance resource utilization across CPU and memory" — i.e. K8s
LeastAllocated scoring combined with the balanced-allocation tiebreak.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass
class NodeAllocation:
    cpu_capacity: int
    mem_capacity: int
    cpu_used: int = 0
    mem_used: int = 0
    schedulable: bool = True

    def fits(self, cpu: int, mem: int) -> bool:
        return (self.schedulable
                and self.cpu_used + cpu <= self.cpu_capacity
                and self.mem_used + mem <= self.mem_capacity)

    def score(self, cpu: int, mem: int) -> float:
        """Higher is better: least-allocated, balanced across CPU and mem."""
        cpu_frac = (self.cpu_used + cpu) / self.cpu_capacity
        mem_frac = (self.mem_used + mem) / self.mem_capacity
        least_allocated = 1.0 - (cpu_frac + mem_frac) / 2.0
        balance = 1.0 - abs(cpu_frac - mem_frac)
        return 0.75 * least_allocated + 0.25 * balance


class Placer:
    """Tracks per-node allocation; picks the best node for a new sandbox.

    ``policy`` selects the scoring function (core/policies.py): "balanced"
    (kube default, used by all benchmarks), "hermod_packing", "random".
    """

    def __init__(self, policy: str = "balanced"):
        from repro.core.policies import PLACEMENT_POLICIES
        self.nodes: Dict[int, NodeAllocation] = {}
        self.policy = policy
        self._score = PLACEMENT_POLICIES[policy]

    def add_node(self, worker_id: int, cpu_capacity: int, mem_capacity: int) -> None:
        self.nodes[worker_id] = NodeAllocation(cpu_capacity, mem_capacity)

    def remove_node(self, worker_id: int) -> None:
        self.nodes.pop(worker_id, None)

    def set_schedulable(self, worker_id: int, ok: bool) -> None:
        if worker_id in self.nodes:
            self.nodes[worker_id].schedulable = ok

    def place(self, cpu: int, mem: int) -> Optional[int]:
        best_id, best_score = None, float("-inf")
        for wid in sorted(self.nodes):
            node = self.nodes[wid]
            if not node.fits(cpu, mem):
                continue
            s = self._score(node, cpu, mem)
            if s > best_score:
                best_id, best_score = wid, s
        if best_id is not None:
            self.commit(best_id, cpu, mem)
        return best_id

    def commit(self, worker_id: int, cpu: int, mem: int) -> None:
        node = self.nodes[worker_id]
        node.cpu_used += cpu
        node.mem_used += mem

    def release(self, worker_id: int, cpu: int, mem: int) -> None:
        node = self.nodes.get(worker_id)
        if node is None:
            return
        node.cpu_used = max(0, node.cpu_used - cpu)
        node.mem_used = max(0, node.mem_used - mem)
