"""Sandbox placement policy (kube-scheduler default semantics, paper §4).

"The placement policy favors nodes with the least utilized resources while
aiming to balance resource utilization across CPU and memory" — i.e. K8s
LeastAllocated scoring combined with the balanced-allocation tiebreak.

The scoring semantics live in core/policies.py; this module owns the data
structures that make placement fast at paper scale (5000 workers, 2500
creations/s):

  * ``Placer`` — single scoring domain over all nodes. By default it keeps a
    lazy max-heap index per request signature so one placement costs
    O(dirty·log n) instead of re-scoring (and re-sorting) every node.
    The index reproduces the brute-force scan bit-for-bit, including the
    lowest-worker-id tie-break (property-tested in tests/test_property.py).
  * ``PartitionedPlacer`` — Archipelago-style sharded placement: nodes are
    statically partitioned (``wid % n_shards``), each shard has its own
    index, and a deterministic round-robin cursor picks the shard to try
    first. Keeps per-placement work bounded by the shard size in the
    5000-worker regime.

The sharded control plane (core/control_plane.py, ``cp_shards > 1``)
composes with ``PartitionedPlacer`` by construction: the CP builds one with
``n_shards = cp_shards`` and CP shard *k* scores ``placer.shards[k]``
directly — the exact worker partition shard *k* health-checks — so a
placement never crosses shards on the hot path. When shard *k*'s partition
is full, the CP's capacity spill (``ControlPlane._place``) probes the other
``shards[j]`` itself, least-loaded-first with backoff (work stealing) —
*not* through the parent entry point. The parent ``place()`` round-robin
entry point remains for single-domain callers
(``placement_policy="partitioned"`` with an unsharded CP).

A function *split* across a CP shard-set (``cp_fn_split_enabled``) needs no
new placer machinery: each subshard's creations call ``_place`` with that
subshard's context, scoring ``shards[k]`` — its own worker partition — so a
split function's replicas spread over the partitions of every subshard in
its set, and each subshard's spill steals independently. The placer still
sees one opaque stream of (cpu, mem) requests per shard.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass
class NodeAllocation:
    cpu_capacity: int
    mem_capacity: int
    cpu_used: int = 0
    mem_used: int = 0
    schedulable: bool = True

    def fits(self, cpu: int, mem: int) -> bool:
        return (self.schedulable
                and self.cpu_used + cpu <= self.cpu_capacity
                and self.mem_used + mem <= self.mem_capacity)

    def score(self, cpu: int, mem: int) -> float:
        """Higher is better: least-allocated, balanced across CPU and mem."""
        cpu_frac = (self.cpu_used + cpu) / self.cpu_capacity
        mem_frac = (self.mem_used + mem) / self.mem_capacity
        least_allocated = 1.0 - (cpu_frac + mem_frac) / 2.0
        balance = 1.0 - abs(cpu_frac - mem_frac)
        return 0.75 * least_allocated + 0.25 * balance


class _ScoreIndex:
    """Lazy max-heap over nodes for ONE (cpu, mem) request signature.

    Heap entries are ``(-score, wid, version)``; an entry is live iff its
    version matches the owning placer's current version for that node, so a
    node is re-scored only after its allocation actually changed (it lands in
    ``pending`` and is re-pushed on the next placement). Because every request
    served by this index has identical (cpu, mem), a live entry that does not
    fit can be dropped outright: the node re-enters via ``pending`` the next
    time its allocation changes.

    Tie-break: heapq orders equal ``-score`` entries by ascending wid — the
    same winner as the brute-force lowest-id-first scan.
    """

    __slots__ = ("cpu", "mem", "_heap", "pending")

    def __init__(self, cpu: int, mem: int):
        self.cpu = cpu
        self.mem = mem
        self._heap: List[Tuple[float, int, int]] = []
        self.pending: set = set()

    def pop_best(self, placer: "Placer") -> Optional[int]:
        nodes, versions, score = placer.nodes, placer._versions, placer._score
        if self.pending:
            # sorted: set iteration is hash-order (insertion-history
            # dependent for ints); push order is invisible to the heap's
            # (score, wid, version) total order, but a deterministic sweep
            # keeps replay byte-identical if the scoring ever gains state
            for wid in sorted(self.pending):
                node = nodes.get(wid)
                if node is None or not node.schedulable:
                    continue
                heapq.heappush(self._heap,
                               (-score(node, self.cpu, self.mem), wid,
                                versions[wid]))
            self.pending.clear()
        heap = self._heap
        while heap:
            neg_s, wid, ver = heapq.heappop(heap)
            if versions.get(wid) != ver:
                continue                      # stale: allocation changed
            node = nodes[wid]
            if not node.fits(self.cpu, self.mem):
                continue                      # dead for this signature until
            return wid                        # the node changes again
        return None


class Placer:
    """Tracks per-node allocation; picks the best node for a new sandbox.

    ``policy`` selects the scoring function (core/policies.py): "balanced"
    (kube default, used by all benchmarks), "hermod_packing", "random".
    ``use_index=False`` forces the original brute-force scan (the reference
    implementation the index is property-tested against).
    """

    def __init__(self, policy: str = "balanced",
                 use_index: Optional[bool] = None):
        from repro.core.policies import PLACEMENT_POLICIES
        self.nodes: Dict[int, NodeAllocation] = {}
        self.policy = policy
        self._score = PLACEMENT_POLICIES[policy]
        if use_index is None:
            # call-order-dependent scores (marked ``stateful`` on the policy
            # function) cannot be cached in the index
            use_index = not getattr(self._score, "stateful", False)
        self.use_index = use_index
        self._versions: Dict[int, int] = {}
        self._indexes: Dict[Tuple[int, int], _ScoreIndex] = {}

    # -- node membership ---------------------------------------------------
    def add_node(self, worker_id: int, cpu_capacity: int, mem_capacity: int) -> None:
        self.nodes[worker_id] = NodeAllocation(cpu_capacity, mem_capacity)
        self._touch(worker_id)

    def remove_node(self, worker_id: int) -> None:
        if self.nodes.pop(worker_id, None) is not None:
            # bump — never drop — the version: popping it would let heap
            # entries from this incarnation resurrect if the id re-registers
            self._versions[worker_id] += 1
        for idx in self._indexes.values():
            idx.pending.discard(worker_id)

    def set_schedulable(self, worker_id: int, ok: bool) -> None:
        if worker_id in self.nodes:
            self.nodes[worker_id].schedulable = ok
            self._touch(worker_id)

    def _touch(self, worker_id: int) -> None:
        """Invalidate cached scores after an allocation/schedulability change."""
        self._versions[worker_id] = self._versions.get(worker_id, 0) + 1
        for idx in self._indexes.values():
            idx.pending.add(worker_id)

    # -- placement ---------------------------------------------------------
    def place(self, cpu: int, mem: int) -> Optional[int]:
        if self.use_index:
            best_id = self._index_for(cpu, mem).pop_best(self)
        else:
            best_id = self._place_brute(cpu, mem)
        if best_id is not None:
            self.commit(best_id, cpu, mem)
        return best_id

    def _place_brute(self, cpu: int, mem: int) -> Optional[int]:
        best_id, best_score = None, float("-inf")
        for wid in sorted(self.nodes):
            node = self.nodes[wid]
            if not node.fits(cpu, mem):
                continue
            s = self._score(node, cpu, mem)
            if s > best_score:
                best_id, best_score = wid, s
        return best_id

    def _index_for(self, cpu: int, mem: int) -> _ScoreIndex:
        idx = self._indexes.get((cpu, mem))
        if idx is None:
            idx = _ScoreIndex(cpu, mem)
            idx.pending.update(self.nodes)
            self._indexes[(cpu, mem)] = idx
        return idx

    def commit(self, worker_id: int, cpu: int, mem: int) -> None:
        node = self.nodes[worker_id]
        node.cpu_used += cpu
        node.mem_used += mem
        self._touch(worker_id)

    def release(self, worker_id: int, cpu: int, mem: int) -> None:
        node = self.nodes.get(worker_id)
        if node is None:
            return
        node.cpu_used = max(0, node.cpu_used - cpu)
        node.mem_used = max(0, node.mem_used - mem)
        self._touch(worker_id)


class PartitionedPlacer(Placer):
    """Archipelago-style sharded placer for the multi-thousand-worker regime.

    Nodes are statically assigned to ``n_shards`` partitions (``wid %
    n_shards``), each with its own score index. A placement probes shards in
    deterministic round-robin order starting from a cursor that advances once
    per placement, falling through to the next shard when the preferred one
    has no fitting node — so per-placement work is bounded by one shard and
    load spreads evenly across partitions without any randomness.
    """

    def __init__(self, policy: str = "balanced", n_shards: int = 8,
                 use_index: Optional[bool] = None):
        if policy == "partitioned":
            policy = "balanced"      # scoring inside a shard is kube-default
        super().__init__(policy=policy, use_index=use_index)
        self.n_shards = max(1, n_shards)
        self.shards: List[Placer] = [
            Placer(policy=policy, use_index=self.use_index)
            for _ in range(self.n_shards)
        ]
        self._cursor = 0

    def _shard(self, worker_id: int) -> Placer:
        return self.shards[worker_id % self.n_shards]

    def add_node(self, worker_id: int, cpu_capacity: int, mem_capacity: int) -> None:
        shard = self._shard(worker_id)
        shard.add_node(worker_id, cpu_capacity, mem_capacity)
        # parent view shares the shard's NodeAllocation objects so existing
        # introspection (tests, recovery) keeps working unchanged
        self.nodes[worker_id] = shard.nodes[worker_id]

    def remove_node(self, worker_id: int) -> None:
        self._shard(worker_id).remove_node(worker_id)
        self.nodes.pop(worker_id, None)

    def set_schedulable(self, worker_id: int, ok: bool) -> None:
        self._shard(worker_id).set_schedulable(worker_id, ok)

    def place(self, cpu: int, mem: int) -> Optional[int]:
        start, self._cursor = self._cursor, self._cursor + 1
        for k in range(self.n_shards):
            shard = self.shards[(start + k) % self.n_shards]
            wid = shard.place(cpu, mem)
            if wid is not None:
                return wid
        return None

    def commit(self, worker_id: int, cpu: int, mem: int) -> None:
        self._shard(worker_id).commit(worker_id, cpu, mem)

    def release(self, worker_id: int, cpu: int, mem: int) -> None:
        self._shard(worker_id).release(worker_id, cpu, mem)


def make_placer(policy: str = "balanced", **kw) -> Placer:
    """Placer factory: ``policy="partitioned"`` selects the sharded placer;
    anything else is a scoring-policy name for the flat placer."""
    if policy == "partitioned":
        return PartitionedPlacer(policy="balanced", **kw)
    return Placer(policy=policy, **kw)
