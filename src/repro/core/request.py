"""Invocation records and latency bookkeeping."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Optional


class InvocationMode(enum.Enum):
    SYNC = "sync"
    ASYNC = "async"


@dataclass
class LiveRequest:
    """A real inference request riding an invocation in live mode.

    Plain data (prompt in, tokens out) so the core never imports the live
    backend: the DP threads it to ``WorkerDaemon.execute``, which hands it
    to the worker's ``live_backend`` for slot admission + shared decode
    (repro/live/backend.py). ``wall_s`` is the payload wall time billed to
    the sim clock; ``batched_with`` counts how many other requests shared
    at least one decode step in the same replica's batcher slots."""

    prompt: list = field(default_factory=list)     # token ids
    max_new_tokens: int = 16
    # -- filled by the live backend -----------------------------------------
    tokens: Optional[list] = None                  # generated ids
    wall_s: float = 0.0
    batched_with: int = 0
    failed: bool = False
    failure_reason: str = ""


@dataclass
class Invocation:
    inv_id: int
    function_name: str
    arrival: float                 # submit time (client -> front-end LB)
    exec_time: float               # modeled service time on a dedicated node
    mode: InvocationMode = InvocationMode.SYNC
    # live-mode payload: a real callable executed on the worker (examples/)
    payload: Optional[Callable[[], object]] = None
    # live-mode request: real inference dispatched into the target sandbox's
    # replica/batcher by the worker's live backend (preferred over payload —
    # a payload can't know which sandbox the DP picked; a request rides it)
    request: Optional[LiveRequest] = None

    # -- timestamps (filled as the request traverses the system) -----------
    t_dp_arrival: float = -1.0
    t_queued: float = -1.0
    t_dispatch: float = -1.0       # DP picked a sandbox & sent to worker
    t_exec_start: float = -1.0
    t_done: float = -1.0
    cold: bool = False             # waited for a sandbox creation
    failed: bool = False
    failure_reason: str = ""
    retries: int = 0
    result: object = None

    @property
    def e2e_latency(self) -> float:
        return self.t_done - self.arrival

    @property
    def scheduling_latency(self) -> float:
        """End-to-end latency minus pure execution time (paper §5.3)."""
        return self.e2e_latency - self.exec_time

    @property
    def slowdown(self) -> float:
        return self.e2e_latency / max(self.exec_time, 1e-9)
