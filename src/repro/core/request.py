"""Invocation records and latency bookkeeping."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Optional


class InvocationMode(enum.Enum):
    SYNC = "sync"
    ASYNC = "async"


@dataclass
class Invocation:
    inv_id: int
    function_name: str
    arrival: float                 # submit time (client -> front-end LB)
    exec_time: float               # modeled service time on a dedicated node
    mode: InvocationMode = InvocationMode.SYNC
    # live-mode payload: a real callable executed on the worker (examples/)
    payload: Optional[Callable[[], object]] = None

    # -- timestamps (filled as the request traverses the system) -----------
    t_dp_arrival: float = -1.0
    t_queued: float = -1.0
    t_dispatch: float = -1.0       # DP picked a sandbox & sent to worker
    t_exec_start: float = -1.0
    t_done: float = -1.0
    cold: bool = False             # waited for a sandbox creation
    failed: bool = False
    failure_reason: str = ""
    retries: int = 0
    result: object = None

    @property
    def e2e_latency(self) -> float:
        return self.t_done - self.arrival

    @property
    def scheduling_latency(self) -> float:
        """End-to-end latency minus pure execution time (paper §5.3)."""
        return self.e2e_latency - self.exec_time

    @property
    def slowdown(self) -> float:
        return self.e2e_latency / max(self.exec_time, 1e-9)
