"""Monolithic data plane (paper §3.2, §3.3).

One process-level component owning, for every function steered to it:

  * the per-function request queue (requests waiting for a sandbox — this is
    what replaces Knative's per-sandbox queue-proxy sidecars);
  * the endpoint list (ready sandboxes) with per-sandbox concurrency slots
    (throttling, default 1 request at a time);
  * least-loaded load balancing across endpoints (Knative default policy);
  * autoscaling metric reports to the control plane (periodic + an immediate
    push when a queue forms with zero capacity — a cold start).

The front-end LB steers invocations by function-ID hash, so all invocations
of a function land on one DP replica and in-flight accounting is centralized.

Endpoint updates arrive from the control plane per *CP shard* flush queue —
and, for a function split across a CP shard-set (``cp_fn_split_enabled``),
from **multiple owning subshards concurrently**: each subshard broadcasts
exactly the adds/removes for the replicas it created or tore down, exactly
once, so a function's endpoint table here is the union of its subshards'
flushes. Nothing in the DP keys on the sending shard — endpoints are keyed
by sandbox id, adds are idempotent, removes of unknown ids are no-ops — so
the DP is oblivious to splits and merges by construction (the CP's merge
handoff moves still-pending flush entries between queues rather than
re-sending them, preserving exactly-once; tests/test_fn_split.py pins it).

Mechanism → paper section map (claim ids C1..C12 as in costmodel.py):

  * ``handle`` / ``_dispatch`` — §3.3 warm path: LB hop → DP proxy CPU
    (``dp_proxy_cpu`` on ``dp_cores``) → ephemeral port from the
    ``dp_port_pool`` → worker NAT hop. Port exhaustion under sustained load
    is what caps the warm path at ~4000/s (C5, Fig 8).
  * ``_metrics_loop`` / urgent push — §3.2 autoscaling inputs: in-flight
    counts batched to the CP every ``metrics_report_period`` (250 ms), plus
    an *event-driven* push the instant a queue forms with zero free slots
    (the cold-start trigger; keeps scale-up off the periodic tick).
  * dead-endpoint report (``report_dead_sandbox``) — §3.4 stale-state
    self-healing: a dispatch to a sandbox that died behind the CP's back
    fails once, evicts the endpoint locally and reconciles via the CP —
    never an endless failure stream.
  * ``recover`` — §5.4 DP failover (C11): systemd restart → re-register →
    pull function/endpoint caches from the CP (~2 s end to end vs ~15 s for
    the Istio-gateway-bound baseline).
  * request hedging (``hedge_after``) — §4 pluggable-policy surface, off by
    default for paper fidelity (policies.py holds the LB policies).
  * connection reuse (``conn_reuse``) — a per-endpoint keep-alive pool on
    the invoke path: a port is acquired once per *connection* and reused
    across requests instead of burning a ``dp_port_hold`` TIME_WAIT per
    request. Close semantics follow TCP's asymmetry: an idle-timeout close
    is DP-initiated, so the DP's port rides TIME_WAIT before freeing; an
    endpoint-teardown close is server-initiated (the DP is the passive
    closer), so the port frees immediately. Off by default — the paper's
    one-connection-per-request path stays bit-identical.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Generator, List, Optional, TYPE_CHECKING

from repro.core.abstractions import Sandbox
from repro.core.costmodel import DirigentCosts
from repro.core.metrics import Collector
from repro.core.request import Invocation
from repro.simcore import Environment, Event

if TYPE_CHECKING:
    from repro.core.control_plane import ControlPlane
    from repro.core.cluster import Cluster


@dataclass
class Endpoint:
    sandbox: Sandbox
    capacity: int = 1           # concurrency throttle (paper: 1 req at a time)
    in_use: int = 0
    draining: bool = False

    @property
    def free(self) -> int:
        return 0 if self.draining else self.capacity - self.in_use


@dataclass
class FunctionTable:
    endpoints: Dict[int, Endpoint] = field(default_factory=dict)
    queue: Deque[Invocation] = field(default_factory=deque)
    inflight: int = 0           # executing + queued (the autoscaling signal)
    creating_hint: int = 0      # CP-echoed count (metric freshness only)
    free_slots: int = 0         # incrementally maintained sum of non-draining
    #                             endpoints' free capacity: the O(1) stand-in
    #                             for the per-queued-request any-free-slot
    #                             scan (every in_use/draining transition in
    #                             DataPlane adjusts it; the runtime sanitizer
    #                             cross-checks it against the scan)


class _Conn:
    """One keep-alive connection DP→endpoint. Pins the *pool object* its
    port was acquired from: a DP crash rebuilds the port table (fresh
    ``Resource``), and any straggler release from the old life must settle
    against the old pool, never leak into the recovered one."""

    __slots__ = ("sandbox_id", "pool", "idle_since", "closed")

    def __init__(self, sandbox_id: int, pool):
        self.sandbox_id = sandbox_id
        self.pool = pool
        self.idle_since = -1.0      # -1 while checked out
        self.closed = False


class DataPlane:
    def __init__(self, env: Environment, dp_id: int, costs: DirigentCosts,
                 cluster: "Cluster", collector: Collector,
                 concurrency: int = 1, hedge_after: Optional[float] = None,
                 lb_policy: str = "least_loaded",
                 conn_reuse: Optional[bool] = None,
                 conn_idle_timeout: Optional[float] = None):
        self.env = env
        self.dp_id = dp_id
        self.costs = costs
        self.cluster = cluster
        self.collector = collector
        self.concurrency = concurrency
        self.hedge_after = hedge_after   # straggler mitigation (None = off)
        self.hedged = 0
        self.hedge_wins = 0
        self.conn_reuse = (costs.dp_conn_reuse if conn_reuse is None
                           else conn_reuse)
        self.conn_idle_timeout = (
            costs.dp_conn_idle_timeout if conn_idle_timeout is None
            else conn_idle_timeout)
        # keep-alive pool: sandbox_id -> LIFO stack of parked _Conns (LIFO so
        # the warmest conn is reused and the cold tail idles out)
        self._idle_conns: Dict[int, List[_Conn]] = {}
        self.conn_open = 0          # live conns (checked out + parked)
        self.conn_hits = 0
        self.conn_misses = 0
        self.conn_expired = 0
        self.time_wait_ports = 0    # ports riding TIME_WAIT after DP close
        from repro.core.policies import LB_POLICIES
        self.lb_policy = lb_policy
        self._lb_pick = LB_POLICIES[lb_policy]
        # hoisted once: the backlog fast path in _drain_queue_tbl is
        # least-loaded-only, and the string compare ran per dispatch
        self._lb_fast = lb_policy == "least_loaded"
        self.alive = True
        self.tables: Dict[str, FunctionTable] = {}
        self._cpu = env.resource(capacity=costs.dp_cores,
                                 name=f"dp{dp_id}-cpu")
        self._ports = env.resource(capacity=costs.dp_port_pool,
                                   name=f"dp{dp_id}-ports")
        self._dirty: set[str] = set()   # functions with metric changes
        self._rng = env.rng(f"dp-{dp_id}")
        self._procs = []
        self._procs.append(env.process(self._metrics_loop(), name=f"dp{dp_id}-metrics"))
        # keyed by inv_id, insertion-ordered: membership/removal must not be
        # an O(n) list scan with dataclass __eq__ — at a 10k-request cold
        # burst that scan was the single hottest line of the whole simulator
        self.inflight_requests: Dict[int, Invocation] = {}

    # -- control-plane-driven state ------------------------------------------------
    def sync_functions(self, names: List[str]) -> None:
        for n in names:
            self.tables.setdefault(n, FunctionTable())

    def add_endpoint(self, fn: str, sandbox: Sandbox) -> None:
        tbl = self.tables.setdefault(fn, FunctionTable())
        ep = tbl.endpoints.get(sandbox.sandbox_id)
        if ep is None:
            ep = tbl.endpoints[sandbox.sandbox_id] = Endpoint(
                sandbox=sandbox, capacity=self.concurrency)
            tbl.free_slots += ep.capacity
        self._check_free_slots(tbl)
        self._drain_queue_tbl(tbl, hint=ep)

    def remove_endpoint(self, fn: str, sandbox_id: int, drain: bool = True) -> None:
        tbl = self.tables.get(fn)
        if not tbl:
            return
        ep = tbl.endpoints.get(sandbox_id)
        if ep is None:
            return
        if drain and ep.in_use > 0:
            if not ep.draining:
                tbl.free_slots -= ep.capacity - ep.in_use
                ep.draining = True
        else:
            tbl.endpoints.pop(sandbox_id, None)
            if not ep.draining:
                tbl.free_slots -= ep.capacity - ep.in_use
            if self.conn_reuse:
                self._close_idle_conns(sandbox_id)

    def endpoint_count(self, fn: str) -> int:
        tbl = self.tables.get(fn)
        return len(tbl.endpoints) if tbl else 0

    @property
    def ports_in_use(self) -> int:
        """Ports currently held on this DP (open conns + TIME_WAIT holds)."""
        return self._ports.in_use

    # -- request path --------------------------------------------------------------
    def handle(self, inv: Invocation) -> Generator:
        """Full life of a request inside this DP (called by the front-end LB)."""
        c = self.costs
        inv.t_dp_arrival = self.env.now
        tbl = self.tables.get(inv.function_name)
        if tbl is None:
            inv.failed = True
            inv.failure_reason = "unknown function"
            inv.t_done = self.env.now
            self.collector.done(inv)
            return

        tbl.inflight += 1
        self.inflight_requests[inv.inv_id] = inv
        try:
            # proxy CPU cost
            yield self._cpu.acquire()
            try:
                yield self.env.timeout(c.dp_proxy_cpu)
            finally:
                self._cpu.release()

            ep = self._pick_endpoint(tbl, fn=inv.function_name)
            if ep is None:
                # cold or saturated: queue, and push a metric immediately if
                # there is no capacity at all for this function.
                inv.t_queued = self.env.now
                inv.cold = self.endpoint_count(inv.function_name) == 0
                waiter = self.env.event()
                tbl.queue.append(inv)
                inv._waiter = waiter            # type: ignore[attr-defined]
                self._notify_cp_now(inv.function_name, tbl)
                ep = yield waiter               # an Endpoint when dispatched
            yield from self._proxy(inv, tbl, ep)
        finally:
            tbl.inflight = max(0, tbl.inflight - 1)
            self._dirty.add(inv.function_name)
            self.inflight_requests.pop(inv.inv_id, None)

    def _pick_endpoint(self, tbl: FunctionTable,
                       exclude: Optional[int] = None,
                       fn: str = "") -> Optional[Endpoint]:
        """Pick an endpoint per the configured LB policy (default:
        least-loaded, the Knative policy used by every benchmark)."""
        best = self._lb_pick(tbl.endpoints, fn, exclude=exclude)
        if best is not None:
            best.in_use += 1   # reserve the slot synchronously
            tbl.free_slots -= 1  # every policy picks non-draining with free>0
        return best

    def _proxy(self, inv: Invocation, tbl: FunctionTable, ep: Endpoint) -> Generator:
        c = self.costs
        inv.t_dispatch = self.env.now
        worker = self.cluster.worker_by_id(ep.sandbox.worker_id)
        conn = None
        if self.conn_reuse:
            conn = yield from self._conn_acquire(ep.sandbox.sandbox_id)
        else:
            # capture the pool at acquire time: if the DP crashes and re-arms
            # a fresh port table before this request unwinds, the TIME_WAIT
            # release must settle against the pool the port came from
            pool = self._ports
            yield pool.acquire()
        hedge_ep = None
        try:
            jit = self._rng.lognormal(1.0, c.hop_jitter_sigma)
            yield self.env.timeout(c.grpc_call * jit)   # DP -> worker hop
            inv.t_exec_start = self.env.now
            primary = self.env.process(
                worker.execute(ep.sandbox.sandbox_id, inv.exec_time,
                               inv.payload, request=inv.request),
                name=f"exec-{inv.inv_id}")
            try:
                if self.hedge_after is None:
                    inv.result = yield primary
                else:
                    # straggler mitigation: after hedge_after with no reply,
                    # duplicate the request onto another endpoint and take
                    # whichever finishes first (idempotent functions; paper
                    # §2.1 R3 request-level semantics)
                    idx, val = yield self.env.any_of(
                        [primary, self.env.timeout(self.hedge_after)])
                    if idx == 0:
                        # a failed process delivers its exception as the
                        # any_of VALUE — re-raise so failures are handled,
                        # not returned as results
                        if not primary.ok:
                            raise val
                        inv.result = val
                    else:
                        hedge_ep = self._pick_endpoint(
                            tbl, exclude=ep.sandbox.sandbox_id,
                            fn=inv.function_name)
                        if hedge_ep is None:
                            inv.result = yield primary
                        else:
                            self.hedged += 1
                            w2 = self.cluster.worker_by_id(
                                hedge_ep.sandbox.worker_id)
                            backup = self.env.process(
                                w2.execute(hedge_ep.sandbox.sandbox_id,
                                           inv.exec_time, inv.payload,
                                           request=inv.request),
                                name=f"hedge-{inv.inv_id}")
                            idx2, val2 = yield self.env.any_of(
                                [primary, backup])
                            winner, w_ep, loser, l_ep = (
                                (primary, ep, backup, hedge_ep) if idx2 == 0
                                else (backup, hedge_ep, primary, ep))
                            if winner.ok:
                                inv.result = val2
                                if idx2 == 1:
                                    self.hedge_wins += 1
                                loser.kill()
                            else:
                                # winner died (its sandbox is gone): heal it
                                # and fall back to the surviving attempt
                                self._report_dead_endpoint(
                                    inv.function_name, w_ep)
                                try:
                                    inv.result = yield loser
                                except RuntimeError as e2:
                                    inv.failed = True
                                    inv.failure_reason = str(e2)
                                    self._report_dead_endpoint(
                                        inv.function_name, l_ep)
            except RuntimeError as e:
                inv.failed = True
                inv.failure_reason = str(e)
                self._report_dead_endpoint(inv.function_name, ep)
            yield self.env.timeout(
                c.grpc_call * self._rng.lognormal(1.0, c.hop_jitter_sigma))
        finally:
            if conn is not None:
                # keep-alive: park the connection for the next request to
                # this endpoint (or close it if the endpoint is gone)
                self._conn_release(conn, tbl)
            else:
                # ephemeral port held in TIME_WAIT after the per-request
                # connection closes
                def port_hold(env, ports=pool):
                    yield env.timeout(c.dp_port_hold)
                    ports.release()
                self.env.process(port_hold(self.env), name="port-hold")
        # a DP crash already failed-and-recorded this request (client conn
        # lost); finishing the server side must not record it twice
        crashed = (inv.failed and inv.t_done >= 0
                   and inv.failure_reason == "data plane crash")
        if not crashed:
            inv.t_done = self.env.now
            self.collector.done(inv)
        if hedge_ep is not None:
            self._release_slot(tbl, hedge_ep)
        self._release_slot(tbl, ep)

    # -- keep-alive connection pool (conn_reuse) -----------------------------
    def _conn_acquire(self, sandbox_id: int) -> Generator:
        """Check out a keep-alive conn to this endpoint — a parked one if
        available (zero events), else a new one for a fresh port."""
        stack = self._idle_conns.get(sandbox_id)
        if stack:
            conn = stack.pop()
            conn.idle_since = -1.0
            self.conn_hits += 1
            return conn
        self.conn_misses += 1
        pool = self._ports
        yield pool.acquire()
        self.conn_open += 1
        return _Conn(sandbox_id, pool)

    def _conn_release(self, conn: _Conn, tbl: FunctionTable) -> None:
        if conn.closed:
            return
        if conn.pool is not self._ports or not self.alive:
            # the DP crashed since this conn's port was acquired: the
            # rebuilt pool never saw this port — settle the old one
            conn.closed = True
            conn.pool.release()
            return
        ep = tbl.endpoints.get(conn.sandbox_id)
        if ep is None or ep.draining:
            # endpoint torn down: server-initiated close, port frees now
            self._close_conn(conn, time_wait=False)
            return
        now = self.env.now
        conn.idle_since = now
        self._idle_conns.setdefault(conn.sandbox_id, []).append(conn)
        self.env.schedule_at(now + self.conn_idle_timeout,
                             lambda: self._conn_expire(conn, now))

    def _conn_expire(self, conn: _Conn, since: float) -> None:
        """Idle timer fired: close the conn iff it is still parked from the
        instant this timer was armed (a reuse in between re-arms a fresh
        timer and this one must not fire under it)."""
        if conn.closed or conn.idle_since != since:
            return
        stack = self._idle_conns.get(conn.sandbox_id)
        if stack is not None and conn in stack:
            stack.remove(conn)
            if not stack:
                self._idle_conns.pop(conn.sandbox_id, None)
        self.conn_expired += 1
        self._close_conn(conn, time_wait=True)

    def _close_conn(self, conn: _Conn, time_wait: bool) -> None:
        conn.closed = True
        pool = conn.pool
        if pool is not self._ports:
            pool.release()      # straggler from a pre-crash life
            return
        self.conn_open -= 1
        if not time_wait:
            pool.release()      # passive close: no TIME_WAIT on our side
            return
        # active close by the DP: the port rides TIME_WAIT before freeing
        self.time_wait_ports += 1

        def _free(self=self, pool=pool):
            if pool is self._ports:
                self.time_wait_ports -= 1
            pool.release()
        self.env.schedule_at(self.env.now + self.costs.dp_port_hold, _free)

    def _close_idle_conns(self, sandbox_id: int) -> None:
        """Endpoint is gone: its parked conns got the server's FIN — close
        them all, ports free immediately (we are the passive closer)."""
        stack = self._idle_conns.pop(sandbox_id, None)
        if not stack:
            return
        for conn in stack:
            self._close_conn(conn, time_wait=False)

    def _report_dead_endpoint(self, fn: str, ep: Endpoint) -> None:
        """Dispatch hit a dead sandbox: stop routing to it and tell the CP so
        cluster state (capacity, replacement scaling) reconciles — a stale
        endpoint must cost one failed request, not an endless stream."""
        if not ep.draining:
            tbl = self.tables.get(fn)
            if tbl is not None \
                    and tbl.endpoints.get(ep.sandbox.sandbox_id) is ep:
                tbl.free_slots -= ep.capacity - ep.in_use
        ep.draining = True          # skipped by the LB; reaped on last release
        if not self.alive:
            return
        cp = self.cluster.control_plane_leader()
        if cp is not None:
            self.env.process(
                cp.report_dead_sandbox(fn, ep.sandbox.sandbox_id),
                name="dead-ep-report")

    def _release_slot(self, tbl: FunctionTable, ep: Endpoint) -> None:
        ep.in_use -= 1
        if ep.draining and ep.in_use == 0:
            tbl.endpoints.pop(ep.sandbox.sandbox_id, None)
            if self.conn_reuse:
                self._close_idle_conns(ep.sandbox.sandbox_id)
        elif not ep.draining \
                and tbl.endpoints.get(ep.sandbox.sandbox_id) is ep:
            # the slot only counts if the endpoint is still routable: a
            # release on an endpoint removed undrained (dead-sandbox
            # reconcile) frees nothing the LB could pick
            tbl.free_slots += 1
        self._drain_queue_tbl(tbl, hint=ep)

    def _drain_queue(self, fn: str) -> None:
        tbl = self.tables.get(fn)
        if tbl:
            self._drain_queue_tbl(tbl)

    def _drain_queue_tbl(self, tbl: FunctionTable,
                         hint: Optional[Endpoint] = None) -> None:
        if hint is not None and tbl.queue and self._lb_fast:
            # Backlog fast path. A request only ever queues when no endpoint
            # has a free slot, and every slot freed while the queue is
            # non-empty is consumed right here — so a backlogged function has
            # *zero* free slots, and the endpoint that just freed a slot (or
            # was just added) is the only possible pick. Dispatching to it
            # directly is decision-identical to the least-loaded scan at
            # O(1) instead of O(endpoints) — the scan per dispatch dominated
            # burst-drain wall time at 3000-endpoint burst peaks.
            # the hint must still be routable: a slot released on an endpoint
            # already evicted from the table (undrained remove, DP crash)
            # frees nothing the scan would ever have picked
            if tbl.endpoints.get(hint.sandbox.sandbox_id) is hint:
                while tbl.queue and not hint.draining \
                        and hint.in_use < hint.capacity:
                    hint.in_use += 1
                    tbl.free_slots -= 1
                    inv = tbl.queue.popleft()
                    inv._waiter.succeed(hint)   # type: ignore[attr-defined]
                if tbl.queue:
                    return  # hint exhausted; no other endpoint can be free
        while tbl.queue:
            head = tbl.queue[0]
            ep = self._pick_endpoint(tbl, fn=head.function_name)
            if ep is None:
                return
            inv = tbl.queue.popleft()
            inv._waiter.succeed(ep)   # type: ignore[attr-defined]

    def _check_free_slots(self, tbl: FunctionTable) -> None:
        """Sanitize-mode tripwire: the incremental free-slot count must equal
        the scan it replaced (counter drift would silently change urgent
        metric pushes). Zero cost outside REPRO_SANITIZE=1."""
        if self.env.sanitizer is None:
            return
        scan = sum(ep.capacity - ep.in_use
                   for ep in tbl.endpoints.values() if not ep.draining)
        assert tbl.free_slots == scan, \
            f"free_slots drift: counter={tbl.free_slots} scan={scan}"

    # -- metrics -------------------------------------------------------------------
    def _notify_cp_now(self, fn: str, tbl: FunctionTable) -> None:
        """Immediate scaling hint when requests wait with zero free capacity."""
        if not self.alive:
            return
        cp = self.cluster.control_plane_leader()
        if cp is None:
            return
        self._check_free_slots(tbl)
        # O(1) any-free-slot check: this ran as an O(endpoints) scan per
        # *queued request* — at 100k-worker churn peaks the scans were the
        # largest remaining per-creation DP cost (every creation's queue
        # build-up walks the whole endpoint table of the hot function)
        if tbl.free_slots > 0:
            return
        self.env.process(
            cp.receive_metric(self.dp_id, fn, tbl.inflight, urgent=True),
            name="metric-push")

    def _metrics_loop(self) -> Generator:
        c = self.costs
        while True:
            yield self.env.timeout(c.metrics_report_period)
            if not self.alive:
                continue
            cp = self.cluster.control_plane_leader()
            if cp is None:
                continue
            # one batched report covering every active function on this DP
            report = {fn: tbl.inflight for fn, tbl in self.tables.items()
                      if tbl.inflight > 0 or fn in self._dirty}
            self._dirty.clear()
            if report:
                self.env.process(cp.receive_metric_batch(self.dp_id, report),
                                 name="metric-batch")

    # -- failure -------------------------------------------------------------------
    def fail(self) -> List[Invocation]:
        """Crash: all in-flight requests on this DP fail (client conns lost)."""
        self.alive = False
        dropped = list(self.inflight_requests.values())
        for inv in dropped:
            if inv.t_done < 0:
                inv.failed = True
                inv.failure_reason = "data plane crash"
                inv.t_done = self.env.now
                self.collector.done(inv)
        self.inflight_requests.clear()
        for tbl in self.tables.values():
            tbl.queue.clear()
            tbl.inflight = 0
            tbl.endpoints.clear()
            tbl.free_slots = 0
        # the crashed kernel forgets its whole port table: re-arm a fresh
        # pool so recovery starts from zero ports in use. In-flight requests
        # and TIME_WAIT holds from the old life captured the old pool object
        # and settle against it — they must not leak into the recovered pool
        # (regression: tests/test_data_plane.py).
        self._ports = self.env.resource(capacity=self.costs.dp_port_pool,
                                        name=f"dp{self.dp_id}-ports")
        for stack in self._idle_conns.values():
            for conn in stack:
                conn.closed = True
        self._idle_conns.clear()
        self.conn_open = 0
        self.time_wait_ports = 0
        return dropped

    def recover(self, functions: List[str],
                endpoints: Dict[str, List[Sandbox]]) -> None:
        """Re-register with CP and repopulate caches (paper §3.4.1)."""
        self.alive = True
        self.sync_functions(functions)
        for fn, sbs in endpoints.items():  # simlint: ok(dict-iteration): snapshot built in deterministic order
            for sb in sbs:
                self.add_endpoint(fn, sb)
