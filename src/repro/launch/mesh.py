"""Production mesh definitions.

Kept as FUNCTIONS so importing this module never touches jax device state
(device count is locked at first jax init — see launch/dryrun.py which must
set XLA_FLAGS before any jax import).

Topology: TPU v5e pods of 256 chips arranged (16, 16) = (data, model);
multi-pod adds a leading DCN "pod" axis: (2, 16, 16) = (pod, data, model).
Batch shards over (pod, data); tensor/expert parallelism over model.
"""
from __future__ import annotations

import jax

from repro.models.sharding import compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh(n_model: int = 1):
    """Small mesh over however many real devices exist (tests/examples)."""
    n = len(jax.devices())
    n_model = min(n_model, n)
    return compat_make_mesh((n // n_model, n_model), ("data", "model"))


def mesh_axis_names(mesh) -> tuple:
    return tuple(mesh.axis_names)
