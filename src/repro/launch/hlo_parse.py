"""Optimized-HLO collective parsing (shared by dryrun + tests).

Import-safe: no jax imports, no environment side effects.
"""
import re


def parse_collectives(hlo_text: str) -> list:
    """Extract (op_kind, output_bytes, group_size) for every collective in
    the optimized HLO. Bytes = sum of the op's result buffer sizes."""
    dtype_bytes = {
        "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
        "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
        "f8e4m3fn": 1, "f8e5m2": 1,
    }
    out = []
    op_re = re.compile(
        r"=\s+(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
        r"(-start)?\(")
    shape_re = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    group_re = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
    group_re2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
    for line in hlo_text.splitlines():
        m = op_re.search(line)
        if not m:
            continue
        result_ty, kind = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in shape_re.findall(result_ty):
            if dt not in dtype_bytes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * dtype_bytes[dt]
        gsize = None
        gm = group_re.search(line)
        if gm:
            gsize = len([x for x in gm.group(1).split(",") if x.strip()])
        else:
            gm2 = group_re2.search(line)
            if gm2:
                gsize = int(gm2.group(2))
        out.append({"kind": kind, "bytes": nbytes, "group": gsize})
    return out


