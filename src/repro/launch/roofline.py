"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Per (arch x shape) single-pod cell, derives the three roofline terms for the
TPU v5e target:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
    memory term     = HLO_bytes_per_device / HBM_bw               [s]
    collective term = ici_bytes_per_device / (links * link_bw)    [s]

FLOPs/bytes come from the probe-extrapolated cost analysis (exact for the
homogeneous layer stacks; see launch/dryrun.py). Collective bytes use ring
algorithm accounting per op kind:

    all-reduce      2 * size * (g-1)/g        (reduce-scatter + all-gather)
    all-gather      size * (g-1)/g            (size = full output)
    reduce-scatter  size * (g-1)/g
    all-to-all      size * (g-1)/g
    collective-permute  size

Also reports MODEL_FLOPS (6*N*D dense train / 6*N_active*D MoE train /
2*N*D inference) and the MODEL/HLO ratio that exposes remat + causal-masking
+ capacity-factor waste.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
        [--markdown] [--csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, Optional

# -- TPU v5e hardware constants (per task spec) -------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
ICI_LINKS = 4                # 2D torus: 4 links per chip (16x16 pod)


def collective_bytes_on_wire(summary: Dict) -> float:
    """Per-device bytes crossing ICI, ring-algorithm accounting."""
    total = 0.0
    for kind, rec in (summary or {}).items():
        size = rec.get("bytes", 0.0)
        g = rec.get("group") or 16
        if g <= 1:
            continue
        frac = (g - 1) / g
        if kind == "all-reduce":
            total += 2 * size * frac
        elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
            total += size * frac
        elif kind == "collective-permute":
            total += size
    return total


def model_flops_per_device(arch: str, shape: str, n_devices: int) -> float:
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    cfg = get_config(arch)
    sp = SHAPES[shape]
    n = cfg.n_active_params if cfg.moe else cfg.n_params
    if sp.kind == "train":
        tokens = sp.global_batch * sp.seq_len
        total = 6.0 * n * tokens
    elif sp.kind == "prefill":
        tokens = sp.global_batch * sp.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n * sp.global_batch
    return total / n_devices


def ragged_dense_overcount(arch: str, shape: str, n_devices: int) -> float:
    """CPU-backend correction for MoE archs (kimi, arctic).

    ``lax.ragged_dot`` has no grouped-GEMM lowering on the CPU backend: it
    lowers to a dense dot against EVERY local expert (E_local x the intended
    work). The TPU target lowers to a true grouped matmul (one expert per
    row). This returns the per-device FLOP excess to subtract so the compute
    term reflects the TPU target. (HBM bytes are NOT corrected: expert
    weights are read once either way; the lhs re-read excess is <1% of the
    memory term.) Verified against the probe numbers: kimi train_4k measured
    2.17e16 FLOPs/device ~= intended 1.4e15 + excess 2.03e16.
    """
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    cfg = get_config(arch)
    if not cfg.moe:
        return 0.0
    e = cfg.moe
    sp = SHAPES[shape]
    tp = 16
    n_data = n_devices // tp
    if sp.kind == "decode":
        local_tokens = max(sp.global_batch // n_data, 1)
    else:
        local_tokens = sp.global_batch * sp.seq_len // n_data
    cap = max(int(local_tokens * e.top_k / tp * 1.25), e.top_k)
    cap = min(cap, local_tokens * e.top_k)
    e_local = e.n_experts // tp
    intended = 6.0 * cap * cfg.d_model * e.d_ff_expert    # 3 mats x 2 MACs
    passes = 4.0 if sp.kind == "train" else 1.0           # fwd+remat+bwd
    return intended * (e_local - 1) * cfg.n_layers * passes


@dataclass
class RooflineRow:
    arch: str
    shape: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    hlo_flops: float = 0.0
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    step_time_s: float = 0.0
    roofline_frac: float = 0.0
    hbm_gib: float = 0.0
    fits_16g: bool = True
    note: str = ""


def analyze_cell(rec: dict) -> RooflineRow:
    arch, shape = rec["arch"], rec["shape"]
    if rec.get("status") == "skipped":
        return RooflineRow(arch=arch, shape=shape, status="skipped",
                           note=rec.get("reason", ""))
    if rec.get("status") != "ok":
        return RooflineRow(arch=arch, shape=shape, status="error",
                           note=rec.get("error", "")[:100])
    cost = rec["cost"]
    flops = cost["flops"]
    flops -= min(ragged_dense_overcount(arch, shape,
                                        rec.get("n_devices", 256)),
                 0.98 * flops)
    bytes_acc = cost["bytes_accessed"]
    coll = collective_bytes_on_wire(cost.get("collectives", {}))
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = coll / (ICI_LINKS * ICI_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_per_device(arch, shape, rec.get("n_devices", 256))
    # step time lower bound: the dominant term (perfect overlap assumption)
    step = max(terms.values())
    mem = rec.get("memory", {})
    hbm = (mem.get("argument_bytes", 0) + mem.get("output_bytes", 0)
           + mem.get("temp_bytes", 0) - mem.get("alias_bytes", 0))
    return RooflineRow(
        arch=arch, shape=shape, status="ok",
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, hlo_flops=flops, model_flops=mf,
        useful_ratio=(mf / flops if flops else 0.0),
        step_time_s=step,
        roofline_frac=(compute_s / step if step else 0.0),
        hbm_gib=hbm / 2**30, fits_16g=hbm <= 16 * 2**30,
        note="")


def load_rows(dir_: str, mesh: str = "single") -> list:
    rows = []
    for f in sorted(glob.glob(os.path.join(dir_, f"*_{mesh}.json"))):
        with open(f) as fh:
            rows.append(analyze_cell(json.load(fh)))
    return rows


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", type=str, default="results/dryrun")
    ap.add_argument("--mesh", type=str, default="single")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()

    rows = load_rows(args.dir, args.mesh)
    if args.csv:
        print("arch,shape,status,compute_s,memory_s,collective_s,bottleneck,"
              "hlo_flops,model_flops,useful_ratio,roofline_frac,hbm_gib,fits")
        for r in rows:
            print(f"{r.arch},{r.shape},{r.status},{r.compute_s:.6g},"
                  f"{r.memory_s:.6g},{r.collective_s:.6g},{r.bottleneck},"
                  f"{r.hlo_flops:.6g},{r.model_flops:.6g},"
                  f"{r.useful_ratio:.3f},{r.roofline_frac:.3f},"
                  f"{r.hbm_gib:.2f},{r.fits_16g}")
        return

    hdr = (f"{'arch':<18}{'shape':<13}{'compute':>9}{'memory':>9}"
           f"{'coll':>9}{'bound':>11}{'MODEL/HLO':>10}{'roofl%':>8}"
           f"{'HBM GiB':>9}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r.status != "ok":
            print(f"{r.arch:<18}{r.shape:<13}  [{r.status}] {r.note[:60]}")
            continue
        print(f"{r.arch:<18}{r.shape:<13}{fmt_s(r.compute_s):>9}"
              f"{fmt_s(r.memory_s):>9}{fmt_s(r.collective_s):>9}"
              f"{r.bottleneck:>11}{r.useful_ratio:>10.2f}"
              f"{r.roofline_frac * 100:>7.1f}%"
              f"{r.hbm_gib:>9.1f}{'' if r.fits_16g else '  (>16G!)'}")


if __name__ == "__main__":
    main()
