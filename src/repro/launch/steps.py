"""Builds jitted, sharded step functions per (arch x shape x mesh).

The three step kinds map to the assigned shape classes:
  * train  -> full train_step (fwd + bwd + AdamW update), params+opt donated;
  * prefill -> last-position logits from a full forward;
  * decode -> one-token serve_step against a donated KV/state cache.
"""
from __future__ import annotations

from dataclasses import replace
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, SHAPES
from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.api import RunConfig, build_model
from repro.models.sharding import filter_spec, use_mesh
from repro.train.optimizer import (adamw_init_specs, adamw_pspecs,
                                   adamw_update)
from repro.train.train_step import make_train_step


def _shard(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree, filtered to mesh axes."""
    def conv(s):
        fs = filter_spec(s)
        return NamedSharding(mesh, fs if fs is not None else s)
    with use_mesh(mesh):
        return jax.tree.map(conv, spec_tree,
                            is_leaf=lambda x: isinstance(x, P))


def default_run_config(mesh, shape: ShapeSpec, **overrides) -> RunConfig:
    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    kw = dict(data_axes=dax)
    kw.update(overrides)
    return RunConfig(**kw)


def build_step(arch: str, shape_name: str, mesh,
               run_cfg: Optional[RunConfig] = None, lr: float = 3e-4,
               cfg_override: Optional[ArchConfig] = None):
    """Returns (jitted_fn, example_args (ShapeDtypeStructs), meta)."""
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    run_cfg = run_cfg or default_run_config(mesh, shape)
    model = build_model(cfg, run_cfg)

    p_specs = model.param_specs()
    p_shard = _shard(mesh, model.param_pspecs())
    in_specs = model.input_specs(shape)
    in_shard = _shard(mesh, model.input_pspecs(shape))
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "run_cfg": run_cfg}

    if shape.kind == "train":
        opt_specs = adamw_init_specs(p_specs)
        opt_shard = _shard(mesh, adamw_pspecs(
            model.param_pspecs(), p_specs, use_zero1=run_cfg.use_zero1,
            dax=run_cfg.data_axes))
        step = make_train_step(model, lr=lr)
        rng_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
        fn = jax.jit(step,
                     in_shardings=(p_shard, opt_shard, in_shard, None),
                     out_shardings=(p_shard, opt_shard, None),
                     donate_argnums=(0, 1))
        args = (p_specs, opt_specs, in_specs, rng_spec)
        return fn, args, meta

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits = model.forward(params, batch)
            return logits[:, -1, :]      # serving prefill returns next-token logits

        fn = jax.jit(prefill_step, in_shardings=(p_shard, in_shard))
        return fn, (p_specs, in_specs), meta

    # decode
    cache_specs = model.cache_specs(shape)
    cache_shard = _shard(mesh, model.cache_pspecs(shape))

    def decode(params, cache, batch):
        return model.decode_step(params, cache, batch)

    fn = jax.jit(decode,
                 in_shardings=(p_shard, cache_shard, in_shard),
                 out_shardings=(None, cache_shard),
                 donate_argnums=(1,))
    return fn, (p_specs, cache_specs, in_specs), meta
