"""Serving launcher: a Dirigent cluster fronting real model replicas.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --requests 32 [--rate 5] [--hedge 0.5] [--slots 4]

Stands up the full orchestration stack in live mode (control plane, data
planes, workers), registers the model as a Function, drives an open-loop
request stream of prompts through the front-end LB, and reports per-request
latency + autoscaling/cold-start behaviour. This is the paper's serving path
with real JAX compute in the sandboxes.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import Cluster, Function, ScalingConfig
from repro.serving.engine import Replica
from repro.simcore import Environment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="virtual-time requests/s")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--hedge", type=float, default=None,
                    help="straggler hedge timeout (s), None = off")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(
        n_layers=4, d_model=128, n_heads=4, d_ff=256, vocab=1024)
    replicas = {}

    def create_replica(sandbox):
        rep = Replica(cfg, max_seq=128, rng_seed=args.seed)
        rep.generate([1], max_new_tokens=1)      # compile warm-up
        replicas[sandbox.sandbox_id] = rep

    env = Environment(seed=args.seed)
    cluster = Cluster(env, n_workers=args.workers, runtime="firecracker",
                      create_hook=create_replica, hedge_after=args.hedge)
    cluster.start()
    cluster.register_sync(Function(
        name=cfg.name, image_url=f"registry://{cfg.name}", port=9000,
        scaling=ScalingConfig(target_concurrency=1, stable_window=120,
                              scale_to_zero_grace=120)))
    print(f"[serve] {cfg.name} registered; {args.workers} workers")

    rng = np.random.default_rng(args.seed)
    invs = []
    t_wall = time.perf_counter()

    def driver(env):
        for i in range(args.requests):
            prompt = rng.integers(1, cfg.vocab, size=rng.integers(2, 8)).tolist()

            def payload(p=prompt, i=i):
                rep = next(iter(replicas.values()))
                return rep.generate(p, max_new_tokens=args.max_new, seed=i)

            invs.append(cluster.invoke(cfg.name, exec_time=0.05,
                                       payload=payload))
            yield env.timeout(1.0 / args.rate)

    env.process(driver(env), name="driver")
    env.run(until=args.requests / args.rate + 60.0)
    wall = time.perf_counter() - t_wall

    ok = [i for i in invs if not i.failed and i.t_done > 0]
    lats = np.array([i.e2e_latency for i in ok])
    cold = sum(1 for i in ok if i.cold)
    toks = sum(len(i.result) for i in ok if i.result)
    print(f"[serve] {len(ok)}/{len(invs)} ok; {cold} cold starts; "
          f"{cluster.collector.sandbox_creations} replicas; {toks} tokens")
    print(f"[serve] e2e virtual-time: p50 {np.percentile(lats, 50)*1e3:.0f} ms "
          f"p99 {np.percentile(lats, 99)*1e3:.0f} ms; wall {wall:.1f}s")


if __name__ == "__main__":
    main()
