import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (sharding
propagates, collectives legal, no compile-time OOM) and records the numbers
§Roofline consumes: memory_analysis, cost_analysis (FLOPs/bytes) and the
per-collective byte counts parsed from the optimized HLO.

Usage (one cell per process — keeps compiler memory bounded, enables
parallel sweeps on a real workstation):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape train_4k --mesh single [--scan] [--out results/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Single-pod cells default to *exact-cost mode* (unrolled layer stack — XLA's
cost_analysis counts scan bodies once, unrolling makes FLOP/byte/collective
totals exact). Multi-pod cells default to scan mode: they exist to prove the
pod axis shards, the roofline table is single-pod (EXPERIMENTS.md §Dry-run).
"""
import argparse
import json
import re
import time
import traceback


from repro.launch.hlo_parse import parse_collectives  # noqa: E402
from repro.models.sharding import use_mesh  # noqa: E402


def _probe_variants(cfg):
    """Probe configs for exact cost extrapolation.

    XLA's cost_analysis counts a lax.scan body once, so the full-depth scan
    compile underreports FLOPs/bytes/collective counts. All our layer stacks
    are homogeneous, so per-device cost is exactly affine in the layer count:
    cost(L) = fixed + L * per_layer. We compile tiny *unrolled* probes (with
    full-window attention and unrolled recurrence chunks, so nothing hides in
    a loop body) and solve for (fixed, per_layer). Whisper has two stacks ->
    three probes. Returns (variants, solver) where variants is a list of
    (tag, cfg) and solver maps {tag: cost} -> extrapolated cost.
    """
    import dataclasses
    if cfg.family == "audio":
        e = cfg.enc_dec
        v = [("p11", dataclasses.replace(
                 cfg, n_layers=1,
                 enc_dec=dataclasses.replace(e, n_encoder_layers=1))),
             ("p21", dataclasses.replace(
                 cfg, n_layers=1,
                 enc_dec=dataclasses.replace(e, n_encoder_layers=2))),
             ("p12", dataclasses.replace(
                 cfg, n_layers=2,
                 enc_dec=dataclasses.replace(e, n_encoder_layers=1)))]

        def solve(c):
            f_enc = c["p21"] - c["p11"]
            f_dec = c["p12"] - c["p11"]
            fixed = c["p11"] - f_enc - f_dec
            return fixed + e.n_encoder_layers * f_enc + cfg.n_layers * f_dec
        return v, solve
    if cfg.family == "hybrid":
        k = cfg.ssm.attn_every
        v = [("p1", dataclasses.replace(cfg, n_layers=k)),
             ("p2", dataclasses.replace(cfg, n_layers=2 * k))]
        n_blocks = cfg.n_layers // k
    else:
        v = [("p1", dataclasses.replace(cfg, n_layers=1)),
             ("p2", dataclasses.replace(cfg, n_layers=2))]
        n_blocks = cfg.n_layers

    def solve(c):
        body = c["p2"] - c["p1"]
        fixed = c["p1"] - body
        return fixed + n_blocks * body
    return v, solve


def _collect(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    colls = parse_collectives(compiled.as_text())
    summary = {}
    for c in colls:
        s = summary.setdefault(c["kind"], {"count": 0, "bytes": 0})
        s["count"] += 1
        s["bytes"] += c["bytes"]
        s["group"] = c["group"] or s.get("group")
    return {"flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
            "collectives": summary}


def _parse_overrides(txt: str) -> dict:
    out = {}
    for kv in filter(None, txt.split(",")):
        k, v = kv.split("=")
        if v in ("true", "True"):
            v = True
        elif v in ("false", "False"):
            v = False
        elif v in ("none", "None"):
            v = None
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             exact_costs: bool, out_dir: str,
             run_overrides: dict | None = None, tag: str = "") -> dict:
    import jax
    from repro.configs import get_config, applicable_shapes
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step, default_run_config
    from repro.configs.base import SHAPES

    cfg = get_config(arch)
    if shape_name not in applicable_shapes(cfg):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped",
               "reason": "full-attention arch: long_500k inapplicable "
                         "(DESIGN.md §Arch-applicability)"}
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir,
                               f"{arch}_{shape_name}_{mesh_kind}.json"),
                  "w") as fh:
            json.dump(rec, fh, indent=1)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    shape = SHAPES[shape_name]
    t0 = time.time()
    run_overrides = run_overrides or {}
    if "data_axes" in run_overrides and isinstance(run_overrides["data_axes"], str):
        run_overrides["data_axes"] = tuple(run_overrides["data_axes"].split("+"))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "n_devices": mesh.size, "exact_costs": exact_costs,
           "overrides": {k: str(v) for k, v in run_overrides.items()},
           "tag": tag}
    try:
        # -- full-depth compile: feasibility proof + memory analysis ------
        with use_mesh(mesh):
            base_rc = default_run_config(mesh, shape, **run_overrides)
            fn, args, meta = build_step(arch, shape_name, mesh,
                                        run_cfg=base_rc)
            lowered = fn.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        }
        rec["cost_scan_raw"] = _collect(compiled)
        del compiled, lowered

        # -- probe compiles: exact per-layer costs, affine extrapolation ---
        if exact_costs:
            variants, solve = _probe_variants(cfg)
            probe_costs = {}
            seq_full = max(shape.seq_len if shape.kind != "decode" else 1, 1)
            for ptag, pcfg in variants:
                run_cfg = default_run_config(
                    mesh, shape, **dict(run_overrides, layer_mode="unroll",
                                        q_chunk=max(seq_full, 128),
                                        kv_chunk=max(seq_full, 128),
                                        seq_chunk=512))
                with use_mesh(mesh):
                    pfn, pargs, _ = build_step(arch, shape_name, mesh,
                                               run_cfg=run_cfg,
                                               cfg_override=pcfg)
                    pcompiled = pfn.lower(*pargs).compile()
                probe_costs[ptag] = _collect(pcompiled)
                del pcompiled

            def solve_field(get):
                return solve({t: get(probe_costs[t]) for t in probe_costs})

            coll_kinds = set()
            for c in probe_costs.values():
                coll_kinds |= set(c["collectives"])
            rec["cost"] = {
                "flops": solve_field(lambda c: c["flops"]),
                "bytes_accessed": solve_field(lambda c: c["bytes_accessed"]),
                "collectives": {
                    k: {"bytes": solve_field(
                            lambda c: c["collectives"].get(k, {}).get("bytes", 0)),
                        "count": solve_field(
                            lambda c: c["collectives"].get(k, {}).get("count", 0)),
                        "group": max((c["collectives"].get(k, {}).get("group")
                                      or 0) for c in probe_costs.values())}
                    for k in coll_kinds},
                "method": "probe-extrapolated (exact for homogeneous stacks)",
            }
        else:
            rec["cost"] = dict(rec["cost_scan_raw"],
                               method="scan-raw (bodies counted once)")

        rec["status"] = "ok"
        rc = meta["run_cfg"]
        rec["run_cfg"] = {"layer_mode": rc.layer_mode,
                          "q_chunk": rc.q_chunk, "kv_chunk": rc.kv_chunk,
                          "seq_chunk": rc.seq_chunk,
                          "capacity_factor": rc.moe_capacity_factor}
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
              f"(compile {rec['compile_s']}s, "
              f"flops/dev {rec['cost']['flops']:.3e}, "
              f"temp {rec['memory']['temp_bytes']/2**30:.2f} GiB)")
        print(f"  memory_analysis: {ma}")
        print(f"  collectives: {rec['cost'].get('collectives')}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: FAIL {rec['error']}")

    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    fname = f"{arch}_{shape_name}_{mesh_kind}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as fh:
        json.dump(rec, fh, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="")
    ap.add_argument("--shape", type=str, default="")
    ap.add_argument("--mesh", type=str, default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--scan", action="store_true",
                    help="force scan layer mode (fast compile, inexact costs)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="results/dryrun")
    ap.add_argument("--override", type=str, default="",
                    help="RunConfig overrides, e.g. sharded_decode=true")
    ap.add_argument("--tag", type=str, default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import all_arch_names, applicable_shapes, get_config

    if args.all:
        cells = []
        for arch in all_arch_names():
            for shape in applicable_shapes(get_config(arch)):
                cells.append((arch, shape))
    else:
        cells = [(args.arch, args.shape)]

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for arch, shape in cells:
        for mesh_kind in meshes:
            exact = (mesh_kind == "single") and not args.scan
            fname = os.path.join(args.out, f"{arch}_{shape}_{mesh_kind}.json")
            if args.skip_existing and os.path.exists(fname):
                with open(fname) as fh:
                    if json.load(fh).get("status") in ("ok", "skipped"):
                        print(f"[dryrun] skip existing {fname}")
                        continue
            run_cell(arch, shape, mesh_kind, exact, args.out,
                     run_overrides=_parse_overrides(args.override),
                     tag=args.tag)


if __name__ == "__main__":
    main()
