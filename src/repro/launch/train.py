"""Training launcher: any assigned arch, any mesh, checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
        --steps 100 [--resume] [--zero1] [--grad-accum 4] [--compress]

Full-size configs lower onto the local mesh (use a TPU host); --reduced runs
the same code path with the smoke-test config (CPU-friendly). Checkpoints are
written via the elastic-reshard-capable store (train/checkpoint.py), so a
restart may use a different device count.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import default_run_config
from repro.models.api import build_model
from repro.models.sharding import use_mesh
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.data import ZipfLMStream
from repro.train.optimizer import adamw_init
from repro.train.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 stochastic-rounding gradient codec")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", type=str, default="results/train_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=128, n_heads=4, d_ff=384,
                          vocab=2048)
    mesh = make_local_mesh()
    with use_mesh(mesh):
        run = default_run_config(
            mesh, None, q_chunk=64, kv_chunk=64, seq_chunk=16,
            grad_accum=args.grad_accum, use_zero1=args.zero1,
            grad_compress=args.compress)
        model = build_model(cfg, run)
        params = model.init_params(jax.random.PRNGKey(args.seed))
        opt = adamw_init(params)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"[train] {cfg.name}: {n/1e6:.1f}M params on {mesh.shape} mesh")

        start = 0
        if args.resume and latest_step(args.ckpt_dir) is not None:
            (state, start) = restore_checkpoint(
                args.ckpt_dir, None, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            print(f"[train] resumed from step {start}")

        step_fn = jax.jit(make_train_step(model, lr=args.lr))
        stream = ZipfLMStream(vocab=cfg.vocab, seq=args.seq,
                              batch=args.batch, seed=args.seed + 1)
        t0 = time.time()
        for i in range(start, start + args.steps):
            params, opt, m = step_fn(params, opt, stream.batch_at(i),
                                     jax.random.PRNGKey(i))
            if (i + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, i + 1,
                                {"params": params, "opt": opt},
                                async_save=True)
            if (i + 1) % 20 == 0:
                tps = args.batch * args.seq * 20 / (time.time() - t0)
                t0 = time.time()
                print(f"[train] step {i+1:5d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.2f} {tps:,.0f} tok/s")
        save_checkpoint(args.ckpt_dir, start + args.steps,
                        {"params": params, "opt": opt})
        print(f"[train] done at step {start + args.steps}")


if __name__ == "__main__":
    main()
