"""Serving engine: the compute payload a Dirigent "sandbox" hosts.

Two layers:

  * ``Replica`` — one model instance: jitted prefill/decode, greedy or
    temperature sampling, simple per-request ``generate``. This is what the
    live-mode worker hook instantiates per sandbox (examples/serve_llm.py).
  * ``ContinuousBatcher`` — slot-based continuous batching on top of a
    Replica: a fixed (max_slots, max_seq) KV cache; new requests are admitted
    into free slots mid-flight and their prompts are consumed token-by-token
    while other slots generate (decode-only lockstep, per-slot cache
    lengths). This is the data-plane concurrency-throttling analogue: the
    sandbox advertises ``max_slots`` as its concurrency capacity to the
    Dirigent DP.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.api import RunConfig
from repro.serving.exec_cache import ExecutableCache, default_cache


def sample_token(logits: jax.Array, rng: Optional[jax.Array] = None,
                 temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits: (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, -1e30, logits)
    # categorical draws full-shape Gumbel noise: rows sample independently
    return jax.random.categorical(rng, logits).astype(jnp.int32)


class Replica:
    def __init__(self, cfg: ArchConfig, params=None, rng_seed: int = 0,
                 max_seq: int = 256, run_cfg: Optional[RunConfig] = None,
                 exec_cache: Optional[ExecutableCache] = None):
        self.cfg = cfg
        self.run_cfg = run_cfg or RunConfig(q_chunk=64, kv_chunk=64,
                                            seq_chunk=16)
        # prefill and decode executables come from the shared process-global
        # cache: the second replica of a (cfg, run_cfg) pays model-state
        # construction but zero XLA recompilation (serving/exec_cache.py)
        self.exec_cache = exec_cache if exec_cache is not None \
            else default_cache()
        entry = self.exec_cache.get(cfg, self.run_cfg)
        self.model = entry.model
        self.max_seq = max_seq
        if params is None:
            params = self.model.init_params(jax.random.PRNGKey(rng_seed))
        self.params = params
        self._decode = entry.decode
        self._prefill = entry.prefill
        self.stats = {"requests": 0, "tokens": 0, "decode_steps": 0}

    def new_cache(self, batch: int):
        shape = ShapeSpec("serve", self.max_seq, batch, "decode")
        return self.model.init_cache(shape, batch=batch)

    def generate(self, prompt_tokens: List[int], max_new_tokens: int = 16,
                 temperature: float = 0.0, seed: int = 0) -> List[int]:
        """Single-request generation (prompt consumed via decode steps)."""
        cache = self.new_cache(1)
        toks = list(prompt_tokens)
        out: List[int] = []
        rng = jax.random.PRNGKey(seed)
        logits = None
        for t, tok in enumerate(toks):
            batch = {"tokens": jnp.array([[tok]], jnp.int32),
                     "cache_len": jnp.array(t, jnp.int32)}
            logits, cache = self._decode(self.params, cache, batch)
            self.stats["decode_steps"] += 1
        pos = len(toks)
        for i in range(max_new_tokens):
            rng, sub = jax.random.split(rng)
            nxt = int(sample_token(logits, sub, temperature)[0])
            out.append(nxt)
            batch = {"tokens": jnp.array([[nxt]], jnp.int32),
                     "cache_len": jnp.array(pos, jnp.int32)}
            logits, cache = self._decode(self.params, cache, batch)
            self.stats["decode_steps"] += 1
            pos += 1
        self.stats["requests"] += 1
        self.stats["tokens"] += len(out)
        return out


@dataclass
class Slot:
    active: bool = False
    request_id: int = -1
    pending: List[int] = field(default_factory=list)   # prompt not yet fed
    generated: List[int] = field(default_factory=list)
    length: int = 0
    max_new: int = 0


class ContinuousBatcher:
    """Decode-only continuous batching with per-slot cache lengths."""

    def __init__(self, replica: Replica, max_slots: int = 8):
        self.replica = replica
        self.max_slots = max_slots
        self.slots = [Slot() for _ in range(max_slots)]
        self.cache = replica.new_cache(max_slots)
        self._next_id = 0
        self.finished: Dict[int, List[int]] = {}
        self.steps = 0

    @property
    def free_slots(self) -> int:
        return sum(1 for s in self.slots if not s.active)

    def add_request(self, prompt: List[int], max_new: int = 16) -> int:
        for slot in self.slots:
            if not slot.active:
                rid = self._next_id
                self._next_id += 1
                slot.active = True
                slot.request_id = rid
                slot.pending = list(prompt)
                slot.generated = []
                slot.length = 0
                slot.max_new = max_new
                return rid
        raise RuntimeError("no free slot (throttle at the data plane)")

    def step(self) -> List[int]:
        """One lockstep decode over all slots; returns finished request ids."""
        if all(not s.active for s in self.slots):
            return []
        tokens = np.zeros((self.max_slots, 1), np.int32)
        lens = np.zeros((self.max_slots,), np.int32)
        for i, s in enumerate(self.slots):
            lens[i] = s.length
            if not s.active:
                continue
            if s.pending:
                tokens[i, 0] = s.pending.pop(0)
            else:
                tokens[i, 0] = (s.generated[-1] if s.generated else 0)
        batch = {"tokens": jnp.asarray(tokens),
                 "cache_len": jnp.asarray(lens)}
        logits, self.cache = self.replica._decode(self.replica.params,
                                                  self.cache, batch)
        self.steps += 1
        argmax = np.asarray(jnp.argmax(logits, axis=-1))
        done: List[int] = []
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            s.length += 1
            if s.pending:
                continue               # still consuming the prompt
            s.generated.append(int(argmax[i]))
            if (len(s.generated) >= s.max_new
                    or s.length >= self.replica.max_seq - 1):
                self.finished[s.request_id] = s.generated
                done.append(s.request_id)
                s.active = False
        return done

    def abort(self) -> List[int]:
        """Kill every in-slot request without finishing it (node-failure
        semantics; graceful teardown drains via ``run_until_done`` instead,
        mirroring the DES ``teardown_drain_grace``). Aborted requests never
        appear in ``finished``; returns their request ids."""
        killed: List[int] = []
        for s in self.slots:
            if s.active:
                killed.append(s.request_id)
                s.active = False
                s.pending = []
                s.generated = []
        return killed

    def run_until_done(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            if all(not s.active for s in self.slots):
                break
            self.step()
        return self.finished
