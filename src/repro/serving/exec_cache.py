"""Process-global compiled-executable cache for live-mode replicas.

The live analogue of the paper's sandbox-churn insight: Dirigent makes
sandbox *creation* cheap by keeping the expensive state (VM snapshots,
pooled network configs) out of the per-creation critical path. For a JAX
replica the expensive state is the XLA executable — compiling the decode
step of even a truncated smollm config costs ~1-2 s on CPU while building
the model state (params + KV cache) costs ~10 ms. Without sharing, every
sandbox cold start pays the compile; with this cache a cold start pays
model-state construction only, which is what makes live creation throughput
track the orchestrator rather than the compiler (ISSUE 10 acceptance:
warm >= 10x cold).

Keying is ``(ArchConfig, RunConfig, mode)`` for the jitted callables —
both are frozen dataclasses, so they hash structurally — plus a per-entry
``shapes`` table recording which ``ShapeSpec`` signatures have been traced
(jit compiles one executable per input signature; ``warm()`` forces the
trace for a shape up front and records its compile wall time). ``mode``
keeps process-mode entries distinct from container-mode bookkeeping
entries: a subprocess worker cannot share an in-process executable, so its
"shared cache" is the on-disk persistent compilation cache
(``repro.live.container``), and its entries here only carry the per-shape
compile-time observations used for cost calibration.

The model objects handed out are safe to share between replicas: a
``Model`` holds only ``(cfg, run_cfg)`` — params and caches are passed
explicitly through every jitted call — so N replicas of one config share
one traced executable and differ only in their param/cache pytrees.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.api import RunConfig, build_model


@dataclass
class CacheEntry:
    """One (arch, run_cfg, mode) entry: shared model + jitted callables."""

    cfg: ArchConfig
    run_cfg: RunConfig
    mode: str
    model: object = None
    decode: object = None          # jit(model.decode_step)
    prefill: object = None         # jit(model.forward)
    # ShapeSpec -> compile wall seconds observed when the shape was warmed
    shapes: Dict[ShapeSpec, float] = field(default_factory=dict)

    def compiled_executables(self) -> int:
        """Distinct traced signatures across decode + prefill (jax's own
        per-jit trace count; the regression-test observable)."""
        n = 0
        for fn in (self.decode, self.prefill):
            if fn is not None and hasattr(fn, "_cache_size"):
                n += fn._cache_size()
        return n


class ExecutableCache:
    """LRU cache of jitted replica executables, shared process-wide.

    ``capacity`` bounds the number of distinct (cfg, run_cfg, mode) entries
    (None = unbounded; eviction drops the jitted wrappers, letting XLA free
    the executables). Hit/miss counters feed the
    ``dirigent_live_exec_cache_*`` metrics.
    """

    def __init__(self, capacity: Optional[int] = None):
        self.capacity = capacity
        self._entries: "OrderedDict[Tuple, CacheEntry]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, cfg: ArchConfig, run_cfg: Optional[RunConfig] = None,
            mode: str = "process") -> CacheEntry:
        """Return the shared entry for (cfg, run_cfg, mode), building the
        model + jitted wrappers on first use (the cold path a warm sandbox
        creation skips)."""
        import jax

        run_cfg = run_cfg or RunConfig()
        key = (cfg, run_cfg, mode)
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self.hits += 1
                self._entries.move_to_end(key)
                return entry
            self.misses += 1
            entry = CacheEntry(cfg=cfg, run_cfg=run_cfg, mode=mode)
            entry.model = build_model(cfg, run_cfg)
            entry.decode = jax.jit(entry.model.decode_step)
            entry.prefill = jax.jit(entry.model.forward)
            self._entries[key] = entry
            if self.capacity is not None:
                while len(self._entries) > self.capacity:
                    self._entries.popitem(last=False)
                    self.evictions += 1
            return entry

    def warm(self, cfg: ArchConfig, shape: ShapeSpec,
             run_cfg: Optional[RunConfig] = None,
             mode: str = "process", params=None) -> float:
        """Force-trace the decode executable for ``shape`` (batch =
        ``shape.global_batch``, cache length ``shape.seq_len``) and record
        its compile wall time under the entry. Returns the seconds spent
        (~0 when the signature was already traced). This is what a
        container-mode worker's boot does against the *persistent* cache;
        process mode gets it implicitly on the first decode step."""
        import jax
        import jax.numpy as jnp

        entry = self.get(cfg, run_cfg, mode)
        if shape in entry.shapes:
            return 0.0
        if params is None:
            params = entry.model.init_params(jax.random.PRNGKey(0))
        cache = entry.model.init_cache(shape, batch=shape.global_batch)
        batch = {"tokens": jnp.zeros((shape.global_batch, 1), jnp.int32),
                 "cache_len": jnp.zeros((shape.global_batch,), jnp.int32)}
        t0 = time.perf_counter()
        logits, _ = entry.decode(params, cache, batch)
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        entry.shapes[shape] = dt
        return dt

    def compiled_executables(self) -> int:
        return sum(e.compiled_executables() for e in self._entries.values())

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "compiled_executables": self.compiled_executables()}


# -- the process-global default ------------------------------------------------
_DEFAULT: Optional[ExecutableCache] = None
_DEFAULT_LOCK = threading.Lock()


def default_cache() -> ExecutableCache:
    """The process-global cache every Replica shares unless told otherwise."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = ExecutableCache()
        return _DEFAULT


def reset_default_cache() -> ExecutableCache:
    """Swap in a fresh global cache (tests measuring cold compiles)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        _DEFAULT = ExecutableCache()
        return _DEFAULT
