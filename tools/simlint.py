#!/usr/bin/env python
"""Determinism lint for the DES control plane (thin CLI wrapper).

Usage:
    python tools/simlint.py                      # lint src/repro/{core,simcore}
    python tools/simlint.py src/repro/core/x.py  # lint specific files
    python tools/simlint.py --list-rules

Rules, rationale and the ``# simlint: ok(<rule>): <why>`` suppression
syntax are documented in docs/determinism.md. The implementation lives in
src/repro/analysis/ and needs nothing beyond the standard library, so this
runs in any CI job without installing the simulator's dependencies.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.analysis import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
