#!/usr/bin/env python
"""Umbrella static-check runner: everything that gates a commit without
running the simulator.

    python tools/checks.py           # all checks (what CI's lint job runs)
    python tools/checks.py --lint    # simlint only (docs/determinism.md)
    python tools/checks.py --links   # markdown link/anchor check only

Each check prints its own report; the exit code is non-zero if *any* check
failed. Both checks are stdlib-only, so this needs no installed
dependencies — ``python tools/checks.py`` works in a bare checkout.
"""
from __future__ import annotations

import argparse
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

# markdown targets mirror CI's docs job
_MD_PATHS = ["README.md", "docs", "CHANGES.md", "ROADMAP.md", "PAPER.md"]


def run_lint() -> int:
    from repro.analysis import main as simlint_main
    print("== simlint (determinism lint, docs/determinism.md) ==")
    return simlint_main([])


def run_links() -> int:
    import check_markdown_links
    print("== markdown link + anchor check ==")
    paths = [p for p in (os.path.join(_REPO, m) for m in _MD_PATHS)
             if os.path.exists(p)]
    return check_markdown_links.main(paths)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="checks", description="run the repo's static checks")
    parser.add_argument("--lint", action="store_true",
                        help="run only simlint")
    parser.add_argument("--links", action="store_true",
                        help="run only the markdown link check")
    args = parser.parse_args(argv)
    selected = []
    if args.lint or not (args.lint or args.links):
        selected.append(run_lint)
    if args.links or not (args.lint or args.links):
        selected.append(run_links)
    rc = 0
    for check in selected:
        rc |= check()
        print()
    print("checks: OK" if rc == 0 else "checks: FAILED")
    return rc


if __name__ == "__main__":
    os.chdir(_REPO)   # simlint's default paths are repo-relative
    sys.exit(main())
