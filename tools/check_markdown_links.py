#!/usr/bin/env python
"""Offline markdown link checker (no deps, no network).

Walks the given files/directories for ``*.md``, extracts inline links and
images ``[text](target)``, and verifies that every *relative* target exists
on disk (anchors are stripped; ``http(s)``/``mailto`` targets are skipped —
CI has no network guarantee). Exits non-zero listing every broken link.

Usage:  python tools/check_markdown_links.py README.md docs CHANGES.md
"""
from __future__ import annotations

import os
import re
import sys

# inline link/image: [text](target) — target up to the first unescaped ')';
# skips reference-style and autolinks, which this repo doesn't use
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^()\s]+(?:\([^()]*\)[^()\s]*)*)\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def md_files(paths):
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                for n in sorted(names):
                    if n.endswith(".md"):
                        yield os.path.join(root, n)
        elif p.endswith(".md"):
            yield p
        else:
            print(f"warning: skipping non-markdown argument {p!r}",
                  file=sys.stderr)


def check_file(path: str) -> list:
    broken = []
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    # blank out fenced code blocks (their bracket/paren text is not a link)
    # preserving newlines so reported line numbers stay correct
    text = re.sub(r"```.*?```",
                  lambda m: "\n" * m.group(0).count("\n"), text, flags=re.S)
    base = os.path.dirname(path)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_SCHEMES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:                      # pure in-page anchor
            continue
        resolved = os.path.normpath(os.path.join(base, rel))
        if not os.path.exists(resolved):
            line = text[:m.start()].count("\n") + 1
            broken.append((path, line, target))
    return broken


def main(argv) -> int:
    paths = argv or ["README.md", "docs"]
    files = list(md_files(paths))
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 1
    broken = []
    for f in files:
        broken.extend(check_file(f))
    for path, line, target in broken:
        print(f"{path}:{line}: broken link -> {target}")
    print(f"checked {len(files)} files, {len(broken)} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
