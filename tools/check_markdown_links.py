#!/usr/bin/env python
"""Offline markdown link + anchor checker (no deps, no network).

Walks the given files/directories for ``*.md``, extracts inline links and
images ``[text](target)``, and verifies that

  * every *relative* file target exists on disk, and
  * every ``#fragment`` — in-page (``#section``) or cross-file
    (``other.md#section``) — names a real heading anchor in the target
    markdown file, using GitHub's heading→anchor slug rules (lowercase,
    punctuation stripped, spaces→hyphens, ``-1``/``-2``… suffixes for
    duplicate headings).

``http(s)``/``mailto`` targets are skipped — CI has no network guarantee.
Exits non-zero listing every broken link or dangling anchor.

Usage:  python tools/check_markdown_links.py README.md docs CHANGES.md
"""
from __future__ import annotations

import os
import re
import sys

# inline link/image: [text](target) — target up to the first unescaped ')';
# skips reference-style and autolinks, which this repo doesn't use
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^()\s]+(?:\([^()]*\)[^()\s]*)*)\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
# explicit HTML anchors (<a name="..."> / <a id="...">) also count
HTML_ANCHOR_RE = re.compile(r"<a\s+(?:name|id)=[\"']([^\"']+)[\"']")


def md_files(paths):
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                for n in sorted(names):
                    if n.endswith(".md"):
                        yield os.path.join(root, n)
        elif p.endswith(".md"):
            yield p
        else:
            print(f"warning: skipping non-markdown argument {p!r}",
                  file=sys.stderr)


def _strip_code_fences(text: str) -> str:
    """Blank out fenced code blocks (their bracket/paren/heading-looking
    text is neither a link nor a heading), preserving newlines so reported
    line numbers stay correct."""
    return re.sub(r"```.*?```",
                  lambda m: "\n" * m.group(0).count("\n"), text, flags=re.S)


def slugify(heading: str) -> str:
    """GitHub's heading→anchor slug: strip markdown inline syntax, lowercase,
    drop everything but word chars/spaces/hyphens, spaces→hyphens."""
    s = heading.strip()
    s = re.sub(r"`([^`]*)`", r"\1", s)                 # code spans
    s = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", s)     # links -> text
    s = re.sub(r"\*{1,3}([^*]+)\*{1,3}", r"\1", s)     # *emphasis*
    # _emphasis_ only at word boundaries: intra-word underscores
    # (snake_case identifiers) are literal on GitHub
    s = re.sub(r"(?<!\w)_{1,3}([^_]+)_{1,3}(?!\w)", r"\1", s)
    s = s.lower()
    s = re.sub(r"[^\w\- ]", "", s)
    s = s.replace(" ", "-")
    return s


def anchors_of(text: str) -> set:
    """All valid anchor targets in a markdown document (already fence-
    stripped): heading slugs with GitHub duplicate suffixes, plus explicit
    HTML anchors."""
    out: set = set()
    counts: dict = {}
    for line in text.splitlines():
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = slugify(m.group(2))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    out.update(HTML_ANCHOR_RE.findall(text))
    return out


class AnchorCache:
    """Per-file anchor sets, loaded lazily (a cross-file fragment check
    reads the target file once, whether or not it was on the CLI)."""

    def __init__(self):
        self._by_path: dict = {}

    def seed(self, path: str, stripped_text: str) -> None:
        """Record anchors for an already-read, fence-stripped document so a
        checked file is never re-read just to resolve its own anchors."""
        self._by_path.setdefault(os.path.normpath(path),
                                 anchors_of(stripped_text))

    def get(self, path: str) -> set:
        key = os.path.normpath(path)
        if key not in self._by_path:
            try:
                with open(key, encoding="utf-8") as fh:
                    text = _strip_code_fences(fh.read())
            except OSError:
                text = ""
            self._by_path[key] = anchors_of(text)
        return self._by_path[key]


def check_file(path: str, cache: AnchorCache = None) -> list:
    """Returns [(path, line, target, reason), ...] for every broken link."""
    cache = cache or AnchorCache()
    broken = []
    with open(path, encoding="utf-8") as fh:
        text = _strip_code_fences(fh.read())
    cache.seed(path, text)
    base = os.path.dirname(path)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_SCHEMES):
            continue
        line = text[:m.start()].count("\n") + 1
        rel, _, frag = target.partition("#")
        if rel:
            resolved = os.path.normpath(os.path.join(base, rel))
            if not os.path.exists(resolved):
                broken.append((path, line, target, "missing file"))
                continue
        else:
            resolved = path                    # pure in-page anchor
        if frag:
            if not resolved.endswith(".md"):
                continue                       # anchors into non-markdown
            if frag not in cache.get(resolved):
                broken.append((path, line, target, "dangling anchor"))
    return broken


def main(argv) -> int:
    paths = argv or ["README.md", "docs"]
    files = list(md_files(paths))
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 1
    cache = AnchorCache()
    broken = []
    for f in files:
        broken.extend(check_file(f, cache))
    for path, line, target, reason in broken:
        print(f"{path}:{line}: {reason} -> {target}")
    print(f"checked {len(files)} files, {len(broken)} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
