"""End-to-end serving driver: a real JAX LM served through Dirigent.

This is the paper's serving path with real compute in the sandboxes:
  * each Dirigent *sandbox* hosts a ``Replica`` of a (reduced) smollm-360m
    running real jitted decode steps on this machine, managed by the
    ``LiveBackend`` (``create_hook`` builds it, ``teardown_hook`` reclaims
    it when the autoscaler scales down);
  * invocations carry ``LiveRequest`` prompts; the DP dispatches each to a
    sandbox and the worker executes it *in that sandbox's* batcher slots —
    concurrent requests share decode steps — billing measured wall time to
    the virtual clock (live mode);
  * cold starts = replica construction; the XLA compile is paid once into
    the shared executable cache, so every replica after the first starts
    warm (serving/exec_cache.py);
  * finally, the ContinuousBatcher is driven directly to show slot-level
    batched decoding (the per-sandbox concurrency throttle).

Run:  PYTHONPATH=src python examples/serve_llm.py
"""
import time

import jax

from repro.configs import get_config
from repro.core import Cluster, Function, ScalingConfig
from repro.core.request import LiveRequest
from repro.live import LiveBackend, LiveFunctionSpec
from repro.serving.engine import ContinuousBatcher, Replica
from repro.simcore import Environment


def main() -> None:
    cfg = get_config("smollm-360m").reduced(
        n_layers=4, d_model=128, n_heads=4, d_ff=256, vocab=1024)
    probe = Replica(cfg, max_seq=96)
    print(f"model: smollm-360m (reduced) — "
          f"{sum(x.size for x in jax.tree.leaves(probe.params)):,} params")

    backend = LiveBackend(default_spec=LiveFunctionSpec(
        cfg=cfg, mode="process", max_seq=96, max_slots=4,
        default_max_new=8))
    env = Environment(seed=7)
    cluster = Cluster(env, n_workers=4, runtime="firecracker",
                      live_backend=backend, sandbox_concurrency=4)
    cluster.start()
    cluster.register_sync(Function(
        name="llm", image_url="registry://smollm:reduced", port=9000,
        scaling=ScalingConfig(target_concurrency=4)))

    prompts = [[1, 5, 9], [2, 6], [3, 7, 11, 13], [4, 8, 12], [1, 2, 3],
               [9, 9, 9], [5], [6, 10]]
    t_wall = time.perf_counter()
    invs = [cluster.invoke("llm", exec_time=0.05,
                           request=LiveRequest(prompt=p, max_new_tokens=8))
            for p in prompts]
    env.run(until=env.now + 30.0)
    wall = time.perf_counter() - t_wall

    starts = backend.start_log
    print(f"\nserved {sum(1 for i in invs if not i.failed)}/{len(invs)} "
          f"requests through the Dirigent data plane "
          f"({len(starts)} replicas cold-started, "
          f"{sum(1 for s in starts if not s['cold'])} of them warm via the "
          f"shared executable cache); wall {wall:.1f}s")
    for i, inv in enumerate(invs[:4]):
        req = inv.request
        print(f"  req{i}: tokens={req.tokens} "
              f"e2e(virtual)={inv.e2e_latency * 1e3:.0f} ms "
              f"cold={inv.cold} shared_slots_with={req.batched_with}")

    # -- continuous batching inside one replica ------------------------------
    rep = Replica(cfg, max_seq=96)        # warm: executables from the cache
    cb = ContinuousBatcher(rep, max_slots=4)
    rids = [cb.add_request(p, max_new=8) for p in prompts[:4]]
    t0 = time.perf_counter()
    cb.run_until_done()
    dt = time.perf_counter() - t0
    tokens = sum(len(v) for v in cb.finished.values())
    print(f"\ncontinuous batcher: {len(rids)} requests, {tokens} tokens in "
          f"{cb.steps} lockstep decode steps ({tokens / dt:.0f} tok/s wall)")
    # consistency with single-request generation:
    single = rep.generate(prompts[0], max_new_tokens=8)
    assert cb.finished[rids[0]] == single, "batched != single-request output"
    print("batched output == single-request output (exactness check passed)")


if __name__ == "__main__":
    main()
