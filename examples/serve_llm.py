"""End-to-end serving driver: a real JAX LM served through Dirigent.

This is the paper's serving path with real compute in the sandboxes:
  * each Dirigent *sandbox* hosts a ``Replica`` of a (reduced) smollm-360m
    running real jitted decode steps on this machine;
  * invocations carry prompts as payloads; the worker executes them and the
    measured wall time is billed to the virtual clock (live mode);
  * cold starts = replica instantiation; the autoscaler scales replicas with
    load, exactly as in the simulation benchmarks;
  * finally, the ContinuousBatcher is driven directly to show slot-level
    batched decoding (the per-sandbox concurrency throttle).

Run:  PYTHONPATH=src python examples/serve_llm.py
"""
import time

import jax

from repro.configs import get_config
from repro.core import Cluster, Function, ScalingConfig
from repro.serving.engine import ContinuousBatcher, Replica
from repro.simcore import Environment


def main() -> None:
    cfg = get_config("smollm-360m").reduced(
        n_layers=4, d_model=128, n_heads=4, d_ff=256, vocab=1024)
    print(f"model: smollm-360m (reduced) — "
          f"{sum(x.size for x in jax.tree.leaves(Replica(cfg, max_seq=96).params)):,} params")

    replicas = {}

    def create_replica(sandbox):
        # the live-mode "sandbox boot": instantiate + warm up the replica
        rep = Replica(cfg, max_seq=96)
        rep.generate([1, 2], max_new_tokens=1)     # trigger compilation
        replicas[sandbox.sandbox_id] = rep

    env = Environment(seed=7)
    cluster = Cluster(env, n_workers=4, runtime="firecracker",
                      create_hook=create_replica, sandbox_concurrency=1)
    cluster.start()
    cluster.register_sync(Function(
        name="llm", image_url="registry://smollm:reduced", port=9000,
        scaling=ScalingConfig(target_concurrency=1)))

    prompts = [[1, 5, 9], [2, 6], [3, 7, 11, 13], [4, 8, 12], [1, 2, 3],
               [9, 9, 9], [5], [6, 10]]
    t_wall = time.perf_counter()
    invs = []
    for i, p in enumerate(prompts):
        def payload(p=p, i=i):
            rep = next(iter(replicas.values()))
            return rep.generate(p, max_new_tokens=8, seed=i)
        invs.append(cluster.invoke("llm", exec_time=0.05, payload=payload))
        env.run(until=env.now + 0.3)
    env.run(until=env.now + 30.0)
    wall = time.perf_counter() - t_wall

    print(f"\nserved {sum(1 for i in invs if not i.failed)}/{len(invs)} "
          f"requests through the Dirigent data plane "
          f"({cluster.collector.sandbox_creations} replicas cold-started); "
          f"wall {wall:.1f}s")
    for i, inv in enumerate(invs[:4]):
        print(f"  req{i}: tokens={inv.result} "
              f"e2e(virtual)={inv.e2e_latency * 1e3:.0f} ms cold={inv.cold}")

    # -- continuous batching inside one replica ------------------------------
    rep = next(iter(replicas.values()))
    cb = ContinuousBatcher(rep, max_slots=4)
    rids = [cb.add_request(p, max_new=8) for p in prompts[:4]]
    t0 = time.perf_counter()
    cb.run_until_done()
    dt = time.perf_counter() - t0
    tokens = sum(len(v) for v in cb.finished.values())
    print(f"\ncontinuous batcher: {len(rids)} requests, {tokens} tokens in "
          f"{cb.steps} lockstep decode steps ({tokens / dt:.0f} tok/s wall)")
    # consistency with single-request generation:
    single = rep.generate(prompts[0], max_new_tokens=8)
    assert cb.finished[rids[0]] == single, "batched != single-request output"
    print("batched output == single-request output (exactness check passed)")


if __name__ == "__main__":
    main()
