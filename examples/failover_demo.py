"""Failover walkthrough: kill the CP leader, a data plane, and half the
workers mid-traffic; watch the cluster recover (paper §5.4 live).

Run:  PYTHONPATH=src python examples/failover_demo.py
"""
import numpy as np

from repro.core import Cluster, Function, ScalingConfig
from repro.simcore import Environment


def main() -> None:
    env = Environment(seed=13)
    cluster = Cluster(env, n_workers=12, runtime="firecracker",
                      enable_ha_sim=True)
    cluster.start()
    for i in range(4):
        cluster.register_sync(Function(
            name=f"svc{i}", image_url=f"registry://svc{i}", port=8080,
            scaling=ScalingConfig(stable_window=120, scale_to_zero_grace=120)))

    invs = []

    def traffic(env):
        i = 0
        while env.now < 60.0:
            invs.append(cluster.invoke(f"svc{i % 4}", exec_time=0.05))
            i += 1
            yield env.timeout(0.05)

    env.process(traffic(env), name="traffic")
    env.run(until=10.0)

    def stats(lo, hi, label):
        window = [i for i in invs if lo <= i.arrival < hi and i.t_done > 0]
        ok = [i for i in window if not i.failed]
        lat = np.percentile([i.scheduling_latency for i in ok], 99) * 1e3 \
            if ok else float("nan")
        print(f"  [{label:>22}] t={lo:4.0f}-{hi:4.0f}s  ok={len(ok):4d}  "
              f"failed={len(window) - len(ok):3d}  sched p99={lat:7.1f} ms")

    print("phase 1: steady state")
    env.run(until=15.0)
    stats(10, 15, "baseline")

    print("phase 2: control-plane leader killed at t=15 (recovery ~10 ms)")
    cluster.fail_control_plane_leader()
    env.run(until=25.0)
    elected = [t for t, k, _ in cluster.collector.events
               if k == "leader-elected" and t >= 15.0]
    print(f"  new leader elected after {(elected[0] - 15.0) * 1e3:.1f} ms; "
          f"sandbox state rebuilt from worker daemons")
    stats(15, 25, "during/after CP kill")

    print("phase 3: one data plane killed at t=25 (recovery ~2 s)")
    cluster.fail_data_plane(0)
    env.run(until=35.0)
    ev = {k: t for t, k, _ in cluster.collector.events if k.startswith("dp-")}
    print(f"  dp recovered at t={ev.get('dp-recovered', float('nan')):.2f}s")
    stats(25, 35, "during/after DP kill")

    print("phase 4: 6/12 worker daemons killed at t=35")
    for wid in range(6):
        cluster.fail_worker_daemon(wid)
    env.run(until=50.0)
    evicted = [d for t, k, d in cluster.collector.events
               if k == "worker-evicted" and t >= 35.0]
    print(f"  {len(evicted)} workers evicted via heartbeat timeout; "
          f"sandboxes rescheduled on survivors")
    stats(35, 50, "during/after worker kill")

    env.run(until=70.0)
    total_ok = sum(1 for i in invs if i.t_done > 0 and not i.failed)
    total_failed = sum(1 for i in invs if i.failed)
    print(f"\ntotal: {total_ok} served, {total_failed} failed "
          f"(in-flight on the killed DP + eviction window), "
          f"{cluster.collector.sandbox_creations} sandboxes created")


if __name__ == "__main__":
    main()
