"""End-to-end training driver with checkpoint/restart + elastic rescale.

Trains a reduced smollm-360m on the synthetic Zipf LM for a few hundred
steps, saving sharded checkpoints; then simulates a node failure by
restarting from the checkpoint on a SMALLER mesh (elastic rescale) and
verifies the loss trajectory continues.

Run:  PYTHONPATH=src python examples/train_smollm.py [--steps 200] [--full]
       (--full uses the real 360M config — sized for a TPU host, slow on CPU)
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.api import RunConfig, build_model
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.data import ZipfLMStream
from repro.train.optimizer import adamw_init
from repro.train.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--out", type=str, default="results/train_smollm")
    args = ap.parse_args()

    if args.full:
        cfg = get_config("smollm-360m")
        seq, batch = 512, 8
    else:
        cfg = get_config("smollm-360m").reduced(
            n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
            vocab=2048)
        seq, batch = 64, 16

    run = RunConfig(q_chunk=64, kv_chunk=64, grad_accum=2)
    model = build_model(cfg, run)
    params = model.init_params(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"training {cfg.name} ({n_params/1e6:.1f}M params) "
          f"seq={seq} batch={batch}")

    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(model, lr=3e-3))
    stream = ZipfLMStream(vocab=cfg.vocab, seq=seq, batch=batch, seed=11)

    t0 = time.time()
    losses = []
    for i in range(args.steps):
        params, opt, m = step_fn(params, opt, stream.batch_at(i),
                                 jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
        if (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.out, i + 1, {"params": params, "opt": opt},
                            async_save=True)
        if (i + 1) % 25 == 0:
            rate = batch * seq * 25 / (time.time() - t0)
            t0 = time.time()
            print(f"step {i+1:4d}  loss {m['loss']:.4f}  "
                  f"gnorm {m['grad_norm']:.2f}  {rate:,.0f} tok/s")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")

    # -- simulate failure + elastic restart ---------------------------------
    print("\nsimulating node failure: restoring latest checkpoint "
          "(elastic restore API; resharding happens via device_put)")
    (restored, at) = restore_checkpoint(args.out, None,
                                        {"params": params, "opt": opt})
    p2, o2 = restored["params"], restored["opt"]
    for i in range(at, at + 25):
        p2, o2, m = step_fn(p2, o2, stream.batch_at(i), jax.random.PRNGKey(i))
    print(f"resumed from step {at}; loss after 25 more steps: "
          f"{float(m['loss']):.4f} (continues the trajectory)")


if __name__ == "__main__":
    main()
