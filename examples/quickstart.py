"""Quickstart: stand up a Dirigent cluster, register and invoke functions.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import Cluster, Function, InvocationMode, ScalingConfig
from repro.simcore import Environment


def main() -> None:
    env = Environment(seed=42)
    cluster = Cluster(env, n_workers=16, runtime="firecracker",
                      enable_ha_sim=True)
    cluster.start()

    # -- register a function (persisted; ~2 ms, paper §5.2.4) ---------------
    cluster.register_sync(Function(
        name="hello", image_url="registry://hello:v1", port=8080,
        scaling=ScalingConfig(target_concurrency=1, scale_to_zero_grace=30)))
    print(f"registered 'hello' at t={env.now * 1e3:.2f} ms")

    # -- cold start: sandbox created on demand ------------------------------
    inv = cluster.invoke("hello", exec_time=0.050)
    env.run(until=2.0)
    print(f"cold  invocation: e2e={inv.e2e_latency * 1e3:6.1f} ms "
          f"(scheduling {inv.scheduling_latency * 1e3:.1f} ms, cold={inv.cold})")

    # -- warm starts ---------------------------------------------------------
    for _ in range(3):
        inv = cluster.invoke("hello", exec_time=0.050)
        env.run(until=env.now + 1.0)
        print(f"warm  invocation: e2e={inv.e2e_latency * 1e3:6.1f} ms "
              f"(scheduling {inv.scheduling_latency * 1e3:.2f} ms)")

    # -- async invocation (durable queue, at-least-once) ---------------------
    inv = cluster.invoke("hello", exec_time=0.050, mode=InvocationMode.ASYNC)
    env.run(until=env.now + 2.0)
    print(f"async invocation: done={inv.t_done > 0}, retries={inv.retries}")

    # -- kill the control-plane leader: recovery in ~10 ms (paper §5.4) ------
    t0 = env.now
    cluster.fail_control_plane_leader()
    env.run(until=t0 + 1.0)
    elected = [t for t, k, _ in cluster.collector.events
               if k == "leader-elected" and t >= t0]
    print(f"CP failover: new leader after {(elected[0] - t0) * 1e3:.1f} ms")

    inv = cluster.invoke("hello", exec_time=0.050)
    env.run(until=env.now + 2.0)
    print(f"post-failover invocation ok: {not inv.failed} "
          f"(warm={not inv.cold} — sandbox state was rebuilt from workers)")

    s = cluster.collector.summary()
    print(f"\ntotals: {s['n_completed']} ok, {s['n_failed']} failed, "
          f"{cluster.collector.sandbox_creations} sandboxes created, "
          f"{cluster.store.write_count} persistent writes")


if __name__ == "__main__":
    main()
