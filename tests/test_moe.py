"""MoE layer tests: EP shard-count invariance, capacity dropping, FSDP specs."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ArchConfig, MoEConfig
from repro.models.api import RunConfig, build_model
from repro.models.moe import _local_moe, moe_param_pspecs


def _mini_moe_cfg(n_experts=8, top_k=2, cf=8.0):
    base = get_config("kimi-k2-1t-a32b").reduced()
    import dataclasses
    return dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, n_experts=n_experts,
                                      top_k=top_k, capacity_factor=cf))


def test_local_moe_matches_dense_reference():
    """With generous capacity, sorted-EP output == the dense per-expert sum."""
    cfg = _mini_moe_cfg()
    run = RunConfig(moe_capacity_factor=8.0)
    T, D = 16, cfg.d_model
    Fe = cfg.moe.d_ff_expert
    E = cfg.moe.n_experts
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    w = {
        "router": jax.random.normal(ks[0], (D, E)) * 0.5,
        "e_gate": jax.random.normal(ks[1], (E, D, Fe)) * 0.1,
        "e_up": jax.random.normal(ks[2], (E, D, Fe)) * 0.1,
        "e_down": jax.random.normal(ks[3], (E, Fe, D)) * 0.1,
    }
    x = jax.random.normal(ks[4], (T, D))
    y = _local_moe(cfg, run, w, x, n_shards=1, shard_id=0)

    # dense reference
    logits = x @ w["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    y_ref = jnp.zeros((T, D))
    for t in range(T):
        for j in range(cfg.moe.top_k):
            e = int(top_e[t, j])
            h = jax.nn.silu(x[t] @ w["e_gate"][e]) * (x[t] @ w["e_up"][e])
            y_ref = y_ref.at[t].add(float(top_p[t, j]) * (h @ w["e_down"][e]))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-3,
                               rtol=1e-3)


def test_capacity_drops_overflow():
    """With capacity ~0, outputs go to ~zero (dropped tokens), no NaNs."""
    cfg = _mini_moe_cfg(cf=8.0)
    run_full = RunConfig(moe_capacity_factor=8.0)
    run_tight = RunConfig(moe_capacity_factor=0.01)
    T, D = 32, cfg.d_model
    E, Fe = cfg.moe.n_experts, cfg.moe.d_ff_expert
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    w = {
        "router": jax.random.normal(ks[0], (D, E)) * 0.5,
        "e_gate": jax.random.normal(ks[1], (E, D, Fe)) * 0.1,
        "e_up": jax.random.normal(ks[2], (E, D, Fe)) * 0.1,
        "e_down": jax.random.normal(ks[3], (E, Fe, D)) * 0.1,
    }
    x = jax.random.normal(ks[4], (T, D))
    y_full = _local_moe(cfg, run_full, w, x, n_shards=1, shard_id=0)
    y_tight = _local_moe(cfg, run_tight, w, x, n_shards=1, shard_id=0)
    assert not bool(jnp.isnan(y_tight).any())
    # tight capacity serves at most a couple of assignments
    served_tight = int(jnp.sum(jnp.any(jnp.abs(y_tight) > 0, axis=-1)))
    served_full = int(jnp.sum(jnp.any(jnp.abs(y_full) > 0, axis=-1)))
    assert served_tight < served_full


def test_fsdp_pspecs_no_duplicate_axes():
    from jax.sharding import PartitionSpec as P
    cfg = get_config("kimi-k2-1t-a32b")
    specs = moe_param_pspecs(cfg, "model", fsdp_axes=("pod", "data"))
    for name, sp in specs.items():
        used = []
        for entry in sp:
            if entry is None:
                continue
            used += list(entry) if isinstance(entry, tuple) else [entry]
        assert len(used) == len(set(used)), f"duplicate axes in {name}: {sp}"


def test_ep_shard_invariance_subprocess():
    """MoE output must be identical at 1 vs 4 EP shards (fake devices)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        import dataclasses
        from repro.configs import get_config
        from repro.models.api import build_model, RunConfig
        base = get_config("kimi-k2-1t-a32b").reduced()
        cfg = dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, n_experts=8, top_k=2))
        run = RunConfig(q_chunk=16, kv_chunk=16, data_axes=("data",),
                        moe_capacity_factor=8.0)
        model = build_model(cfg, run)
        params = model.init_params(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.arange(64, dtype=jnp.int32).reshape(2, 32) % 100}
        y1 = model.forward(params, batch)          # no mesh: local path
        from repro.models.sharding import compat_make_mesh, use_mesh
        mesh = compat_make_mesh((1, 4), ("data", "model"))
        with use_mesh(mesh):
            y4 = jax.jit(model.forward)(params, batch)
        err = float(jnp.abs(y1 - y4).max())
        assert err < 2e-2, f"EP shard mismatch: {err}"
        print("EP-invariance OK", err)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                        "HOME": "/root"}, cwd="/root/repo",
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "EP-invariance OK" in r.stdout


def test_sharded_decode_subprocess():
    """Distributed flash-decode == plain decode on an 8-device mesh."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import ShapeSpec
        from repro.models.api import build_model, RunConfig
        cfg = get_config("qwen3-32b").reduced(n_layers=2, d_model=64,
                                              n_heads=8, n_kv_heads=2,
                                              d_ff=128, vocab=256)
        m0 = build_model(cfg, RunConfig(q_chunk=16, kv_chunk=16,
                                        data_axes=("data",)))
        params = m0.init_params(jax.random.PRNGKey(0))
        B = 4
        cache = m0.init_cache(ShapeSpec("t", 32, B, "decode"))
        batch = {"tokens": jnp.ones((B, 1), jnp.int32),
                 "cache_len": jnp.array(3, jnp.int32)}
        l_ref, _ = jax.jit(m0.decode_step)(params, cache, batch)
        from repro.models.sharding import compat_make_mesh, use_mesh
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            m1 = build_model(cfg, RunConfig(q_chunk=16, kv_chunk=16,
                                            data_axes=("data",),
                                            sharded_decode=True))
            l1, _ = jax.jit(m1.decode_step)(params, cache, batch)
        err = float(jnp.abs(l1 - l_ref).max())
        assert err < 1e-4, err
        print("sharded-decode OK", err)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                        "HOME": "/root"}, cwd="/root/repo",
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "sharded-decode OK" in r.stdout
