"""Per-architecture smoke tests (deliverable f).

Each assigned arch is instantiated at a REDUCED same-family config and runs
one forward + one train step + one decode step on CPU, asserting output
shapes and no NaNs. The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config
from repro.configs.base import ShapeSpec
from repro.models.api import RunConfig, build_model
from repro.train.optimizer import adamw_init
from repro.train.train_step import make_train_step

ARCHS = all_arch_names()
RUN = RunConfig(q_chunk=16, kv_chunk=16, seq_chunk=16, layer_mode="scan")


def _batch(cfg, B=2, S=32):
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.full((B, cfg.enc_dec.encoder_seq, cfg.d_model),
                                   0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, RUN)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    step = jax.jit(make_train_step(model, lr=1e-3))
    opt = adamw_init(params)
    params2, opt2, m = step(params, opt, batch, jax.random.PRNGKey(1))
    assert not bool(jnp.isnan(m["loss"]))
    assert float(m["grad_norm"]) > 0
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, RUN)
    params = model.init_params(jax.random.PRNGKey(0))
    B = 2
    shape = ShapeSpec("t", 64, B, "decode")
    cache = model.init_cache(shape)
    if cfg.family == "audio":
        cache = model.prefill_cross(
            params, _batch(cfg, B=B)["frames"], cache)
    batch = {"tokens": jnp.ones((B, 1), jnp.int32),
             "cache_len": jnp.array(3, jnp.int32)}
    logits, cache2 = jax.jit(model.decode_step)(params, cache, batch)
    assert logits.shape == (B, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ["qwen3-32b", "rwkv6-7b", "zamba2-2.7b"])
def test_prefill_decode_consistency(arch):
    """Token-by-token decode must reproduce the parallel forward's last
    logits — validates KV caches / recurrent state carries exactly."""
    cfg = get_config(arch).reduced()
    run = RunConfig(q_chunk=8, kv_chunk=8, seq_chunk=8, layer_mode="scan")
    model = build_model(cfg, run)
    params = model.init_params(jax.random.PRNGKey(1))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    ref_logits = model.forward(params, {"tokens": toks})[:, -1]
    cache = model.init_cache(ShapeSpec("t", 32, B, "decode"))
    lg = None
    for t in range(S):
        lg, cache = model.decode_step(
            params, cache, {"tokens": toks[:, t:t + 1],
                            "cache_len": jnp.array(t, jnp.int32)})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref_logits),
                               atol=2e-4, rtol=2e-4)


def test_param_counts_match_assignment():
    """Config-table param counts are in-family (catches config typos)."""
    expect = {
        "qwen3-32b": (28, 38), "granite-34b": (30, 38),
        "smollm-360m": (0.3, 0.5), "glm4-9b": (8, 11),
        "kimi-k2-1t-a32b": (950, 1150), "arctic-480b": (430, 520),
        "rwkv6-7b": (5.5, 8), "zamba2-2.7b": (2.2, 3.5),
        "whisper-small": (0.1, 0.35), "qwen2-vl-72b": (65, 80),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.1f}B outside [{lo}, {hi}]"


def test_moe_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    assert 25 <= kimi.n_active_params / 1e9 <= 40     # "a32b"
