"""Kernel validation: Pallas (interpret mode) + chunked jnp vs ref oracles.

Per the deliverable: each Pallas kernel is swept over shapes/dtypes and
asserted allclose against the pure-jnp oracle in ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.chunked import ssd_chunked, wkv6_chunked
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rwkv6_chunk import wkv6_pallas
from repro.kernels.ssd_chunk import ssd_pallas
from repro.models.layers import repeat_kv


def _qkv(B, S, Hq, Hkv, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D)).astype(dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,S,Hq,Hkv,D,bq,bkv", [
    (1, 128, 2, 2, 64, 64, 64),      # MHA
    (2, 256, 4, 2, 64, 128, 64),     # GQA
    (1, 128, 4, 1, 128, 32, 128),    # MQA, wide head
    (2, 192, 3, 3, 32, 64, 96),      # non-pow2 blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_pallas(B, S, Hq, Hkv, D, bq, bkv, dtype, causal):
    q, k, v = _qkv(B, S, Hq, Hkv, D, dtype)
    g = Hq // Hkv
    want = ref.flash_attention_ref(q.astype(jnp.float32),
                                   repeat_kv(k, g).astype(jnp.float32),
                                   repeat_kv(v, g).astype(jnp.float32),
                                   causal=causal)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                 block_kv=bkv, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("B,Smax,Hq,Hkv,D,bkv,clen", [
    (1, 256, 2, 2, 64, 64, 256),
    (2, 256, 4, 2, 64, 128, 130),
    (2, 512, 8, 1, 128, 256, 7),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_pallas(B, Smax, Hq, Hkv, D, bkv, clen, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D)).astype(dtype)
    kc = jax.random.normal(ks[1], (B, Smax, Hkv, D)).astype(dtype)
    vc = jax.random.normal(ks[2], (B, Smax, Hkv, D)).astype(dtype)
    cl = jnp.array(clen, jnp.int32)
    want = ref.decode_attention_ref(q.astype(jnp.float32),
                                    kc.astype(jnp.float32),
                                    vc.astype(jnp.float32), cl)
    got = decode_attention_pallas(q, kc, vc, cl, block_kv=bkv, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def _wkv_inputs(B, S, H, dk, dv, dtype, seed=2):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = (jax.random.normal(ks[0], (B, S, H, dk)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, S, H, dk)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, S, H, dv)) * 0.5).astype(dtype)
    w = jnp.clip(jnp.exp(-jnp.exp(
        jax.random.normal(ks[3], (B, S, H, dk)) * 0.5 - 1.5)),
        0.62, 0.9999).astype(jnp.float32)
    u = (jax.random.normal(ks[4], (H, dk)) * 0.3).astype(jnp.float32)
    s0 = (jax.random.normal(ks[5], (B, H, dk, dv)) * 0.1).astype(jnp.float32)
    return r, k, v, w, u, s0


@pytest.mark.parametrize("B,S,H,dk,dv,chunk", [
    (1, 64, 2, 16, 16, 16),
    (2, 128, 3, 16, 24, 32),
    (1, 256, 2, 32, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_pallas_and_chunked(B, S, H, dk, dv, chunk, dtype):
    r, k, v, w, u, s0 = _wkv_inputs(B, S, H, dk, dv, dtype)
    y_ref, s_ref = ref.wkv6_ref(r, k, v, w, u, s0)
    y_c, s_c = wkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
    y_p, s_p = wkv6_pallas(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref),
                               atol=tol * 5, rtol=tol * 5)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_ref),
                               atol=tol * 5, rtol=tol * 5)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_ref),
                               atol=tol * 5, rtol=tol * 5)


def _ssd_inputs(b, S, H, Pd, N, dtype, seed=3):
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    x = (jax.random.normal(ks[0], (b, S, H, Pd)) * 0.5).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)) * 0.5) * 0.5
          ).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = (jax.random.normal(ks[3], (b, S, H, N)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[4], (b, S, H, N)) * 0.5).astype(dtype)
    D = jax.random.normal(ks[5], (H,)) * 0.3
    h0 = (jax.random.normal(ks[6], (b, H, Pd, N)) * 0.1).astype(jnp.float32)
    return x, dt, A, B, C, D, h0


@pytest.mark.parametrize("b,S,H,Pd,N,chunk", [
    (1, 64, 2, 16, 16, 16),
    (2, 128, 3, 32, 16, 32),
    (1, 256, 2, 64, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_pallas_and_chunked(b, S, H, Pd, N, chunk, dtype):
    x, dt, A, B, C, D, h0 = _ssd_inputs(b, S, H, Pd, N, dtype)
    y_ref, h_ref = ref.ssd_ref(x, dt, A, B, C, D, h0)
    y_c, h_c = ssd_chunked(x, dt, A, B, C, D, h0, chunk=chunk)
    y_p, h_p = ssd_pallas(x, dt, A, B, C, D, h0, chunk=chunk, interpret=True)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref),
                               atol=tol * 5, rtol=tol * 5)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_ref),
                               atol=tol * 5, rtol=tol * 5)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_ref),
                               atol=tol * 5, rtol=tol * 5)


def test_ops_dispatch():
    q, k, v = _qkv(1, 64, 2, 2, 32, jnp.float32)
    a = ops.flash_attention(q, k, v, impl="jnp", q_chunk=32, kv_chunk=32)
    b = ops.flash_attention(q, k, v, impl="pallas_interpret")
    c = ops.flash_attention(q, k, v, impl="reference")
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5,
                               rtol=2e-5)
    np.testing.assert_allclose(np.asarray(b), np.asarray(c), atol=2e-5,
                               rtol=2e-5)
