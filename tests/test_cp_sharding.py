"""Sharded control plane (core/control_plane.py, ``cp_shards``).

Claims pinned here:

1. ``cp_shards=1`` (the default) is *bit-identical* to the pre-shard control
   plane. The ``GOLD7``/``GOLD8`` constants below were recorded by running
   the exact workloads in this file against a reference tree built from the
   pre-shard control plane (commit 16aeff4's core modules) plus PR 2's
   orthogonal worker-heartbeat boot fix in cluster.py: same latency
   percentiles to the last float bit, same creation/teardown counts, and —
   the strongest pin — the same total number of simulator events, i.e. the
   identical event sequence. (Relative to pure 16aeff4, only the event
   totals differ, by the few boot-window heartbeat events the fix adds;
   every latency statistic is bit-identical to pure 16aeff4 too.)

2. With rebalancing off (the default), the function→shard *indirection
   table* is pure routing plumbing: ``GOLD7_S4``/``GOLD8_S4`` pin
   ``cp_shards=4`` bit-identically against goldens recorded from PR 2's
   static ``stable_hash % N`` control plane, so the table (and the
   work-stealing spill order, unused when capacity never forces a spill)
   changes nothing until the rebalancer actually moves a function.

3. ``cp_shards>1`` partitions functions and workers across shards with
   per-shard scale locks and health monitors, keeps placement shard-local
   until capacity forces a spill, survives concurrent multi-worker failure
   in different shards, and rebuilds every shard on leader failover.

4. Load-adaptive sharding (``cp_rebalance_enabled``): a hot shard sheds its
   hottest functions to the coldest shard through the quiesce→move→publish
   handoff (pending endpoint-flush entries travel with the function), the
   persisted ``shardmap/`` overrides survive leader failover, a deposed
   leader's in-flight handoff aborts without touching shared state, and the
   capacity spill steals from the least-loaded victim with backoff.
"""
import numpy as np
import pytest

from repro.core import Cluster, Function, Sandbox, ScalingConfig
from repro.simcore import Environment, stable_hash

COLD_SCALING = dict(stable_window=1.0, panic_window=1.0,
                    scale_to_zero_grace=0.2, cpu_req_millis=100,
                    mem_req_mb=128)

# Recorded from the pre-shard ControlPlane (see module docstring) with the
# PR 2 worker-heartbeat boot fix applied to cluster.py — the fix starts each
# worker's heartbeat at registration, which adds a few boot-window events but
# leaves every latency statistic bit-identical at this scale. The ``events``
# fields were re-recorded for PR 4's demand-driven timers / heartbeat wheel /
# lazy lock holds (158654→20896, 99302→10160): event totals legitimately
# shrank ~8-10x while every latency statistic stayed bit-identical to the
# pre-PR 4 values — which is exactly the claim these pins enforce. Any change
# to these workloads invalidates the constants — re-record, don't tweak.
GOLD7 = {"done": 240, "total": 240, "creations": 240, "teardowns": 240,
         "p50": 0.14846846481036485, "p99": 0.17291408266620184,
         "lat_sum": 35.9401392552082, "events": 20896}
GOLD8 = {"done": 400, "total": 400, "creations": 8,
         "p50": 0.0015260204436948754, "p99": 0.002034961221146396,
         "lat_sum": 0.6199089000305911, "events": 10160}

# Recorded from PR 2's static-hash sharded CP at cp_shards=4 (same tree as
# above plus the PR 2 sharding layer): pins that the indirection table +
# work-stealing spill order are no-ops while rebalancing is off and capacity
# never forces a spill. ``events`` re-recorded for PR 4 (see above).
# Re-record, don't tweak.
GOLD7_S4 = {"done": 240, "total": 240, "creations": 240, "teardowns": 240,
            "p50": 0.14856441964943767, "p99": 0.17284698168466597,
            "lat_sum": 35.95150878463096, "events": 21182}
GOLD8_S4 = {"done": 400, "total": 400, "creations": 8,
            "p50": 0.0015260204436948754, "p99": 0.002034961221146396,
            "lat_sum": 0.6199089000305911, "events": 10327}


def _preload(cl, names, scaling_kw):
    leader = cl.control_plane_leader()
    for name in names:
        fn = Function(name=name, image_url="img://bench", port=80,
                      scaling=ScalingConfig(**scaling_kw))
        leader.install_function(fn)
        for dp in cl.data_planes:
            dp.sync_functions([name])


def fig7_cold_stats(**cluster_kw):
    """Fig 7 workload shape: every invocation is a cold start."""
    env = Environment(seed=11)
    cl = Cluster(env, n_workers=93, runtime="firecracker", **cluster_kw)
    cl.start()
    n, rate = 240, 300.0
    _preload(cl, [f"f{i}" for i in range(n)], COLD_SCALING)
    invs = []

    def driver(env):
        for i in range(n):
            invs.append(cl.invoke(f"f{i}", exec_time=0.1))
            yield env.timeout(1.0 / rate)

    env.process(driver(env), name="driver")
    env.run(until=n / rate + 30.0)
    lats = np.array([i.e2e_latency for i in invs
                     if i.t_done > 0 and not i.failed])
    return {
        "done": int(lats.size), "total": len(invs),
        "creations": cl.collector.sandbox_creations,
        "teardowns": cl.collector.sandbox_teardowns,
        "p50": float(np.percentile(lats, 50)),
        "p99": float(np.percentile(lats, 99)),
        "lat_sum": float(lats.sum()),
        "events": env.events_processed,
    }


def fig8_warm_stats(**cluster_kw):
    """Fig 8 workload shape: scale up once, then a warm-only open loop."""
    env = Environment(seed=21)
    cl = Cluster(env, n_workers=93, runtime="firecracker", **cluster_kw)
    cl.start()
    cl.register_sync(Function(
        name="w", image_url="img://bench", port=80,
        scaling=ScalingConfig(target_concurrency=1, stable_window=300,
                              scale_to_zero_grace=300)))
    warmup = [cl.invoke("w", exec_time=2.0) for _ in range(8)]
    env.run(until=10.0)
    invs = []

    def driver(env):
        for _ in range(400):
            invs.append(cl.invoke("w", exec_time=0.3e-3))
            yield env.timeout(1.0 / 200.0)

    env.process(driver(env), name="driver")
    env.run(until=20.0)
    assert all(not i.failed for i in warmup)
    lats = np.array([i.e2e_latency for i in invs
                     if i.t_done > 0 and not i.failed])
    return {
        "done": int(lats.size), "total": len(invs),
        "creations": cl.collector.sandbox_creations,
        "p50": float(np.percentile(lats, 50)),
        "p99": float(np.percentile(lats, 99)),
        "lat_sum": float(lats.sum()),
        "events": env.events_processed,
    }


# -- equivalence: cp_shards=1 == pre-shard CP ---------------------------------

@pytest.mark.parametrize("kw", [{}, {"cp_shards": 1}],
                         ids=["default", "explicit-1"])
def test_fig7_cold_bit_identical_to_preshard_cp(kw):
    assert fig7_cold_stats(**kw) == GOLD7


@pytest.mark.parametrize("kw", [{}, {"cp_shards": 1}],
                         ids=["default", "explicit-1"])
def test_fig8_warm_bit_identical_to_preshard_cp(kw):
    assert fig8_warm_stats(**kw) == GOLD8


def test_fig7_cold_s4_indirection_table_bit_identical_to_static_hash():
    """cp_shards=4 with rebalancing off (default) routes through the
    indirection table yet is bit-identical to PR 2's bare stable_hash CP —
    including the total simulator event count."""
    assert fig7_cold_stats(cp_shards=4) == GOLD7_S4


def test_fig8_warm_s4_indirection_table_bit_identical_to_static_hash():
    assert fig8_warm_stats(cp_shards=4) == GOLD8_S4


def test_sharded_cp_same_workload_same_outcomes():
    """cp_shards=4 is a different event interleaving, not different results:
    everything completes, the creation/teardown economy is unchanged, and
    latency stats stay in the same band on an uncontended cluster."""
    g7 = fig7_cold_stats(cp_shards=4)
    assert (g7["done"], g7["total"]) == (GOLD7["done"], GOLD7["total"])
    assert g7["creations"] == GOLD7["creations"]
    assert g7["teardowns"] == GOLD7["teardowns"]
    assert abs(g7["p50"] - GOLD7["p50"]) / GOLD7["p50"] < 0.05
    g8 = fig8_warm_stats(cp_shards=4)
    assert (g8["done"], g8["creations"]) == (GOLD8["done"], GOLD8["creations"])
    assert abs(g8["p50"] - GOLD8["p50"]) / GOLD8["p50"] < 0.05


# -- shard mechanics ----------------------------------------------------------

def make_cluster(seed=3, **kw):
    env = Environment(seed=seed)
    kw.setdefault("n_workers", 16)
    kw.setdefault("enable_ha_sim", True)
    cl = Cluster(env, **kw)
    cl.start()
    return env, cl


def test_functions_and_workers_partition_across_shards():
    env, cl = make_cluster(cp_shards=4)
    names = [f"f{i}" for i in range(12)]
    for n in names:
        cl.register_sync(Function(name=n, image_url="i", port=80))
    leader = cl.control_plane_leader()
    assert len(leader.shards) == 4
    # every function lives in exactly one shard, the stable_hash one
    owned = {}
    for shard in leader.shards:
        for n in shard.functions:
            assert n not in owned
            owned[n] = shard.shard_id
    assert owned == {n: stable_hash(n) % 4 for n in names}
    assert set(owned) == set(leader.functions)
    # workers partition by wid % cp_shards, matching the placer partition
    for shard in leader.shards:
        assert all(wid % 4 == shard.shard_id
                   for wid in shard.worker_last_hb)
    assert sum(len(s.worker_last_hb) for s in leader.shards) == 16


def test_placement_stays_shard_local_until_spill():
    env, cl = make_cluster(cp_shards=4, n_workers=16)
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(stable_window=300,
                                                    scale_to_zero_grace=300)))
    leader = cl.control_plane_leader()
    k = stable_hash("f") % 4
    invs = [cl.invoke("f", exec_time=5.0) for _ in range(3)]
    env.run(until=10.0)
    assert all(not i.failed for i in invs)
    st = leader.functions["f"]
    # hot path: every sandbox landed on the owning shard's own workers
    assert all(sb.worker_id % 4 == k for sb in st.sandboxes.values())


def test_placement_spills_cross_shard_when_own_shard_full():
    # 4 workers / 4 shards -> exactly one worker per shard; a function whose
    # demand exceeds its own worker's capacity must spill to foreign shards
    env, cl = make_cluster(cp_shards=4, n_workers=4)
    cl.register_sync(Function(
        name="f", image_url="i", port=80,
        scaling=ScalingConfig(stable_window=300, scale_to_zero_grace=300,
                              cpu_req_millis=4000, mem_req_mb=1024)))
    k = stable_hash("f") % 4
    invs = [cl.invoke("f", exec_time=5.0) for _ in range(4)]
    env.run(until=10.0)
    assert all(not i.failed for i in invs)
    leader = cl.control_plane_leader()
    wids = {sb.worker_id for sb in leader.functions["f"].sandboxes.values()}
    assert any(w % 4 == k for w in wids)        # own shard used first
    assert any(w % 4 != k for w in wids), "no cross-shard spill happened"


def test_per_shard_health_eviction_concurrent_multi_worker_failure():
    """Workers in *different* shards fail at the same instant: each owning
    shard's health monitor evicts its own dead worker, affected functions are
    reconciled across shards, and replacements land off the dead workers."""
    env, cl = make_cluster(cp_shards=4, n_workers=16)
    names = [f"f{i}" for i in range(8)]
    for n in names:
        cl.register_sync(Function(name=n, image_url="i", port=80,
                                  scaling=ScalingConfig(
                                      stable_window=120,
                                      scale_to_zero_grace=120)))
    invs = [cl.invoke(n, exec_time=0.01) for n in names]
    env.run(until=5.0)
    assert all(not i.failed for i in invs)
    leader = cl.control_plane_leader()
    used = sorted({sb.worker_id for n in names
                   for sb in leader.functions[n].sandboxes.values()})
    # kill one used worker in each of (at least) two different shards
    victims, shards_hit = [], set()
    for wid in used:
        if wid % 4 not in shards_hit:
            victims.append(wid)
            shards_hit.add(wid % 4)
        if len(victims) == 3:
            break
    assert len(victims) >= 2, f"workload only touched shards {shards_hit}"
    for wid in victims:
        cl.fail_worker_daemon(wid)

    def traffic(env):
        while env.now < 20.0:
            for n in names:
                cl.invoke(n, exec_time=0.05)
            yield env.timeout(0.5)

    env.process(traffic(env), name="traffic")
    env.run(until=25.0)
    evicted = [d for t, k, d in cl.collector.events if k == "worker-evicted"]
    for wid in victims:
        assert wid in evicted, f"worker {wid} never evicted"
        assert wid not in leader.shards[wid % 4].worker_last_hb
    for n in names:
        st = leader.functions[n]
        assert st.ready_count >= 1, f"{n} lost all capacity"
        assert all(sb.worker_id not in victims
                   for sb in st.sandboxes.values())
    late = [cl.invoke(n, exec_time=0.01) for n in names]
    env.run(until=35.0)
    assert all(not i.failed for i in late)


def test_failover_rebuilds_all_shards():
    env, cl = make_cluster(cp_shards=4)
    names = [f"f{i}" for i in range(6)]
    for n in names:
        cl.register_sync(Function(name=n, image_url="i", port=80))
    invs = [cl.invoke(n, exec_time=0.01) for n in names]
    env.run(until=5.0)
    cl.fail_control_plane_leader()
    env.run(until=7.0)
    leader = cl.control_plane_leader()
    assert leader is not None and leader.cp_id != 0
    # function records land back in their owning shards, same partition
    for n in names:
        k = stable_hash(n) % 4
        assert n in leader.shards[k].functions
        # sandbox state reconstructed from the workers, not persistence
        assert leader.functions[n].ready_count >= 1
    assert sum(len(s.worker_last_hb) for s in leader.shards) == 16
    warm = [cl.invoke(n, exec_time=0.01) for n in names]
    env.run(until=12.0)
    assert all(not i.failed for i in warm)


def test_cross_shard_reconcile_halts_on_leadership_loss():
    """Regression: eviction fan-out processes are not in the CP's loop list,
    so stop() cannot kill them — a leader deposed mid-fan-out must not keep
    making scaling decisions against the shared workers."""
    env, cl = make_cluster(cp_shards=4, n_workers=8, n_control_planes=1)
    names = [f"f{i}" for i in range(8)]
    for n in names:
        cl.register_sync(Function(name=n, image_url="i", port=80,
                                  scaling=ScalingConfig(stable_window=120,
                                                        scale_to_zero_grace=120)))
    invs = [cl.invoke(n, exec_time=0.01) for n in names]
    env.run(until=5.0)
    assert all(not i.failed for i in invs)
    leader = cl.control_plane_leader()
    # a fan-out message is in flight (its cp_cross_shard_op handoff pending)
    # when the leader is deposed: it must do nothing once it fires
    target = next(s for s in leader.shards if s.functions)
    env.process(leader._cross_shard_reconcile(target,
                                              list(target.functions)),
                name="xshard-inflight")
    r0 = cl.collector.reconciles
    leader.stop()
    env.run(until=env.now + 5.0)
    # no CP is alive (single replica): any further reconcile would be the
    # dead leader's fan-out still mutating shared cluster state
    assert cl.collector.reconciles == r0


def test_eviction_fanout_targets_only_affected_foreign_functions():
    """An eviction must hand foreign shards only the functions that actually
    lost sandboxes on the dead worker (spills), not a full reconcile of every
    shard — unaffected functions are the autoscale loops' business."""
    env, cl = make_cluster(cp_shards=4, n_workers=4)
    # one worker per shard: force f's second sandbox to spill cross-shard
    cl.register_sync(Function(
        name="f", image_url="i", port=80,
        scaling=ScalingConfig(stable_window=300, scale_to_zero_grace=300,
                              cpu_req_millis=6000, mem_req_mb=1024)))
    invs = [cl.invoke("f", exec_time=5.0) for _ in range(2)]
    env.run(until=10.0)
    assert all(not i.failed for i in invs)
    leader = cl.control_plane_leader()
    k = stable_hash("f") % 4
    spilled = [sb for sb in leader.functions["f"].sandboxes.values()
               if sb.worker_id % 4 != k]
    assert spilled, "no cross-shard spill to evict"
    wid = spilled[0].worker_id
    owner = leader._worker_shard(wid)
    fanouts = []
    orig = leader._cross_shard_reconcile

    def spy(shard, fns):
        fanouts.append((shard.shard_id, list(fns)))
        return orig(shard, fns)

    leader._cross_shard_reconcile = spy
    ev = env.process(leader._evict_worker(owner, wid), name="evict")
    env.run_until_event(ev)
    env.run(until=env.now + 1.0)
    # exactly one targeted fan-out: to f's owning shard, for f alone
    assert fanouts == [(k, ["f"])]


def test_scale_lock_convoy_shrinks_with_shards():
    """The C1 convoy is measurable: at a churn rate one lock cannot absorb,
    sharding the CP divides the accumulated scale-lock wait time."""
    def lock_wait(cp_shards):
        env = Environment(seed=7)
        cl = Cluster(env, n_workers=64, runtime="firecracker",
                     cp_shards=cp_shards)
        cl.start()
        leader = cl.control_plane_leader()
        names = [f"f{i}" for i in range(600)]
        _preload(cl, names, COLD_SCALING)

        def driver(env):
            for n in names:
                cl.invoke(n, exec_time=0.05)
                yield env.timeout(1.0 / 3000.0)   # 3000/s > one lock's ~2700/s

        env.process(driver(env), name="driver")
        env.run(until=10.0)
        return sum(s.lock_wait_s for s in leader.shards)

    w1, w4 = lock_wait(1), lock_wait(4)
    assert w1 > 0.0
    assert w4 < w1 / 2, f"sharding did not relieve the convoy: {w1} -> {w4}"


# -- load-adaptive rebalancing -------------------------------------------------

def names_on_shard(shard_id, n, cp_shards=4, limit=10_000):
    """Deterministic function names that all hash to one shard."""
    out = []
    for i in range(limit):
        name = f"f{i}"
        if stable_hash(name) % cp_shards == shard_id:
            out.append(name)
            if len(out) == n:
                return out
    raise AssertionError("not enough names")


def test_rebalance_off_table_is_pure_hash():
    """With rebalancing off (default), the indirection table is exactly the
    static hash partition and nothing ever migrates."""
    env, cl = make_cluster(cp_shards=4, n_workers=16)
    names = [f"f{i}" for i in range(12)]
    for n in names:
        cl.register_sync(Function(name=n, image_url="i", port=80))
    invs = [cl.invoke(n, exec_time=0.01) for n in names]
    env.run(until=10.0)
    assert all(not i.failed for i in invs)
    leader = cl.control_plane_leader()
    assert leader.fn_shard_table == {n: stable_hash(n) % 4 for n in names}
    assert cl.collector.fn_migrations == 0
    assert not cl.store.peek_prefix("shardmap/")


def test_hot_shard_rebalances_to_cold_shards():
    """Skewed load — every function hashes to shard 1 — makes that shard's
    scale lock convoy; the rebalancer migrates functions out until load
    spreads, invocations keep succeeding, and table/shards/persistence stay
    consistent."""
    env = Environment(seed=5)
    cl = Cluster(env, n_workers=32, runtime="firecracker", cp_shards=4,
                 cp_rebalance_enabled=True)
    cl.start()
    leader = cl.control_plane_leader()
    names = names_on_shard(1, 30)
    _preload(cl, names, COLD_SCALING)

    def bursts(env):
        while env.now < 12.0:
            for n in names:
                cl.invoke(n, exec_time=0.05)
            yield env.timeout(1.0)

    env.process(bursts(env), name="bursts")
    env.run(until=20.0)
    assert cl.collector.fn_migrations > 0
    assert all(not i.failed for i in cl.collector.invocations)
    # load actually spread: shard 1 no longer owns everything
    owned_elsewhere = [n for n in names if leader.fn_shard_table[n] != 1]
    assert owned_elsewhere, "no function left the hot shard"
    # table ↔ shard-map consistency: every function lives in exactly the
    # shard its table entry points to
    seen = {}
    for shard in leader.shards:
        for n in shard.functions:
            assert n not in seen
            seen[n] = shard.shard_id
            assert leader.fn_shard_table[n] == shard.shard_id
    assert set(seen) == set(names)
    # every migrated function's override is durable and points at its shard
    shardmap = cl.store.peek_prefix("shardmap/")
    assert shardmap, "no shardmap overrides persisted"
    for key, rec in shardmap.items():
        name = key.split("/", 1)[1]
        assert leader.fn_shard_table[name] == int(rec.decode())


def test_migration_handoff_moves_pending_ep_flush_entries():
    """An endpoint update queued on the source shard but not yet flushed
    must travel with the migrating function and be broadcast exactly once."""
    env, cl = make_cluster(cp_shards=4, n_workers=8)
    leader = cl.control_plane_leader()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    src = leader._fn_shard("f")
    dst = leader.shards[(src.shard_id + 1) % 4]
    sb = Sandbox(sandbox_id=901, function_name="f", ip=(10, 0, 0, 1),
                 port=80, worker_id=src.shard_id)
    # queue the update and migrate in the same event-loop turn: the handoff
    # (an in-memory hop) wins the race against the batched flush (a gRPC)
    leader._queue_endpoint_update("add", "f", sb)
    assert any(u[1] == "f" for u in src.ep_updates)
    ev = env.process(leader._migrate_functions(src, dst, ["f"]),
                     name="migrate")
    env.run_until_event(ev)
    assert "f" in dst.functions and "f" not in src.functions
    assert leader.fn_shard_table["f"] == dst.shard_id
    assert not any(u[1] == "f" for u in src.ep_updates)
    env.run(until=env.now + 1.0)
    assert cl.collector.fn_migrations == 1
    for dp in cl.data_planes:
        eps = dp.tables["f"].endpoints
        assert list(eps) == [901], f"dp{dp.dp_id} saw {list(eps)}"


def test_failover_rebuilds_indirection_table():
    """A new leader must rebuild the indirection table from the persisted
    shardmap overrides — not just re-derive the hash — or a failover would
    silently undo every migration."""
    env, cl = make_cluster(cp_shards=4, n_workers=16,
                           cp_rebalance_enabled=True)
    leader = cl.control_plane_leader()
    names = [f"f{i}" for i in range(6)]
    for n in names:
        cl.register_sync(Function(name=n, image_url="i", port=80))
    invs = [cl.invoke(n, exec_time=0.01) for n in names]
    env.run(until=5.0)
    assert all(not i.failed for i in invs)
    # deterministically migrate one function to a foreign shard
    victim = names[0]
    src = leader._fn_shard(victim)
    dst = leader.shards[(src.shard_id + 2) % 4]
    ev = env.process(leader._migrate_functions(src, dst, [victim]),
                     name="migrate")
    env.run_until_event(ev)
    env.run(until=env.now + 1.0)
    assert leader.fn_shard_table[victim] == dst.shard_id
    cl.fail_control_plane_leader()
    env.run(until=env.now + 3.0)
    new_leader = cl.control_plane_leader()
    assert new_leader is not None and new_leader is not leader
    assert new_leader.fn_shard_table[victim] == dst.shard_id
    assert victim in new_leader.shards[dst.shard_id].functions
    assert victim not in new_leader.shards[src.shard_id].functions
    late = [cl.invoke(n, exec_time=0.01) for n in names]
    env.run(until=env.now + 5.0)
    assert all(not i.failed for i in late)


def test_deposed_leader_migration_aborts():
    """A migration handoff in flight when the leader is deposed must not
    mutate the table, the shards, or the persistent store."""
    env, cl = make_cluster(cp_shards=4, n_workers=8, n_control_planes=1,
                           cp_rebalance_enabled=True)
    leader = cl.control_plane_leader()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    src = leader._fn_shard("f")
    dst = leader.shards[(src.shard_id + 1) % 4]
    table_before = dict(leader.fn_shard_table)
    env.process(leader._migrate_functions(src, dst, ["f"]), name="migrate")
    leader.stop()
    env.run(until=env.now + 2.0)
    assert cl.collector.fn_migrations == 0
    assert leader.fn_shard_table == table_before
    assert "f" not in dst.functions
    assert not cl.store.peek_prefix("shardmap/")


# -- work-stealing capacity spill ---------------------------------------------

def test_spill_steals_from_least_loaded_shard():
    """The capacity spill probes the least-loaded foreign shard first (by
    the shard load signal), not the next shard in round-robin order."""
    env, cl = make_cluster(cp_shards=4, n_workers=8)   # 2 workers per shard
    leader = cl.control_plane_leader()
    # a sandbox fills a whole worker: the owning shard fits exactly 2
    cl.register_sync(Function(
        name="f", image_url="i", port=80,
        scaling=ScalingConfig(stable_window=300, scale_to_zero_grace=300,
                              cpu_req_millis=10_000, mem_req_mb=1024)))
    k = leader._fn_shard_id("f")
    # round-robin would pick shard k+1 first; load says k+2 is the coldest
    # (load_ema is the smoothed lock-wait signal the health loops maintain)
    leader.shards[(k + 1) % 4].load_ema = 1.0
    leader.shards[(k + 2) % 4].load_ema = 0.001
    leader.shards[(k + 3) % 4].load_ema = 0.5
    invs = [cl.invoke("f", exec_time=30.0) for _ in range(3)]
    env.run(until=10.0)
    assert all(not i.failed for i in invs)
    wids = sorted(sb.worker_id % 4
                  for sb in leader.functions["f"].sandboxes.values())
    assert wids.count(k) == 2, f"own shard not filled first: {wids}"
    stolen = [w for w in wids if w != k]
    assert stolen == [(k + 2) % 4], \
        f"stole from {stolen}, expected least-loaded {(k + 2) % 4}"
    assert cl.collector.steals == 1
    assert cl.collector.steal_probes >= 1


def test_failed_probe_backs_off_victim_shard():
    """A probe that finds a victim shard full marks it with a steal backoff
    so subsequent spills demote it, and the spill still finds capacity
    wherever it exists (correctness unaffected by backoff)."""
    env, cl = make_cluster(cp_shards=4, n_workers=4)   # 1 worker per shard
    leader = cl.control_plane_leader()
    cl.register_sync(Function(
        name="f", image_url="i", port=80,
        scaling=ScalingConfig(stable_window=300, scale_to_zero_grace=300,
                              cpu_req_millis=10_000, mem_req_mb=1024)))
    # more demand than the whole cluster fits: probes must exhaust and
    # back off every foreign shard, yet all 4 workers end up used
    invs = [cl.invoke("f", exec_time=30.0) for _ in range(6)]
    env.run(until=10.0)
    k = leader._fn_shard_id("f")
    used = {sb.worker_id % 4
            for sb in leader.functions["f"].sandboxes.values()}
    assert used == {0, 1, 2, 3}
    backed_off = [s.shard_id for s in leader.shards
                  if s.steal_backoff_until > 0.0]
    assert backed_off, "no failed probe ever recorded a backoff"
    assert k not in backed_off            # own shard is never a steal victim
    assert cl.collector.steals == 3
    assert cl.collector.steal_probes > cl.collector.steals
