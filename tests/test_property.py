"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.abstractions import Function, Sandbox, SandboxState, ScalingConfig
from repro.core.autoscaler import FunctionAutoscalerState
from repro.core.placement import Placer, make_placer
from repro.core.baseline_knative import TokenBucket
from repro.simcore import Environment


@given(sid=st.integers(0, 2**32 - 1),
       ip=st.tuples(*[st.integers(0, 255)] * 4),
       port=st.integers(0, 2**16 - 1),
       wid=st.integers(0, 2**32 - 1),
       state=st.sampled_from(list(SandboxState)))
def test_sandbox_codec_roundtrip(sid, ip, port, wid, state):
    sb = Sandbox(sandbox_id=sid, function_name="f", ip=ip, port=port,
                 worker_id=wid, state=state)
    raw = sb.to_bytes()
    assert len(raw) == 16
    back = Sandbox.from_bytes(raw, "f")
    assert (back.sandbox_id, back.ip, back.port, back.worker_id,
            back.state) == (sid, ip, port, wid, state)


@given(name=st.text(min_size=1, max_size=64).filter(lambda s: "\x00" not in s),
       url=st.text(min_size=0, max_size=128),
       port=st.integers(0, 2**16 - 1),
       tc=st.floats(0.5, 64, allow_nan=False),
       ms=st.integers(1, 100000))
def test_function_record_roundtrip_property(name, url, port, tc, ms):
    fn = Function(name=name, image_url=url, port=port,
                  scaling=ScalingConfig(target_concurrency=tc, max_scale=ms))
    back = Function.from_record(fn.persisted_record())
    assert back.name == name and back.image_url == url and back.port == port
    assert abs(back.scaling.target_concurrency - tc) < 1e-3
    assert back.scaling.max_scale == ms


@given(concurrency=st.lists(st.integers(0, 50), min_size=1, max_size=60),
       target=st.floats(0.5, 8.0))
@settings(max_examples=60)
def test_autoscaler_desired_invariants(concurrency, target):
    """desired is never negative, bounded by max_scale, and zero demand
    never scales UP."""
    sc = ScalingConfig(target_concurrency=target, max_scale=100)
    state = FunctionAutoscalerState(sc)
    t = 0.0
    ready = 0
    for c in concurrency:
        state.record_metric(t, float(c))
        d = state.desired(t, ready)
        assert 0 <= d <= sc.max_scale
        if all(x == 0 for x in concurrency[:concurrency.index(c) + 1]):
            assert d <= max(ready, 0)
        ready = d
        t += 2.0


@given(reqs=st.lists(st.tuples(st.integers(50, 2000), st.integers(64, 2048)),
                     min_size=1, max_size=40),
       n_nodes=st.integers(1, 12))
@settings(max_examples=40)
def test_placement_never_overcommits(reqs, n_nodes):
    p = Placer()
    for i in range(n_nodes):
        p.add_node(i, 4000, 8192)
    placed = []
    for cpu, mem in reqs:
        wid = p.place(cpu, mem)
        if wid is not None:
            placed.append((wid, cpu, mem))
    for i in range(n_nodes):
        node = p.nodes[i]
        assert node.cpu_used <= node.cpu_capacity
        assert node.mem_used <= node.mem_capacity
    # conservation: committed == sum of placed requests
    assert sum(c for _, c, _ in placed) == sum(n.cpu_used
                                               for n in p.nodes.values())
    # release restores to zero
    for wid, cpu, mem in placed:
        p.release(wid, cpu, mem)
    assert all(n.cpu_used == 0 and n.mem_used == 0 for n in p.nodes.values())


_REQ_SIZES = [(100, 128), (250, 512), (1000, 2048), (2000, 4096)]


@given(caps=st.lists(st.tuples(st.integers(500, 8000),
                               st.integers(512, 16384)),
                     min_size=1, max_size=30),
       ops=st.lists(st.one_of(
           st.tuples(st.just("place"), st.sampled_from(_REQ_SIZES)),
           st.tuples(st.just("release"), st.integers(0, 2**31)),
           st.tuples(st.just("sched"), st.integers(0, 29)),
           st.tuples(st.just("readd"),
                     st.tuples(st.integers(0, 29), st.integers(500, 8000),
                               st.integers(512, 16384))),
       ), min_size=1, max_size=120),
       policy=st.sampled_from(["balanced", "hermod_packing"]))
@settings(max_examples=60)
def test_placer_index_matches_brute_force(caps, ops, policy):
    """The incremental score index must reproduce the brute-force scan
    bit-for-bit: same winner (including the lowest-id tie-break) on every
    placement of an arbitrary interleaving of place/release/schedulability
    operations over random node sets."""
    fast = Placer(policy, use_index=True)
    ref = Placer(policy, use_index=False)
    assert fast.use_index and not ref.use_index
    for i, (c, m) in enumerate(caps):
        fast.add_node(i, c, m)
        ref.add_node(i, c, m)
    placed = []
    for op, arg in ops:
        if op == "place":
            cpu, mem = arg
            got, want = fast.place(cpu, mem), ref.place(cpu, mem)
            assert got == want
            if got is not None:
                placed.append((got, cpu, mem))
        elif op == "release" and placed:
            wid, cpu, mem = placed.pop(arg % len(placed))
            fast.release(wid, cpu, mem)
            ref.release(wid, cpu, mem)
        elif op == "sched":
            wid = arg % len(caps)
            ok = arg % 2 == 0
            fast.set_schedulable(wid, ok)
            ref.set_schedulable(wid, ok)
        elif op == "readd":
            wid, c, m = arg[0] % len(caps), arg[1], arg[2]
            placed = [p for p in placed if p[0] != wid]
            fast.remove_node(wid)
            ref.remove_node(wid)
            fast.add_node(wid, c, m)
            ref.add_node(wid, c, m)
    for i in range(len(caps)):
        assert (fast.nodes[i].cpu_used, fast.nodes[i].mem_used) == \
               (ref.nodes[i].cpu_used, ref.nodes[i].mem_used)


@given(reqs=st.lists(st.tuples(st.integers(50, 2000), st.integers(64, 2048)),
                     min_size=1, max_size=60),
       n_nodes=st.integers(1, 24), n_shards=st.integers(1, 8))
@settings(max_examples=40)
def test_partitioned_placer_never_overcommits(reqs, n_nodes, n_shards):
    p = make_placer("partitioned", n_shards=n_shards)
    for i in range(n_nodes):
        p.add_node(i, 4000, 8192)
    placed = []
    for cpu, mem in reqs:
        wid = p.place(cpu, mem)
        if wid is not None:
            placed.append((wid, cpu, mem))
    for i in range(n_nodes):
        node = p.nodes[i]
        assert node.cpu_used <= node.cpu_capacity
        assert node.mem_used <= node.mem_capacity
    assert sum(c for _, c, _ in placed) == sum(n.cpu_used
                                               for n in p.nodes.values())
    for wid, cpu, mem in placed:
        p.release(wid, cpu, mem)
    assert all(n.cpu_used == 0 and n.mem_used == 0 for n in p.nodes.values())


@given(qps=st.floats(1.0, 100.0), burst=st.integers(1, 50),
       n=st.integers(1, 80))
@settings(max_examples=40)
def test_token_bucket_rate_limit(qps, burst, n):
    """After the burst credit, admission times respect the refill rate."""
    env = Environment(seed=0)
    tb = TokenBucket(env, qps, burst)
    times = []

    def client(env):
        for _ in range(n):
            yield from tb.acquire()
            times.append(env.now)

    env.process(client(env), name="c")
    env.run()
    assert len(times) == n
    # the i-th admission can't be earlier than (i - burst) / qps
    for i, t in enumerate(times):
        assert t >= (i - burst) / qps - 1e-6
    assert all(b >= a for a, b in zip(times, times[1:]))


@given(fn=st.text(min_size=1, max_size=32),
       n_dps=st.integers(1, 16), width=st.integers(1, 20))
@settings(max_examples=80)
def test_fn_dp_set_properties(fn, n_dps, width):
    """fn→DP-set steering invariants: deterministic (stable_hash, so the
    same across processes), every member drawn from the rotation without
    duplicates, home member first, and width 1 degrades to the sole-DP
    sticky pick."""
    from repro.core.cluster import fn_dp_set
    from repro.simcore import stable_hash
    backends = list(range(n_dps))
    members = fn_dp_set(fn, backends, width)
    # deterministic: recomputation from the same rotation is identical
    assert members == fn_dp_set(fn, backends, width)
    # clamped width, all members distinct and in the rotation
    assert len(members) == min(max(1, width), n_dps)
    assert len(set(members)) == len(members)
    assert set(members) <= set(backends)
    # the home member is the sticky hash pick — width 1 IS the default path
    home = backends[stable_hash(fn) % n_dps]
    assert members[0] == home
    assert fn_dp_set(fn, backends, 1) == (home,)


@given(fn=st.text(min_size=1, max_size=16),
       n_dps=st.integers(2, 6), width=st.integers(2, 6))
@settings(max_examples=20, deadline=None)
def test_steer_round_robin_covers_dp_set(fn, n_dps, width):
    """A spread function's invocations round-robin over every member of its
    DP-set; an unspread function always takes the sticky hash pick."""
    from repro.simcore import stable_hash
    env = Environment(seed=3)
    cl = __import__("repro.core.cluster", fromlist=["Cluster"]).Cluster(
        env, n_workers=1, n_data_planes=n_dps, dp_spread_enabled=True,
        dp_spread_min_rate=1e9)    # never auto-widen: the table is explicit
    members = cl.spread_function(fn, width=width)
    picks = [cl._steer(fn).dp_id for _ in range(3 * len(members))]
    # full coverage of the set, in set order, nothing outside it
    assert set(picks) == set(members)
    assert picks[:len(members)] == list(members)
    # a function not in the table stays sticky to its sole hash-picked DP
    other = fn + "x"
    sticky = {cl._steer(other).dp_id for _ in range(5)}
    assert sticky == {stable_hash(other) % n_dps}


@given(data=st.lists(st.tuples(st.sampled_from(["a", "b", "c"]),
                               st.binary(min_size=0, max_size=64)),
                     min_size=1, max_size=30))
def test_filestore_replay_equals_memory(tmp_path_factory, data):
    from repro.core.persistence import FileStore
    path = str(tmp_path_factory.mktemp("fs") / "wal.log")
    st_ = FileStore(path, fsync=False)
    expect = {}
    for k, v in data:
        if v == b"":
            st_.write(k, None)
            expect.pop(k, None)
        else:
            st_.write(k, v)
            expect[k] = v
    st_.close()
    st2 = FileStore(path, fsync=False)
    assert st2.data == expect
    st2.close()
