"""Unit tests for the discrete-event engine."""
import pytest

from repro.simcore import Environment, Interrupt


def test_timeout_ordering():
    env = Environment()
    log = []

    def proc(env, name, delay):
        yield env.timeout(delay)
        log.append((env.now, name))

    env.process(proc(env, "b", 2.0))
    env.process(proc(env, "a", 1.0))
    env.process(proc(env, "c", 3.0))
    env.run()
    assert log == [(1.0, "a"), (2.0, "b"), (3.0, "c")]


def test_fifo_same_time():
    env = Environment()
    log = []

    def proc(env, name):
        yield env.timeout(1.0)
        log.append(name)

    for n in "abc":
        env.process(proc(env, n))
    env.run()
    assert log == ["a", "b", "c"]


def test_process_return_value():
    env = Environment()

    def child(env):
        yield env.timeout(5.0)
        return 42

    def parent(env, out):
        val = yield env.process(child(env))
        out.append((env.now, val))

    out = []
    env.process(parent(env, out))
    env.run()
    assert out == [(5.0, 42)]


def test_store_blocking_get():
    env = Environment()
    store = env.store()
    log = []

    def consumer(env):
        item = yield store.get()
        log.append((env.now, item))

    def producer(env):
        yield env.timeout(3.0)
        store.put("x")

    env.process(consumer(env))
    env.process(producer(env))
    env.run()
    assert log == [(3.0, "x")]


def test_store_fifo_items_and_getters():
    env = Environment()
    store = env.store()
    log = []

    def consumer(env, name):
        item = yield store.get()
        log.append((name, item))

    env.process(consumer(env, "c1"))
    env.process(consumer(env, "c2"))

    def producer(env):
        yield env.timeout(1.0)
        store.put(1)
        store.put(2)

    env.process(producer(env))
    env.run()
    assert log == [("c1", 1), ("c2", 2)]


def test_resource_contention():
    env = Environment()
    res = env.resource(capacity=1)
    log = []

    def worker(env, name):
        yield res.acquire()
        log.append((env.now, name, "start"))
        yield env.timeout(2.0)
        res.release()
        log.append((env.now, name, "end"))

    env.process(worker(env, "a"))
    env.process(worker(env, "b"))
    env.run()
    assert log == [
        (0.0, "a", "start"),
        (2.0, "a", "end"),
        (2.0, "b", "start"),
        (4.0, "b", "end"),
    ]


def test_resource_capacity_n():
    env = Environment()
    res = env.resource(capacity=2)
    starts = []

    def worker(env, name):
        yield res.acquire()
        starts.append((env.now, name))
        yield env.timeout(1.0)
        res.release()

    for n in "abc":
        env.process(worker(env, n))
    env.run()
    assert starts == [(0.0, "a"), (0.0, "b"), (1.0, "c")]


def test_interrupt():
    env = Environment()
    log = []

    def sleeper(env):
        try:
            yield env.timeout(100.0)
            log.append("slept")
        except Interrupt as it:
            log.append(("interrupted", env.now, it.cause))

    def interrupter(env, target):
        yield env.timeout(1.0)
        target.interrupt("wake")

    p = env.process(sleeper(env))
    env.process(interrupter(env, p))
    env.run()
    assert log == [("interrupted", 1.0, "wake")]


def test_kill():
    env = Environment()
    log = []

    def sleeper(env):
        yield env.timeout(10.0)
        log.append("should not happen")

    p = env.process(sleeper(env))

    def killer(env):
        yield env.timeout(1.0)
        p.kill()

    env.process(killer(env))
    env.run()
    assert log == []
    assert not p.is_alive


def test_any_of():
    env = Environment()
    log = []

    def proc(env):
        idx, val = yield env.any_of([env.timeout(5.0, "slow"), env.timeout(2.0, "fast")])
        log.append((env.now, idx, val))

    env.process(proc(env))
    env.run()
    assert log == [(2.0, 1, "fast")]


def test_run_until():
    env = Environment()
    ticks = []

    def ticker(env):
        while True:
            yield env.timeout(1.0)
            ticks.append(env.now)

    env.process(ticker(env))
    env.run(until=3.5)
    assert ticks == [1.0, 2.0, 3.0]
    assert env.now == 3.5


def test_cross_process_determinism():
    """Simulation state must not depend on the per-process hash salt:
    identical seeds give identical results under different PYTHONHASHSEED
    (regression for builtin hash() feeding RNG streams and DP steering)."""
    import os
    import pathlib
    import subprocess
    import sys
    root = pathlib.Path(__file__).resolve().parents[1]
    code = (
        "from repro.core import Cluster, Function\n"
        "from repro.simcore import Environment\n"
        "env = Environment(seed=3)\n"
        "cl = Cluster(env, n_workers=4)\n"
        "cl.start()\n"
        "cl.register_sync(Function(name='fn-det', image_url='i', port=80))\n"
        "invs = [cl.invoke('fn-det', exec_time=0.01) for _ in range(5)]\n"
        "env.run(until=10.0)\n"
        "print([round(i.e2e_latency, 12) for i in invs])\n"
    )
    outs = []
    for salt in ("1", "2"):
        r = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            env=dict(os.environ, PYTHONHASHSEED=salt,
                     PYTHONPATH=str(root / "src")),
            cwd=str(root), timeout=120)
        assert r.returncode == 0, r.stderr[-2000:]
        outs.append(r.stdout)
    assert outs[0] == outs[1]


def test_rng_determinism():
    a = Environment(seed=7).rng("s").expovariate(1.0)
    b = Environment(seed=7).rng("s").expovariate(1.0)
    c = Environment(seed=8).rng("s").expovariate(1.0)
    assert a == b
    assert a != c


def test_rng_stream_independence():
    env = Environment(seed=1)
    xs = [env.rng("x").random() for _ in range(3)]
    env2 = Environment(seed=1)
    _ = [env2.rng("y").random() for _ in range(5)]
    xs2 = [env2.rng("x").random() for _ in range(3)]
    assert xs == xs2


def test_nested_process_failure_propagates_to_parent():
    env = Environment()

    def child(env):
        yield env.timeout(1.0)
        raise ValueError("boom")

    def parent(env, log):
        try:
            yield env.process(child(env))
        except ValueError as e:
            log.append(str(e))

    log = []
    env.process(parent(env, log))
    env.run()
    assert log == ["boom"]


def test_unobserved_process_failure_raises():
    env = Environment()

    def child(env):
        yield env.timeout(1.0)
        raise ValueError("boom")

    env.process(child(env))
    with pytest.raises(ValueError):
        env.run()
