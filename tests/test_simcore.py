"""Unit tests for the discrete-event engine."""
import pytest

from repro.simcore import Environment, Interrupt


def test_timeout_ordering():
    env = Environment()
    log = []

    def proc(env, name, delay):
        yield env.timeout(delay)
        log.append((env.now, name))

    env.process(proc(env, "b", 2.0))
    env.process(proc(env, "a", 1.0))
    env.process(proc(env, "c", 3.0))
    env.run()
    assert log == [(1.0, "a"), (2.0, "b"), (3.0, "c")]


def test_fifo_same_time():
    env = Environment()
    log = []

    def proc(env, name):
        yield env.timeout(1.0)
        log.append(name)

    for n in "abc":
        env.process(proc(env, n))
    env.run()
    assert log == ["a", "b", "c"]


def test_process_return_value():
    env = Environment()

    def child(env):
        yield env.timeout(5.0)
        return 42

    def parent(env, out):
        val = yield env.process(child(env))
        out.append((env.now, val))

    out = []
    env.process(parent(env, out))
    env.run()
    assert out == [(5.0, 42)]


def test_store_blocking_get():
    env = Environment()
    store = env.store()
    log = []

    def consumer(env):
        item = yield store.get()
        log.append((env.now, item))

    def producer(env):
        yield env.timeout(3.0)
        store.put("x")

    env.process(consumer(env))
    env.process(producer(env))
    env.run()
    assert log == [(3.0, "x")]


def test_store_fifo_items_and_getters():
    env = Environment()
    store = env.store()
    log = []

    def consumer(env, name):
        item = yield store.get()
        log.append((name, item))

    env.process(consumer(env, "c1"))
    env.process(consumer(env, "c2"))

    def producer(env):
        yield env.timeout(1.0)
        store.put(1)
        store.put(2)

    env.process(producer(env))
    env.run()
    assert log == [("c1", 1), ("c2", 2)]


def test_resource_contention():
    env = Environment()
    res = env.resource(capacity=1)
    log = []

    def worker(env, name):
        yield res.acquire()
        log.append((env.now, name, "start"))
        yield env.timeout(2.0)
        res.release()
        log.append((env.now, name, "end"))

    env.process(worker(env, "a"))
    env.process(worker(env, "b"))
    env.run()
    assert log == [
        (0.0, "a", "start"),
        (2.0, "a", "end"),
        (2.0, "b", "start"),
        (4.0, "b", "end"),
    ]


def test_resource_capacity_n():
    env = Environment()
    res = env.resource(capacity=2)
    starts = []

    def worker(env, name):
        yield res.acquire()
        starts.append((env.now, name))
        yield env.timeout(1.0)
        res.release()

    for n in "abc":
        env.process(worker(env, n))
    env.run()
    assert starts == [(0.0, "a"), (0.0, "b"), (1.0, "c")]


def test_interrupt():
    env = Environment()
    log = []

    def sleeper(env):
        try:
            yield env.timeout(100.0)
            log.append("slept")
        except Interrupt as it:
            log.append(("interrupted", env.now, it.cause))

    def interrupter(env, target):
        yield env.timeout(1.0)
        target.interrupt("wake")

    p = env.process(sleeper(env))
    env.process(interrupter(env, p))
    env.run()
    assert log == [("interrupted", 1.0, "wake")]


def test_kill():
    env = Environment()
    log = []

    def sleeper(env):
        yield env.timeout(10.0)
        log.append("should not happen")

    p = env.process(sleeper(env))

    def killer(env):
        yield env.timeout(1.0)
        p.kill()

    env.process(killer(env))
    env.run()
    assert log == []
    assert not p.is_alive


def test_any_of():
    env = Environment()
    log = []

    def proc(env):
        idx, val = yield env.any_of([env.timeout(5.0, "slow"), env.timeout(2.0, "fast")])
        log.append((env.now, idx, val))

    env.process(proc(env))
    env.run()
    assert log == [(2.0, 1, "fast")]


def test_run_until():
    env = Environment()
    ticks = []

    def ticker(env):
        while True:
            yield env.timeout(1.0)
            ticks.append(env.now)

    env.process(ticker(env))
    env.run(until=3.5)
    assert ticks == [1.0, 2.0, 3.0]
    assert env.now == 3.5


def test_cross_process_determinism():
    """Simulation state must not depend on the per-process hash salt:
    identical seeds give identical results under different PYTHONHASHSEED
    (regression for builtin hash() feeding RNG streams and DP steering)."""
    import os
    import pathlib
    import subprocess
    import sys
    root = pathlib.Path(__file__).resolve().parents[1]
    code = (
        "from repro.core import Cluster, Function\n"
        "from repro.simcore import Environment\n"
        "env = Environment(seed=3)\n"
        "cl = Cluster(env, n_workers=4)\n"
        "cl.start()\n"
        "cl.register_sync(Function(name='fn-det', image_url='i', port=80))\n"
        "invs = [cl.invoke('fn-det', exec_time=0.01) for _ in range(5)]\n"
        "env.run(until=10.0)\n"
        "print([round(i.e2e_latency, 12) for i in invs])\n"
    )
    outs = []
    for salt in ("1", "2"):
        r = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            env=dict(os.environ, PYTHONHASHSEED=salt,
                     PYTHONPATH=str(root / "src")),
            cwd=str(root), timeout=120)
        assert r.returncode == 0, r.stderr[-2000:]
        outs.append(r.stdout)
    assert outs[0] == outs[1]


def test_rng_determinism():
    a = Environment(seed=7).rng("s").expovariate(1.0)
    b = Environment(seed=7).rng("s").expovariate(1.0)
    c = Environment(seed=8).rng("s").expovariate(1.0)
    assert a == b
    assert a != c


def test_rng_stream_independence():
    env = Environment(seed=1)
    xs = [env.rng("x").random() for _ in range(3)]
    env2 = Environment(seed=1)
    _ = [env2.rng("y").random() for _ in range(5)]
    xs2 = [env2.rng("x").random() for _ in range(3)]
    assert xs == xs2


def test_nested_process_failure_propagates_to_parent():
    env = Environment()

    def child(env):
        yield env.timeout(1.0)
        raise ValueError("boom")

    def parent(env, log):
        try:
            yield env.process(child(env))
        except ValueError as e:
            log.append(str(e))

    log = []
    env.process(parent(env, log))
    env.run()
    assert log == ["boom"]


def test_unobserved_process_failure_raises():
    env = Environment()

    def child(env):
        yield env.timeout(1.0)
        raise ValueError("boom")

    env.process(child(env))
    with pytest.raises(ValueError):
        env.run()


# -- sole-waiter Timeout fast path -------------------------------------------
# ``yield env.timeout(x)`` resumes the process straight from the timer
# callback (Timeout._waiter) instead of the generic callback list. These pin
# the interrupt/kill semantics on that path: detaching must clear the waiter
# slot, and the stale timer firing later must not resume (or double-drive)
# the process.

def test_interrupt_detaches_sole_waiter_timeout():
    env = Environment()
    log = []

    def sleeper(env):
        try:
            yield env.timeout(100.0)
            log.append("overslept")
        except Interrupt as it:
            log.append(("interrupted", env.now, it.cause))
        # re-wait on a NEW timeout: the stale 100 s timer firing later must
        # not wake this yield
        yield env.timeout(200.0)
        log.append(("woke", env.now))

    p = env.process(sleeper(env))

    def interrupter(env):
        yield env.timeout(1.0)
        p.interrupt("wake")

    env.process(interrupter(env))
    env.run()
    assert log == [("interrupted", 1.0, "wake"), ("woke", 201.0)]


def test_kill_detaches_sole_waiter_timeout():
    env = Environment()
    log = []

    def sleeper(env):
        yield env.timeout(10.0)
        log.append("should not happen")

    p = env.process(sleeper(env))

    def killer(env):
        yield env.timeout(1.0)
        p.kill()

    env.process(killer(env))
    env.run()   # the orphaned 10 s timer fires with no waiter: must be a no-op
    assert log == []
    assert not p.is_alive
    assert env.now == 10.0


def test_interrupted_process_timeout_fires_while_parent_waits():
    """The stale timer must stay detached even when the process has since
    finished and a parent already consumed its result."""
    env = Environment()
    out = []

    def child(env):
        try:
            yield env.timeout(50.0)
        except Interrupt:
            return "early"
        return "late"

    def parent(env):
        p = env.process(child(env))
        yield env.timeout(1.0)
        p.interrupt()
        val = yield p
        out.append((env.now, val))

    env.process(parent(env))
    env.run()
    assert out == [(1.0, "early")]
    assert env.now == 50.0          # the detached timer still fired, harmlessly


def test_timeout_at_exact_instant():
    """timeout_at(t) fires at t bit-exactly even when now + (t - now) != t."""
    env = Environment()
    # 14 accumulated 25 ms grid steps: a value the netcfg/heartbeat float-add
    # chains actually produce, and one that a relative timeout from now=0.1
    # cannot hit (0.1 + (t - 0.1) rounds off the last bit)
    target = 0.0
    for _ in range(14):
        target += 0.025
    hits = []

    def proc(env):
        yield env.timeout(0.1)
        # the relative route would miss the instant: this is the rounding
        # error the absolute-deadline timeout exists to avoid
        assert env.now + (target - env.now) != target
        yield env.timeout_at(target)
        hits.append(env.now)

    env.process(proc(env))
    env.run()
    assert hits == [target]
    with pytest.raises(ValueError):
        env.timeout_at(env.now - 1.0)


# -- AnyOf loser-callback leak -------------------------------------------------

def test_any_of_detaches_loser_callbacks():
    """Regression: a long-lived event that repeatedly loses any_of races must
    not accumulate one dead callback per race (at most the single shared
    ``_observed`` sentinel remains)."""
    env = Environment()
    never = env.event()

    def racer(env):
        for _ in range(25):
            idx, _ = yield env.any_of([never, env.timeout(1.0)])
            assert idx == 1
    p = env.process(racer(env))
    env.run_until_event(p)
    assert len(never.callbacks) <= 1


def test_any_of_detached_loser_failure_stays_observed():
    """A raced-and-lost process that later fails must not crash the event
    loop as an 'unobserved failure' — losing an any_of race counts as being
    observed, with or without the detach optimization."""
    env = Environment()

    def doomed(env):
        yield env.timeout(5.0)
        raise RuntimeError("late failure of the race loser")

    def racer(env):
        idx, _ = yield env.any_of([env.process(doomed(env)),
                                   env.timeout(1.0)])
        assert idx == 1

    p = env.process(racer(env))
    env.run()           # the loser fails at t=5: must be swallowed
    assert p.fired and env.now == 5.0


def test_any_of_still_races_correctly_after_detach_fix():
    env = Environment()
    log = []

    def proc(env):
        first = env.timeout(2.0, "fast")
        idx, val = yield env.any_of([env.timeout(5.0, "slow"), first])
        log.append((env.now, idx, val))
        # the loser (5 s timer) fires later; the finished AnyOf must ignore it
        idx2, val2 = yield env.any_of([env.timeout(1.0, "again"),
                                       env.timeout(9.0)])
        log.append((env.now, idx2, val2))

    env.process(proc(env))
    env.run()
    assert log == [(2.0, 1, "fast"), (3.0, 0, "again")]


# -- schedule_at / Resource.reserve (zero-event timer devices) ----------------

def test_schedule_at_runs_callback_at_absolute_time():
    env = Environment()
    hits = []
    env.schedule_at(2.5, lambda: hits.append(env.now))
    env.run()
    assert hits == [2.5]
    with pytest.raises(ValueError):
        env.schedule_at(env.now - 1.0, lambda: None)


def test_resource_reserve_uncontended_is_reclaimed_lazily():
    env = Environment()
    res = env.resource(capacity=1)
    assert res.reserve(until=1.0)
    assert res.in_use == 1
    ev0 = env.events_processed

    def late(env):
        yield env.timeout(5.0)      # well past the reservation
        got = res.acquire()
        assert got.fired or got.triggered   # granted synchronously
        res.release()

    p = env.process(late(env))
    env.run_until_event(p)
    assert res.in_use == 0
    # the reservation itself contributed no events: just the process + timeout
    assert env.events_processed - ev0 <= 4


def test_resource_reserve_contender_waits_until_exact_release():
    env = Environment()
    res = env.resource(capacity=1)
    log = []

    def holder(env):
        yield env.timeout(2.0)
        assert res.reserve(until=env.now + 3.0)     # holds [2, 5)

    def contender(env):
        yield env.timeout(3.0)
        t0 = env.now
        yield res.acquire()
        log.append((t0, env.now))
        res.release()

    env.process(holder(env))
    env.process(contender(env))
    env.run()
    assert log == [(3.0, 5.0)]      # waited exactly until the phantom release
    assert res.in_use == 0


def test_resource_reserve_refuses_when_busy_or_waited_on():
    env = Environment()
    res = env.resource(capacity=1)

    def proc(env):
        yield res.acquire()
        assert not res.reserve(until=env.now + 1.0)   # busy
        res.release()
        assert res.reserve(until=env.now + 1.0)
        assert not res.reserve(until=env.now + 2.0)   # reservation running

    p = env.process(proc(env))
    env.run_until_event(p)
