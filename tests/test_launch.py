"""Launch-layer tests: mesh construction, sharding specs, step building on a
single-device mesh with reduced configs (the 512-device matrix is exercised
by launch/dryrun.py; see results/dryrun)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import SHAPES, ShapeSpec, applicable_shapes
from repro.launch.hlo_parse import parse_collectives
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import build_step, default_run_config
from repro.models.api import RunConfig, build_model
from repro.models.sharding import filter_spec, use_mesh


def test_applicable_shapes_policy():
    # long_500k only for sub-quadratic archs
    assert "long_500k" in applicable_shapes(get_config("rwkv6-7b"))
    assert "long_500k" in applicable_shapes(get_config("zamba2-2.7b"))
    assert "long_500k" not in applicable_shapes(get_config("qwen3-32b"))
    for arch in ("qwen3-32b", "rwkv6-7b", "whisper-small"):
        shapes = applicable_shapes(get_config(arch))
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


def test_filter_spec_no_mesh():
    assert filter_spec(P("model", None)) is None      # no mesh -> no-op


def test_build_step_reduced_on_local_mesh():
    mesh = make_local_mesh()
    cfg = get_config("qwen3-32b").reduced()
    shape = ShapeSpec("t", 64, 4, "train")
    with use_mesh(mesh):
        run = default_run_config(mesh, shape, q_chunk=16, kv_chunk=16)
        model = build_model(cfg, run)
        # spec trees are structurally consistent
        specs = model.param_specs()
        pspecs = model.param_pspecs()
        assert jax.tree.structure(specs) == jax.tree.structure(
            pspecs, is_leaf=lambda x: isinstance(x, P))
        import repro.train.train_step as ts
        from repro.train.optimizer import adamw_init
        params = model.init_params(jax.random.PRNGKey(0))
        fn = jax.jit(ts.make_train_step(model))
        batch = {"tokens": jnp.ones((4, 64), jnp.int32),
                 "labels": jnp.ones((4, 64), jnp.int32)}
        p2, o2, m = fn(params, adamw_init(params), batch,
                       jax.random.PRNGKey(1))
        assert not bool(jnp.isnan(m["loss"]))


def test_parse_collectives():
    hlo = """
  %ar = bf16[16,1024]{1,0} all-reduce(bf16[16,1024]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag.1 = f32[4,256]{1,0} all-gather(f32[1,256]{1,0} %y), replica_groups=[8,4]<=[32], dimensions={0}
  %p = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) collective-permute(...)
  %notacoll = bf16[2,2]{1,0} add(bf16[2,2] %a, bf16[2,2] %b)
"""
    out = parse_collectives(hlo)
    kinds = sorted(c["kind"] for c in out)
    assert kinds == ["all-gather", "all-reduce", "collective-permute"]
    ar = next(c for c in out if c["kind"] == "all-reduce")
    assert ar["bytes"] == 16 * 1024 * 2
    assert ar["group"] == 4
    ag = next(c for c in out if c["kind"] == "all-gather")
    assert ag["bytes"] == 4 * 256 * 4
    assert ag["group"] == 4
    cp = next(c for c in out if c["kind"] == "collective-permute")
    assert cp["bytes"] == 2 * 8 * 8 * 2


def test_roofline_math():
    from repro.launch.roofline import collective_bytes_on_wire, \
        model_flops_per_device
    s = {"all-reduce": {"bytes": 1000, "group": 16},
         "all-gather": {"bytes": 1600, "group": 16}}
    wire = collective_bytes_on_wire(s)
    assert abs(wire - (2 * 1000 * 15 / 16 + 1600 * 15 / 16)) < 1e-6
    mf = model_flops_per_device("qwen3-32b", "train_4k", 256)
    cfg = get_config("qwen3-32b")
    expect = 6 * cfg.n_params * 4096 * 256 / 256
    assert abs(mf - expect) / expect < 1e-6
    # MoE uses active params
    mfk = model_flops_per_device("kimi-k2-1t-a32b", "train_4k", 256)
    k = get_config("kimi-k2-1t-a32b")
    assert abs(mfk - 6 * k.n_active_params * 4096 * 256 / 256) / mfk < 1e-6
