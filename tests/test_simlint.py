"""simlint (src/repro/analysis): every rule fires on a minimal bad snippet,
the suppression machinery works both ways (valid suppressions silence, stale
ones are themselves findings), and — the actual point of the tool — the
checked tree lints clean, so CI can fail on any new finding.
"""
import os
import textwrap

import pytest

from repro.analysis import DEFAULT_PATHS, Finding, RULES, lint_paths, lint_source

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint(snippet: str):
    return lint_source(textwrap.dedent(snippet), path="snippet.py")


def rules_of(findings):
    return [f.rule for f in findings]


# -- builtin-hash -------------------------------------------------------------

def test_builtin_hash_fires():
    f = lint("""
        def shard_of(name, n):
            return hash(name) % n
    """)
    assert rules_of(f) == ["builtin-hash"]
    assert "stable_hash" in f[0].message


def test_stable_hash_is_clean():
    assert lint("""
        from repro.simcore import stable_hash
        def shard_of(name, n):
            return stable_hash(name) % n
    """) == []


# -- wall-clock ---------------------------------------------------------------

@pytest.mark.parametrize("expr", [
    "time.time()", "time.perf_counter()", "time.monotonic()",
    "datetime.now()", "datetime.datetime.utcnow()",
])
def test_wall_clock_fires(expr):
    f = lint(f"""
        import time, datetime
        def stamp(env):
            return {expr}
    """)
    assert rules_of(f) == ["wall-clock"]


def test_sim_clock_is_clean():
    assert lint("""
        def stamp(env):
            return env.now
    """) == []


# -- global-rng ---------------------------------------------------------------

@pytest.mark.parametrize("expr", [
    "random.random()", "random.randint(0, 9)", "random.shuffle(xs)",
    "np.random.rand()", "np.random.randint(4)", "numpy.random.choice(xs)",
])
def test_global_rng_fires(expr):
    f = lint(f"""
        import random
        import numpy as np
        import numpy
        def draw(xs):
            return {expr}
    """)
    assert rules_of(f) == ["global-rng"]


@pytest.mark.parametrize("expr", [
    "np.random.default_rng(seed)",        # constructing a generator is fine
    "np.random.SeedSequence(seed)",
    "random.Random(seed)",
    "env.rng('stream').uniform(0, 1)",    # the sanctioned named stream
])
def test_seeded_rng_is_clean(expr):
    assert lint(f"""
        import random
        import numpy as np
        def draw(env, seed):
            return {expr}
    """) == []


# -- set-iteration ------------------------------------------------------------

def test_set_iteration_for_loop_fires():
    f = lint("""
        def sweep(pending: set):
            for wid in pending:
                print(wid)
    """)
    assert rules_of(f) == ["set-iteration"]


def test_set_iteration_sorted_is_clean():
    assert lint("""
        def sweep(pending: set):
            for wid in sorted(pending):
                print(wid)
    """) == []


def test_set_iteration_tracks_assignments_and_attrs():
    # local ``= set()`` and module-wide attribute facts both taint
    f = lint("""
        class Shard:
            def __init__(self):
                self.sandbox_ids = set()

        def drain(shard, ids):
            live = set()
            for x in live:
                pass
            for s in shard.sandbox_ids:
                pass
    """)
    assert rules_of(f) == ["set-iteration", "set-iteration"]


def test_set_iteration_dataclass_field_fires():
    # class-level ``field(default_factory=set)`` is an attribute fact
    f = lint("""
        from dataclasses import dataclass, field

        @dataclass
        class Slice:
            sandbox_ids: set = field(default_factory=set)

        def pick_victims(sl):
            return [s for s in sl.sandbox_ids if s > 0]
    """)
    assert rules_of(f) == ["set-iteration"]


def test_set_pop_fires():
    f = lint("""
        def take(pending: set):
            return pending.pop()
    """)
    assert rules_of(f) == ["set-iteration"]
    assert "arbitrary" in f[0].message


def test_order_insensitive_sinks_are_clean():
    # feeding a set comprehension into len/any/sorted cannot leak hash order
    assert lint("""
        def stats(pending: set):
            n = len([x for x in pending])
            hot = any(x > 3 for x in pending)
            order = sorted(x for x in pending)
            count = sum(1 for x in pending)
            return n, hot, order, count
    """) == []


def test_order_sensitive_sum_fires():
    # float accumulation order changes the rounded result — not exempt
    f = lint("""
        def total(loads: set):
            return sum(x * 1.5 for x in loads)
    """)
    assert rules_of(f) == ["set-iteration"]


# -- dict-iteration -----------------------------------------------------------

def test_dict_iteration_fires_on_order_sensitive_path():
    f = lint("""
        def pick_victim(self):
            for name in self.table.keys():
                return name
    """)
    assert rules_of(f) == ["dict-iteration"]


def test_dict_iteration_ignores_order_free_functions():
    # same shape, but the enclosing function name is not on a
    # scheduling/placement path — lexically out of scope for this rule
    assert lint("""
        def snapshot(self):
            for name in self.table.keys():
                yield name
    """) == []


# -- lock-order ---------------------------------------------------------------

def test_lock_order_unsorted_pair_fires():
    f = lint("""
        def quiesce(self):
            yield self.src.scale_lock.acquire()
            yield self.dst.scale_lock.acquire()
    """)
    assert rules_of(f) == ["lock-order"]


def test_lock_order_id_sorted_pair_is_clean():
    # the quiesce discipline: sort the shard pair by unique id first
    assert lint("""
        def quiesce(self, src, dst):
            first, second = sorted((src, dst), key=lambda s: s.shard_id)
            yield first.scale_lock.acquire()
            yield second.scale_lock.acquire()
            second.scale_lock.release()
            first.scale_lock.release()
    """) == []


# -- held-lock-timeout --------------------------------------------------------

def test_held_lock_timeout_fires():
    f = lint("""
        def boot(self):
            yield self.kernel_lock.acquire()
            yield self.env.timeout(0.1)
            self.kernel_lock.release()
    """)
    assert rules_of(f) == ["held-lock-timeout"]


def test_release_before_timeout_is_clean():
    assert lint("""
        def boot(self):
            yield self.kernel_lock.acquire()
            self.kernel_lock.release()
            yield self.env.timeout(0.1)
    """) == []


def test_held_lock_timeout_survives_loop_break():
    # the _create_sandbox shape: acquire inside a loop, break while holding,
    # then sleep — the scanner must carry the break-state out of the loop
    f = lint("""
        def create(self):
            while True:
                yield self.scale_lock.acquire()
                break
            yield self.env.timeout(0.1)
            self.scale_lock.release()
    """)
    assert rules_of(f) == ["held-lock-timeout"]


# -- suppressions -------------------------------------------------------------

def test_trailing_suppression_covers_own_line():
    assert lint("""
        def shard_of(name, n):
            return hash(name) % n  # simlint: ok(builtin-hash): test fixture
    """) == []


def test_standalone_suppression_covers_next_line():
    assert lint("""
        def boot(self):
            yield self.kernel_lock.acquire()
            # simlint: ok(held-lock-timeout): modeled hold, released below
            yield self.env.timeout(0.1)
            self.kernel_lock.release()
    """) == []


def test_suppression_is_rule_specific():
    # a suppression for a different rule does not silence the finding
    f = lint("""
        def shard_of(name, n):
            return hash(name) % n  # simlint: ok(wall-clock): wrong rule
    """)
    assert sorted(rules_of(f)) == ["builtin-hash", "stale-suppression"]


def test_stale_suppression_flagged():
    f = lint("""
        def shard_of(name, n):
            return (name, n)  # simlint: ok(builtin-hash): nothing here
    """)
    assert rules_of(f) == ["stale-suppression"]
    assert "matches no finding" in f[0].message


def test_unknown_rule_name_flagged():
    f = lint("""
        def shard_of(name, n):
            return hash(name) % n  # simlint: ok(no-such-rule): typo
    """)
    assert "stale-suppression" in rules_of(f)
    assert any("unknown rule" in x.message for x in f)


# -- the tree itself ----------------------------------------------------------

def test_checked_tree_is_clean():
    """The acceptance gate: zero findings on the paths CI lints. Any new
    finding here means either fix the code or add a justified suppression."""
    paths = [os.path.join(REPO, p) for p in DEFAULT_PATHS]
    findings = lint_paths(paths)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_finding_str_format():
    f = Finding("a/b.py", 7, "builtin-hash", "msg")
    assert str(f) == "a/b.py:7: [builtin-hash] msg"


def test_all_rules_registered():
    assert set(RULES) == {"builtin-hash", "wall-clock", "global-rng",
                          "set-iteration", "lock-order"}
