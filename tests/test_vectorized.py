"""Decision-identity guards for the vectorized model paths (PR 8).

The vectorized fast paths (array-backed metric windows, cohort heartbeat
wheel, batched eviction reconcile) exist purely to push the churn grid to
50k workers — they must never change what the model *decides*. Each test
here pins one fast path against its scalar reference:

  * ``VectorWindow`` vs the deque ``ConcurrencyWindow`` on randomized
    streams — same lengths, same evictions, averages equal to float
    round-off, and (the part that matters) identical ``desired()``
    decisions through the full autoscaler state machine.
  * the cohort heartbeat wheel vs the exact per-worker wheel — same
    creations, no false evictions, and a dead worker still evicted
    promptly in both modes.
  * batched eviction reconcile vs the legacy all-functions sweep on an
    eviction storm — same replacement creations, same final per-function
    replica counts.

These run in the CI sanitize subset: they are cheap, seed-deterministic,
and fail loudly if a fast path drifts from its reference.
"""
import math

import numpy as np

from repro.core import Cluster, Function, ScalingConfig
from repro.core.autoscaler import (ConcurrencyWindow, FunctionAutoscalerState,
                                   VectorWindow)
from repro.simcore import Environment


# -- VectorWindow vs deque reference ------------------------------------------

def _random_stream(rng, n, horizon):
    """Monotone non-decreasing times (DES clock) with occasional bursts of
    identical timestamps and gaps larger than the horizon (full eviction)."""
    t = 0.0
    out = []
    for _ in range(n):
        r = rng.random()
        if r < 0.15:
            dt = 0.0                       # burst: same-instant samples
        elif r < 0.25:
            dt = horizon * (1.0 + rng.random())   # gap: evicts everything
        else:
            dt = rng.random() * horizon / 7.0
        t += dt
        out.append((t, rng.random() * 40.0))
    return out


def test_vector_window_matches_deque_reference():
    rng = np.random.default_rng(7)
    for trial in range(40):
        horizon = float(rng.choice([0.5, 6.0, 60.0]))
        ref = ConcurrencyWindow(horizon)
        vec = VectorWindow(horizon)
        for t, v in _random_stream(rng, 400, horizon):
            ref.record(t, v)
            vec.record(t, v)
            assert len(vec) == len(ref.values), \
                f"trial {trial}: eviction drift at t={t}"
            ra, va = ref.average(t), vec.average(t)
            assert math.isclose(ra, va, rel_tol=1e-9, abs_tol=1e-12), \
                f"trial {trial}: average drift {ra} vs {va}"
            assert ref.max(t) == vec.max(t)


def test_vector_window_eviction_boundary():
    """A sample exactly ``horizon`` old stays (deque keeps ``times[0] ==
    cut``); one epsilon older goes. Both implementations must agree on the
    boundary or window populations drift over long runs."""
    for win in (ConcurrencyWindow(10.0), VectorWindow(10.0)):
        win.record(0.0, 5.0)
        win.record(10.0, 7.0)              # cut == 0.0: first sample stays
        assert win.average(10.0) == 6.0
        win.record(10.0 + 1e-9, 7.0)       # cut > 0.0: first sample evicted
        assert win.average(10.0 + 1e-9) == 7.0


def test_vector_window_growth_and_compaction():
    """Push far past the initial capacity with interleaved full evictions so
    compaction, doubling, and the ring indices all get exercised."""
    ref = ConcurrencyWindow(1.0)
    vec = VectorWindow(1.0)
    t = 0.0
    for i in range(5000):
        t += 0.001 if i % 997 else 5.0     # periodic full eviction
        v = float(i % 13)
        ref.record(t, v)
        vec.record(t, v)
    assert len(vec) == len(ref.values)
    assert math.isclose(ref.average(t), vec.average(t), rel_tol=1e-9)


def test_autoscaler_decision_identity_on_random_streams():
    """The whole point: the autoscaler consumes windows only through
    ``desired()``. Feed both variants one identical randomized metric
    stream and assert every decision — and the panic/zero state machines
    behind them — stays identical."""
    rng = np.random.default_rng(2024)
    for trial in range(10):
        scaling = ScalingConfig(stable_window=6.0, panic_window=0.6,
                                scale_to_zero_grace=2.0,
                                target_concurrency=float(rng.integers(1, 5)),
                                max_scale=int(rng.integers(8, 200)))
        a = FunctionAutoscalerState(scaling, vectorized=False)
        b = FunctionAutoscalerState(scaling, vectorized=True)
        t = 0.0
        mismatches = 0
        for step in range(2000):
            t += float(rng.random()) * 0.5
            conc = float(rng.random() * 30.0) if rng.random() > 0.2 else 0.0
            a.record_metric(t, conc)
            b.record_metric(t, conc)
            ready = int(rng.integers(0, 24))
            da, db = a.desired(t, ready), b.desired(t, ready)
            if da != db:
                mismatches += 1
            assert (a.in_panic_since is None) == (b.in_panic_since is None)
            assert (a.zero_since is None) == (b.zero_since is None)
        assert mismatches == 0, \
            f"trial {trial}: {mismatches} decision mismatches"


# -- cohort heartbeat wheel vs exact wheel ------------------------------------

def _run_hb_cell(quantum, kill_wid=None, seed=11):
    env = Environment(seed=seed)
    cl = Cluster(env, n_workers=24, runtime="firecracker",
                 hb_cohort_quantum=quantum)
    cl.start()
    leader = cl.control_plane_leader()
    names = [f"f{i}" for i in range(6)]
    for n in names:
        leader.install_function(Function(
            name=n, image_url="i", port=80,
            scaling=ScalingConfig(stable_window=30.0,
                                  scale_to_zero_grace=30.0)))
        for dp in cl.data_planes:
            dp.sync_functions([n])

    def driver(env):
        for _ in range(4):
            for n in names:
                for _ in range(3):
                    cl.invoke(n, exec_time=0.05)
            yield env.timeout(1.0)

    env.process(driver(env), name="hb-driver")
    env.run(until=6.0)
    evicted_at = None
    if kill_wid is not None:
        cl.fail_worker_daemon(kill_wid)
        t_kill = env.now
        env.run(until=t_kill + 10.0)
        for t, k, d in cl.collector.events:
            if k == "worker-evicted" and t >= t_kill:
                evicted_at = t - t_kill
                break
    else:
        env.run(until=12.0)
    evictions = sum(1 for _, k, _ in cl.collector.events
                    if k == "worker-evicted")
    return (cl.collector.sandbox_creations, evictions,
            len(cl.collector.completed), env.events_processed, evicted_at)


def test_cohort_heartbeats_no_false_evictions():
    """Cohort mode snaps first beats onto the shared grid and batches
    same-deadline beats into one lock hold — it must neither evict a live
    worker nor change what the cluster builds, and it must do so in FEWER
    heap events than per-worker exact beats."""
    from repro.core.costmodel import DEFAULT_COSTS
    q = DEFAULT_COSTS.dirigent.worker_hb_cohort_quantum
    creations_c, evictions_c, done_c, events_c, _ = _run_hb_cell(q)
    creations_e, evictions_e, done_e, events_e, _ = _run_hb_cell(None)
    assert evictions_c == 0 and evictions_e == 0
    assert creations_c == creations_e
    assert done_c == done_e
    assert events_c < events_e, (
        f"cohort wheel stopped saving events: {events_c} vs {events_e}")


def test_cohort_heartbeats_still_evict_dead_workers():
    """Batching beats must not mask death: a worker whose daemon dies stops
    appearing in the cohort's live set, its ``last_hb`` goes stale, and the
    health loop evicts it within the same timeout bound as exact mode."""
    from repro.core.costmodel import DEFAULT_COSTS
    c = DEFAULT_COSTS.dirigent
    q = c.worker_hb_cohort_quantum
    *_, evicted_c = _run_hb_cell(q, kill_wid=3)
    *_, evicted_e = _run_hb_cell(None, kill_wid=3)
    assert evicted_c is not None and evicted_e is not None
    # both modes detect within timeout + one health-check period + slack
    bound = c.worker_heartbeat_timeout + 2.0 * c.worker_heartbeat_period + 1.0
    assert evicted_c <= bound
    assert evicted_e <= bound
    # cohort quantization shifts beat instants by at most one quantum, so
    # detection time may differ only marginally between modes
    assert abs(evicted_c - evicted_e) <= 2.0 * c.worker_heartbeat_period + q


# -- batched eviction reconcile vs legacy sweep -------------------------------

def _run_eviction_storm(batched, seed=5):
    env = Environment(seed=seed)
    cl = Cluster(env, n_workers=16, runtime="firecracker", cp_shards=4,
                 cp_batched_eviction=batched)
    cl.start()
    leader = cl.control_plane_leader()
    names = [f"f{i}" for i in range(8)]
    for n in names:
        leader.install_function(Function(
            name=n, image_url="i", port=80,
            scaling=ScalingConfig(stable_window=60.0,
                                  scale_to_zero_grace=60.0)))
        for dp in cl.data_planes:
            dp.sync_functions([n])
    for n in names:
        for _ in range(4):
            cl.invoke(n, exec_time=40.0)
    env.run(until=8.0)
    # the storm: three workers die at once, shredding replicas across every
    # function; the health loop notices and reconciles replacements
    for wid in (1, 5, 9):
        cl.fail_worker_daemon(wid)
    env.run(until=30.0)
    per_fn = {n: len(leader.functions[n].sandboxes) for n in names}
    placed_on_dead = sum(
        1 for n in names for sb in leader.functions[n].sandboxes.values()
        if sb.worker_id in (1, 5, 9))
    return (per_fn, cl.collector.sandbox_creations,
            sum(1 for _, k, _ in cl.collector.events if k == "worker-evicted"),
            placed_on_dead)


def test_batched_eviction_matches_legacy_sweep():
    """The batched path reconciles only the functions that actually lost a
    replica (unique, in eviction-scan order) instead of sweeping every
    function on the shard. Replacement outcomes must be identical: same
    evictions, same replacement creations, same final replica counts, and
    nothing left placed on a dead worker."""
    per_fn_b, creations_b, evictions_b, dead_b = _run_eviction_storm(True)
    per_fn_l, creations_l, evictions_l, dead_l = _run_eviction_storm(False)
    assert evictions_b == evictions_l == 3
    assert dead_b == dead_l == 0
    assert per_fn_b == per_fn_l
    assert creations_b == creations_l
