"""Data-plane behaviour: port pool, endpoint churn, connection reuse,
crash accounting (paper §3.3 warm path, C5 port ceiling, §5.4 DP failover).

The DP had no dedicated test module before the multi-DP work — its
behaviour was pinned only incidentally through cluster/fault tests. These
tests cover the invoke-path resources directly: the ephemeral-port pool
(exhaustion blocks, TIME_WAIT hold timing, pool size = ``dp_port_pool``),
dead-endpoint report/evict/reconcile, LB-policy selection under endpoint
churn, the keep-alive connection pool (``dp_conn_reuse``: hit/miss/expiry
and exact accounting vs the no-reuse golden), and the crash-accounting
regressions the multi-DP work fixed (a recovered DP's port pool must start
empty; a crashed request must be recorded exactly once).
"""
import dataclasses

import pytest

from repro.core import Cluster, Function, ScalingConfig
from repro.core.costmodel import CostModel, DEFAULT_COSTS
from repro.core.policies import LB_POLICIES
from repro.simcore import Environment


def make_cluster(seed=5, dirigent_overrides=None, **kw):
    env = Environment(seed=seed)
    costs = None
    if dirigent_overrides:
        costs = CostModel(dirigent=dataclasses.replace(
            DEFAULT_COSTS.dirigent, **dirigent_overrides))
    kw.setdefault("n_workers", 8)
    cl = Cluster(env, costs=costs, **kw)
    cl.start()
    return env, cl


PINNED = ScalingConfig(stable_window=300, scale_to_zero_grace=300)


# -- port pool ----------------------------------------------------------------

def test_port_pool_size_matches_knob():
    _, cl = make_cluster()
    assert all(dp._ports.capacity == DEFAULT_COSTS.dirigent.dp_port_pool
               for dp in cl.data_planes)
    _, cl = make_cluster(dirigent_overrides={"dp_port_pool": 7})
    assert all(dp._ports.capacity == 7 for dp in cl.data_planes)


def test_port_exhaustion_blocks_until_time_wait_release():
    """With a 1-port pool, a second request must wait out the first one's
    full ``dp_port_hold`` TIME_WAIT before its connection can open."""
    hold = 5.0
    env, cl = make_cluster(
        n_data_planes=1,
        dirigent_overrides={"dp_port_pool": 1, "dp_port_hold": hold})
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=PINNED))
    first = cl.invoke("f", exec_time=0.01)
    env.run(until=2.0)
    assert not first.failed
    dp = cl.data_planes[0]
    # the connection closed but its port is riding TIME_WAIT
    assert dp.ports_in_use == 1
    second = cl.invoke("f", exec_time=0.01)
    env.run(until=2.5)
    assert second.t_done < 0, "second request should be port-blocked"
    env.run(until=20.0)
    assert not second.failed
    # execution could not start before the first request's port freed at
    # (its proxy end ≈ t_done) + hold
    assert second.t_exec_start >= first.t_done + hold - 1e-9
    assert second.t_done < first.t_done + hold + 1.0
    env.run(until=second.t_done + hold + 1.0)
    assert dp.ports_in_use == 0


# -- dead-endpoint report / evict / reconcile ---------------------------------

def test_dead_endpoint_evicted_and_reconciled():
    """A dispatch into a sandbox that died behind the CP's back fails once,
    evicts the endpoint from the DP table, and the CP reconciles capacity —
    the next request lands on a replacement, not the corpse."""
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=PINNED))
    first = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    assert not first.failed
    leader = cl.control_plane_leader()
    sb = next(iter(leader.functions["f"].sandboxes.values()))
    cl.workers[sb.worker_id].sandboxes.pop(sb.sandbox_id)
    bad = cl.invoke("f", exec_time=0.01)
    env.run(until=10.0)
    assert bad.failed
    assert all(sb.sandbox_id not in dp.tables["f"].endpoints
               for dp in cl.data_planes)
    assert sb.sandbox_id not in leader.functions["f"].sandboxes
    later = cl.invoke("f", exec_time=0.01)
    env.run(until=25.0)
    assert not later.failed


# -- LB policy selection under endpoint churn ---------------------------------

@pytest.mark.parametrize("policy", sorted(LB_POLICIES))
def test_lb_policy_serves_through_endpoint_churn(policy):
    """Every LB policy keeps routing to live endpoints while endpoints are
    drained and removed under it mid-traffic."""
    env, cl = make_cluster(lb_policy=policy, n_workers=6)
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=PINNED))
    warm = [cl.invoke("f", exec_time=0.5) for _ in range(3)]
    env.run(until=10.0)
    assert all(not i.failed for i in warm)
    # every DP caches the endpoints; traffic hash-steers to exactly one
    dp = cl._steer("f")
    n_eps = len(dp.tables["f"].endpoints)
    assert n_eps >= 2
    # churn: drain-remove one endpoint while requests hold its slot
    inflight = [cl.invoke("f", exec_time=1.0) for _ in range(n_eps)]
    env.run(until=env.now + 0.2)
    victim = next(ep for ep in dp.tables["f"].endpoints.values()
                  if ep.in_use > 0)
    dp.remove_endpoint("f", victim.sandbox.sandbox_id, drain=True)
    # drained, not yanked: the in-flight request on it must still finish
    assert victim.draining
    env.run(until=env.now + 5.0)
    assert all(not i.failed for i in inflight)
    # reaped at last release, and traffic keeps flowing on the survivors
    assert victim.sandbox.sandbox_id not in dp.tables["f"].endpoints
    after = [cl.invoke("f", exec_time=0.05) for _ in range(4)]
    env.run(until=env.now + 5.0)
    assert all(not i.failed for i in after)


# -- connection reuse (dp_conn_reuse) -----------------------------------------

def test_conn_reuse_hit_miss_and_port_accounting():
    env, cl = make_cluster(n_data_planes=1, dp_conn_reuse=True)
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=PINNED))
    first = cl.invoke("f", exec_time=0.01)
    env.run(until=3.0)
    dp = cl.data_planes[0]
    assert not first.failed
    assert (dp.conn_misses, dp.conn_hits) == (1, 0)
    assert dp.conn_open == 1 and dp.time_wait_ports == 0
    # the conn is parked, holding its port — no TIME_WAIT burn per request
    assert dp.ports_in_use == 1
    for _ in range(3):
        inv = cl.invoke("f", exec_time=0.01)
        env.run(until=env.now + 1.0)
        assert not inv.failed
    assert (dp.conn_misses, dp.conn_hits) == (1, 3)
    assert dp.ports_in_use == dp.conn_open + dp.time_wait_ports == 1


def test_conn_idle_expiry_pays_time_wait():
    """An idle-timeout close is DP-initiated, so the port rides TIME_WAIT
    for ``dp_port_hold`` before returning to the pool."""
    idle, hold = 2.0, 5.0
    env, cl = make_cluster(
        n_data_planes=1, dp_conn_reuse=True, dp_conn_idle_timeout=idle,
        dirigent_overrides={"dp_port_hold": hold})
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=PINNED))
    inv = cl.invoke("f", exec_time=0.01)
    env.run(until=1.0)
    assert not inv.failed
    dp = cl.data_planes[0]
    t_parked = inv.t_done
    env.run(until=t_parked + idle + 0.1)
    assert dp.conn_expired == 1 and dp.conn_open == 0
    assert dp.time_wait_ports == 1 and dp.ports_in_use == 1
    env.run(until=t_parked + idle + hold + 0.1)
    assert dp.time_wait_ports == 0 and dp.ports_in_use == 0


def test_endpoint_teardown_closes_idle_conns_without_time_wait():
    """An endpoint teardown is a server-initiated close: the DP is the
    passive closer, so parked conns free their ports immediately."""
    env, cl = make_cluster(n_data_planes=1, dp_conn_reuse=True)
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=PINNED))
    inv = cl.invoke("f", exec_time=0.01)
    env.run(until=3.0)
    assert not inv.failed
    dp = cl.data_planes[0]
    assert dp.conn_open == 1 and dp.ports_in_use == 1
    sid = next(iter(dp.tables["f"].endpoints))
    dp.remove_endpoint("f", sid, drain=False)
    assert dp.conn_open == 0
    assert dp.time_wait_ports == 0 and dp.ports_in_use == 0


def test_conn_reuse_latencies_exact_vs_noreuse_golden():
    """In an uncontended pool the reuse path must be *time*-identical to the
    no-reuse path per invocation (it only removes port TIME_WAIT churn, it
    models no new latency), while processing strictly fewer events."""
    def run(reuse):
        env, cl = make_cluster(seed=11, n_data_planes=1, dp_conn_reuse=reuse)
        cl.register_sync(Function(name="f", image_url="i", port=80,
                                  scaling=PINNED))
        invs = []
        for _ in range(6):
            invs.append(cl.invoke("f", exec_time=0.02))
            env.run(until=env.now + 1.0)
        env.run(until=env.now + 5.0)
        assert all(not i.failed for i in invs)
        return [i.e2e_latency for i in invs], env.events_processed

    lat_off, events_off = run(False)
    lat_on, events_on = run(True)
    assert lat_on == lat_off
    assert events_on < events_off


# -- crash accounting (regressions pinned by the multi-DP work) ---------------

def test_dp_crash_does_not_leak_ports_into_recovered_pool():
    """Regression: ports held by in-flight requests (and their TIME_WAIT
    holds) at crash time used to release into the *recovered* DP's pool,
    under-counting — or, with a fresh pool, crash a ``release without
    acquire``. The recovered DP must start at zero ports in use and absorb
    the old life's stragglers silently."""
    hold = 50.0
    env, cl = make_cluster(
        n_data_planes=1, enable_ha_sim=True,
        dirigent_overrides={"dp_port_pool": 4, "dp_port_hold": hold})
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=PINNED))
    warm = cl.invoke("f", exec_time=0.01)
    env.run(until=3.0)
    assert not warm.failed
    victim = cl.invoke("f", exec_time=2.0)   # in flight across the crash
    env.run(until=env.now + 0.5)
    dp = cl.data_planes[0]
    assert victim.inv_id in dp.inflight_requests
    t_crash = env.now
    cl.fail_data_plane(0)
    assert victim.failed and victim.failure_reason == "data plane crash"
    # recovered pool starts empty even though old TIME_WAIT holds (hold=50)
    # are still pending against the old life
    env.run(until=t_crash + 5.0)
    assert dp.alive and dp.ports_in_use == 0
    after = [cl.invoke("f", exec_time=0.01) for _ in range(4)]
    env.run(until=t_crash + 20.0)
    assert all(not i.failed for i in after)
    # run past every straggler's TIME_WAIT: old-pool releases must not
    # underflow anything (Resource raises on release-without-acquire)
    env.run(until=t_crash + 2 * hold)
    assert dp.ports_in_use == 0


def test_dp_crash_records_inflight_request_exactly_once():
    """Regression: a request in flight across a DP crash was recorded twice
    — once by ``fail()`` and again when its proxy generator unwound."""
    env, cl = make_cluster(n_data_planes=1, enable_ha_sim=True)
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=PINNED))
    warm = cl.invoke("f", exec_time=0.01)
    env.run(until=3.0)
    assert not warm.failed
    victim = cl.invoke("f", exec_time=2.0)
    env.run(until=env.now + 0.5)
    cl.fail_data_plane(0)
    env.run(until=env.now + 30.0)
    records = [i for i in cl.collector.invocations
               if i.inv_id == victim.inv_id]
    assert len(records) == 1 and records[0].failed


def test_dp_crash_closes_parked_conns_and_recovers_clean():
    env, cl = make_cluster(
        n_data_planes=1, enable_ha_sim=True, dp_conn_reuse=True,
        dp_conn_idle_timeout=4.0,
        dirigent_overrides={"dp_port_pool": 4, "dp_port_hold": 50.0})
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=PINNED))
    warm = cl.invoke("f", exec_time=0.01)
    env.run(until=3.0)
    assert not warm.failed
    dp = cl.data_planes[0]
    assert dp.conn_open == 1          # one parked keep-alive conn
    t_crash = env.now
    cl.fail_data_plane(0)
    assert dp.conn_open == 0 and dp.ports_in_use == 0
    env.run(until=t_crash + 5.0)
    assert dp.alive
    after = [cl.invoke("f", exec_time=0.01) for _ in range(4)]
    env.run(until=t_crash + 20.0)
    assert all(not i.failed for i in after)
    # stale idle-expiry timers from the old life must not touch the new
    # pool's accounting
    env.run(until=t_crash + 120.0)
    assert dp.ports_in_use == dp.conn_open + dp.time_wait_ports


# -- fn→DP-set spread under DP failure ----------------------------------------

def test_spread_hot_fn_survives_dp_failure_and_member_rejoins():
    """Fail one member of a hot function's DP-set: after the keepalived
    health check the survivors absorb the re-steer, and the recovered
    member rejoins the rotation."""
    # min_rate=1: the test's trickle of arrivals keeps the set hot, so the
    # cooldown narrow never folds it back mid-test
    env, cl = make_cluster(n_workers=12, n_data_planes=3,
                           enable_ha_sim=True, dp_spread_enabled=True,
                           dp_spread_min_rate=1.0)
    cl.register_sync(Function(name="hot", image_url="i", port=80,
                              scaling=PINNED))
    members = cl.spread_function("hot", width=3)
    assert len(members) == 3
    warm = [cl.invoke("hot", exec_time=0.05) for _ in range(6)]
    env.run(until=10.0)
    assert all(not i.failed for i in warm)
    dead = members[0]
    cl.fail_data_plane(dead)
    # past the health-check window: the dead member is out of the rotation
    env.run(until=env.now + cl.costs.lb_health_check + 0.05)
    assert dead not in cl._lb_backends
    during = [cl.invoke("hot", exec_time=0.5) for _ in range(6)]
    env.run(until=env.now + 0.2)
    # survivors absorb the re-steer round-robin: both carry in-flight load
    survivors = [cl.data_planes[d] for d in members if d != dead]
    assert all(len(dp.inflight_requests) > 0 for dp in survivors)
    env.run(until=env.now + 10.0)
    assert all(not i.failed for i in during)
    # recovery: the member is back in the rotation and takes traffic again
    assert dead in cl._lb_backends
    after = [cl.invoke("hot", exec_time=0.5) for _ in range(6)]
    env.run(until=env.now + 0.2)
    assert len(cl.data_planes[dead].inflight_requests) > 0
    env.run(until=env.now + 10.0)
    assert all(not i.failed for i in after)
