"""End-to-end Dirigent cluster behaviour (sim mode)."""
import numpy as np
import pytest

from repro.core import Cluster, Function, InvocationMode, ScalingConfig
from repro.simcore import Environment


def make_cluster(seed=1, **kw):
    env = Environment(seed=seed)
    kw.setdefault("n_workers", 8)
    cl = Cluster(env, **kw)
    cl.start()
    return env, cl


def test_cold_then_warm_invocation():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    cold = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    assert not cold.failed
    assert cold.cold
    # Firecracker snapshot regime: cold start in the tens of ms (paper §5.2.1)
    assert 0.02 < cold.scheduling_latency < 0.2
    warm = cl.invoke("f", exec_time=0.01)
    env.run(until=10.0)
    assert not warm.failed and not warm.cold
    # warm path ~1.4 ms p50 (C5)
    assert warm.scheduling_latency < 0.005


def test_no_persistent_writes_on_invocation_path():
    """The paper's core design principle: cold starts write nothing durable."""
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    writes_after_register = cl.store.write_count
    for _ in range(5):
        cl.invoke("f", exec_time=0.01)
        env.run(until=env.now + 3.0)
    assert cl.collector.sandbox_creations >= 1
    assert cl.store.write_count == writes_after_register


def test_persist_ablation_writes_on_critical_path():
    env, cl = make_cluster(persist_sandbox_state=True)
    cl.register_sync(Function(name="f", image_url="i", port=80))
    w0 = cl.store.write_count
    cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    assert cl.store.write_count > w0


def test_autoscaling_up_and_scale_to_zero():
    env, cl = make_cluster()
    cl.register_sync(Function(
        name="f", image_url="i", port=80,
        scaling=ScalingConfig(stable_window=5.0, panic_window=2.0,
                              scale_to_zero_grace=3.0)))
    # 4 concurrent long requests -> needs 4 sandboxes (concurrency target 1)
    invs = [cl.invoke("f", exec_time=2.0) for _ in range(4)]
    env.run(until=15.0)
    assert all(not i.failed for i in invs)
    assert cl.collector.sandbox_creations >= 2
    leader = cl.control_plane_leader()
    # after idle > stable_window + grace, scaled back to zero
    env.run(until=60.0)
    assert leader.functions["f"].ready_count == 0
    assert cl.collector.sandbox_teardowns >= cl.collector.sandbox_creations


def test_sandbox_concurrency_throttling():
    env, cl = make_cluster(sandbox_concurrency=2)
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(target_concurrency=2)))
    invs = [cl.invoke("f", exec_time=1.0) for _ in range(2)]
    env.run(until=10.0)
    # both fit in ONE sandbox with concurrency 2
    assert cl.collector.sandbox_creations == 1
    assert all(not i.failed for i in invs)


def test_teardown_idempotent_after_concurrent_removal():
    """Regression: tearing down a sandbox a concurrent remover (dead-sandbox
    report, eviction) already reconciled away must not release placer
    capacity a second time — phantom free capacity overcommits the node."""
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(stable_window=300,
                                                    scale_to_zero_grace=300)))
    cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    leader = cl.control_plane_leader()
    st = leader.functions["f"]
    sb = next(iter(st.sandboxes.values()))
    st.sandboxes.pop(sb.sandbox_id)        # concurrent remover got there first
    node = leader.placer.nodes[sb.worker_id]
    before = (node.cpu_used, node.mem_used)
    teardowns = cl.collector.sandbox_teardowns
    env.process(leader._teardown_sandbox(st, sb), name="late-teardown")
    env.run(until=env.now + 2.0)
    # the sandbox's own node keeps its commitment (the concurrent remover
    # owns the release); a double release would zero it out. The autoscaler
    # may meanwhile place a replacement on OTHER (less-utilized) nodes.
    assert (node.cpu_used, node.mem_used) == before
    assert cl.collector.sandbox_teardowns == teardowns


def test_async_invocation_at_least_once():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    inv = cl.invoke("f", exec_time=0.01, mode=InvocationMode.ASYNC)
    env.run(until=10.0)
    assert inv.t_done > 0 and not inv.failed
    # the durable queue entry is cleaned up after completion
    assert not cl.store.peek_prefix("asyncq/")


def test_function_hash_steering_centralizes_metrics():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    for _ in range(6):
        cl.invoke("f", exec_time=0.5)
    env.run(until=0.05)
    owners = [dp for dp in cl.data_planes
              if dp.tables.get("f") and dp.tables["f"].inflight > 0]
    assert len(owners) == 1     # all invocations of f land on one DP


def test_hedged_requests_beat_stragglers():
    """Straggler mitigation: duplicate slow requests onto another replica."""
    from repro.core.abstractions import ScalingConfig as SC
    env, cl = make_cluster(hedge_after=0.2)
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=SC(target_concurrency=1,
                                         stable_window=300,
                                         scale_to_zero_grace=300)))
    # warm up two sandboxes on two workers
    a = cl.invoke("f", exec_time=1.0)
    b = cl.invoke("f", exec_time=1.0)
    env.run(until=10.0)
    leader = cl.control_plane_leader()
    st = leader.functions["f"]
    wids = {sb.worker_id for sb in st.sandboxes.values()}
    assert len(wids) >= 2
    # make one worker a straggler (100x slower)
    slow_wid = sorted(wids)[0]
    cl.workers[slow_wid].slow_factor = 100.0
    invs = [cl.invoke("f", exec_time=0.05) for _ in range(6)]
    env.run(until=60.0)
    assert all(not i.failed for i in invs)
    dp = [d for d in cl.data_planes if d.hedged > 0]
    assert dp, "no hedges fired"
    assert dp[0].hedge_wins >= 1
    # hedged requests finish in ~hedge_after + exec, not 100x exec
    lats = sorted(i.e2e_latency for i in invs)
    assert lats[-1] < 2.0, f"straggler not mitigated: {lats}"


# -- demand-driven netcfg replenisher -----------------------------------------

def _make_daemon(env, wid=0):
    from repro.core.abstractions import WorkerNodeInfo
    from repro.core.costmodel import DirigentCosts
    from repro.core.worker import WorkerDaemon
    info = WorkerNodeInfo(worker_id=wid, name=f"w{wid}",
                          ip=(10, 0, 0, 1), port=9000)
    return WorkerDaemon(env, info, DirigentCosts())


def test_netcfg_refill_instants_match_polling_loop():
    """The demand-driven replenisher must refill at exactly the instants the
    retired 25 ms polling loop would have — same grid, same accumulated
    float-add chain — while processing far fewer events. The take plan
    includes a burst that empties the pool (fresh-cost regime) and sparse
    single takes (the common case the polling loop wasted 97% of simulator
    events idling through)."""

    # (delay-before, takes) — deliberately off-grid times and an over-drain
    plan = [(0.003, 3), (0.0401, 70), (0.35, 1), (1.003, 10), (2.5001, 2)]

    def run(demand_driven):
        env = Environment(seed=0)
        d = _make_daemon(env)
        refills = []
        orig_put = d._netcfg_pool.put

        def spy_put(item):
            refills.append(env.now)
            orig_put(item)

        d._netcfg_pool.put = spy_put
        if not demand_driven:
            # disarm the demand path and run the reference polling loop
            # (verbatim the pre-PR 4 _netcfg_replenisher body)
            d._netcfg_refill_pending = True

            def poller(env):
                while True:
                    yield env.timeout(d.costs.netcfg_replenish_period)
                    if d.node_alive and \
                            len(d._netcfg_pool) < d.costs.netcfg_pool_size:
                        d._netcfg_pool.put(object())

            env.process(poller(env), name="poller")

        def taker(env):
            for delay, n in plan:
                yield env.timeout(delay)
                for _ in range(n):
                    if len(d._netcfg_pool):
                        d._netcfg_pool.items.popleft()
                        d._arm_netcfg_refill()

        env.process(taker(env), name="taker")
        env.run(until=6.0)
        return refills, len(d._netcfg_pool), env.events_processed

    refills_d, pool_d, events_d = run(demand_driven=True)
    refills_p, pool_p, events_p = run(demand_driven=False)
    assert refills_d, "plan never drove the pool below target"
    assert refills_d == refills_p          # bit-identical refill instants
    assert pool_d == pool_p
    assert events_d < events_p / 2         # ...at a fraction of the events
    # (the gap is this small only because the plan keeps the pool draining;
    # an idle pool costs the demand path zero events per tick forever)


def test_netcfg_refill_stops_when_pool_full_and_on_node_death():
    env = Environment(seed=1)
    d = _make_daemon(env)
    size = d.costs.netcfg_pool_size
    d._netcfg_pool.items.popleft()
    d._arm_netcfg_refill()
    env.run(until=1.0)
    assert len(d._netcfg_pool) == size     # refilled exactly back to target
    assert not d._netcfg_refill_pending    # and went quiet
    ev0 = env.events_processed
    env.run(until=5.0)
    assert env.events_processed == ev0     # a full pool costs zero events
    # a dead node stops refilling (and never re-arms)
    d._netcfg_pool.items.popleft()
    d._arm_netcfg_refill()
    d.fail_node()
    env.run(until=10.0)
    assert len(d._netcfg_pool) == size - 1
    assert not d._netcfg_refill_pending


# -- per-shard heartbeat wheel -------------------------------------------------

def test_heartbeat_wheel_beats_at_per_process_instants():
    """Beat instants are the per-worker ``(t_reg + phase) + k*period`` chains
    of the retired one-process-per-worker model: the phase comes from the
    same ``hb-{wid}`` stream, and consecutive beats differ by exactly one
    period in accumulated float arithmetic."""
    env, cl = make_cluster(seed=13)
    cl.register_sync(Function(name="g", image_url="i", port=80))
    env.run(until=4.0)
    leader = cl.control_plane_leader()
    period = cl.costs.worker_heartbeat_period
    last = dict(leader.worker_last_hb)
    env.run(until=4.0 + period)
    for wid, t in leader.worker_last_hb.items():
        assert t == last[wid] + period     # the worker's own float-add chain
    # pre-wheel golden (recorded from the per-process model at this seed):
    # worker 3's last beat before t=4.0
    assert last[3] == 3.8565964981624683


def test_heartbeat_wheel_eviction_time_matches_per_process_model():
    """A worker that stops beating is evicted at the very sim time the
    per-worker-process model evicted it (golden recorded pre-wheel)."""
    env, cl = make_cluster(seed=13)
    cl.register_sync(Function(name="g", image_url="i", port=80))
    invs = [cl.invoke("g", exec_time=0.01) for _ in range(3)]
    env.run(until=4.0)
    leader = cl.control_plane_leader()
    assert leader.worker_last_hb[3] == 3.8565964981624683
    cl.fail_worker_daemon(3)
    env.run(until=12.0)
    evicts = [(t, d) for t, k, d in cl.collector.events
              if k == "worker-evicted"]
    assert evicts == [(5.5, 3)]
    assert all(not i.failed for i in invs)
    # recovery: the daemon comes back, resumes beating on its old schedule,
    # and is not evicted again
    cl.recover_worker_daemon(3)
    env.run(until=20.0)
    assert 3 in leader.worker_last_hb
    assert len([1 for t, k, d in cl.collector.events
                if k == "worker-evicted"]) == 1


def test_heartbeat_wheel_one_process_per_shard():
    """The wheel replaces O(n_workers) heartbeat processes with one driver
    per CP shard, beating every worker in wid%shards order on ties."""
    env, cl = make_cluster(seed=3, n_workers=12, cp_shards=4)
    assert len(cl._hb_wheels) == 4
    for k, wheel in enumerate(cl._hb_wheels):
        assert wheel.proc is not None and wheel.proc.is_alive
        wids = sorted(w for _, w in wheel.heap)
        assert wids == [w for w in range(12) if w % 4 == k]
    env.run(until=3.0)
    leader = cl.control_plane_leader()
    assert len(leader.worker_last_hb) == 12
    assert all(t > 0 for t in leader.worker_last_hb.values())
