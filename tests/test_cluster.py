"""End-to-end Dirigent cluster behaviour (sim mode)."""
import numpy as np
import pytest

from repro.core import Cluster, Function, InvocationMode, ScalingConfig
from repro.simcore import Environment


def make_cluster(seed=1, **kw):
    env = Environment(seed=seed)
    kw.setdefault("n_workers", 8)
    cl = Cluster(env, **kw)
    cl.start()
    return env, cl


def test_cold_then_warm_invocation():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    cold = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    assert not cold.failed
    assert cold.cold
    # Firecracker snapshot regime: cold start in the tens of ms (paper §5.2.1)
    assert 0.02 < cold.scheduling_latency < 0.2
    warm = cl.invoke("f", exec_time=0.01)
    env.run(until=10.0)
    assert not warm.failed and not warm.cold
    # warm path ~1.4 ms p50 (C5)
    assert warm.scheduling_latency < 0.005


def test_no_persistent_writes_on_invocation_path():
    """The paper's core design principle: cold starts write nothing durable."""
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    writes_after_register = cl.store.write_count
    for _ in range(5):
        cl.invoke("f", exec_time=0.01)
        env.run(until=env.now + 3.0)
    assert cl.collector.sandbox_creations >= 1
    assert cl.store.write_count == writes_after_register


def test_persist_ablation_writes_on_critical_path():
    env, cl = make_cluster(persist_sandbox_state=True)
    cl.register_sync(Function(name="f", image_url="i", port=80))
    w0 = cl.store.write_count
    cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    assert cl.store.write_count > w0


def test_autoscaling_up_and_scale_to_zero():
    env, cl = make_cluster()
    cl.register_sync(Function(
        name="f", image_url="i", port=80,
        scaling=ScalingConfig(stable_window=5.0, panic_window=2.0,
                              scale_to_zero_grace=3.0)))
    # 4 concurrent long requests -> needs 4 sandboxes (concurrency target 1)
    invs = [cl.invoke("f", exec_time=2.0) for _ in range(4)]
    env.run(until=15.0)
    assert all(not i.failed for i in invs)
    assert cl.collector.sandbox_creations >= 2
    leader = cl.control_plane_leader()
    # after idle > stable_window + grace, scaled back to zero
    env.run(until=60.0)
    assert leader.functions["f"].ready_count == 0
    assert cl.collector.sandbox_teardowns >= cl.collector.sandbox_creations


def test_sandbox_concurrency_throttling():
    env, cl = make_cluster(sandbox_concurrency=2)
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(target_concurrency=2)))
    invs = [cl.invoke("f", exec_time=1.0) for _ in range(2)]
    env.run(until=10.0)
    # both fit in ONE sandbox with concurrency 2
    assert cl.collector.sandbox_creations == 1
    assert all(not i.failed for i in invs)


def test_teardown_idempotent_after_concurrent_removal():
    """Regression: tearing down a sandbox a concurrent remover (dead-sandbox
    report, eviction) already reconciled away must not release placer
    capacity a second time — phantom free capacity overcommits the node."""
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(stable_window=300,
                                                    scale_to_zero_grace=300)))
    cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    leader = cl.control_plane_leader()
    st = leader.functions["f"]
    sb = next(iter(st.sandboxes.values()))
    st.sandboxes.pop(sb.sandbox_id)        # concurrent remover got there first
    node = leader.placer.nodes[sb.worker_id]
    before = (node.cpu_used, node.mem_used)
    teardowns = cl.collector.sandbox_teardowns
    env.process(leader._teardown_sandbox(st, sb), name="late-teardown")
    env.run(until=env.now + 2.0)
    # the sandbox's own node keeps its commitment (the concurrent remover
    # owns the release); a double release would zero it out. The autoscaler
    # may meanwhile place a replacement on OTHER (less-utilized) nodes.
    assert (node.cpu_used, node.mem_used) == before
    assert cl.collector.sandbox_teardowns == teardowns


def test_async_invocation_at_least_once():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    inv = cl.invoke("f", exec_time=0.01, mode=InvocationMode.ASYNC)
    env.run(until=10.0)
    assert inv.t_done > 0 and not inv.failed
    # the durable queue entry is cleaned up after completion
    assert not cl.store.peek_prefix("asyncq/")


def test_function_hash_steering_centralizes_metrics():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    for _ in range(6):
        cl.invoke("f", exec_time=0.5)
    env.run(until=0.05)
    owners = [dp for dp in cl.data_planes
              if dp.tables.get("f") and dp.tables["f"].inflight > 0]
    assert len(owners) == 1     # all invocations of f land on one DP


def test_hedged_requests_beat_stragglers():
    """Straggler mitigation: duplicate slow requests onto another replica."""
    from repro.core.abstractions import ScalingConfig as SC
    env, cl = make_cluster(hedge_after=0.2)
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=SC(target_concurrency=1,
                                         stable_window=300,
                                         scale_to_zero_grace=300)))
    # warm up two sandboxes on two workers
    a = cl.invoke("f", exec_time=1.0)
    b = cl.invoke("f", exec_time=1.0)
    env.run(until=10.0)
    leader = cl.control_plane_leader()
    st = leader.functions["f"]
    wids = {sb.worker_id for sb in st.sandboxes.values()}
    assert len(wids) >= 2
    # make one worker a straggler (100x slower)
    slow_wid = sorted(wids)[0]
    cl.workers[slow_wid].slow_factor = 100.0
    invs = [cl.invoke("f", exec_time=0.05) for _ in range(6)]
    env.run(until=60.0)
    assert all(not i.failed for i in invs)
    dp = [d for d in cl.data_planes if d.hedged > 0]
    assert dp, "no hedges fired"
    assert dp[0].hedge_wins >= 1
    # hedged requests finish in ~hedge_after + exec, not 100x exec
    lats = sorted(i.e2e_latency for i in invs)
    assert lats[-1] < 2.0, f"straggler not mitigated: {lats}"
