"""Serving engine tests: replica generation + continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.engine import ContinuousBatcher, Replica, sample_token


@pytest.fixture(scope="module")
def replica():
    cfg = get_config("smollm-360m").reduced(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab=128)
    return Replica(cfg, max_seq=64)


def test_generate_deterministic(replica):
    a = replica.generate([1, 2, 3], max_new_tokens=6)
    b = replica.generate([1, 2, 3], max_new_tokens=6)
    assert a == b
    assert len(a) == 6
    assert all(0 <= t < 128 for t in a)


def test_batcher_matches_single(replica):
    cb = ContinuousBatcher(replica, max_slots=4)
    prompts = [[1, 2, 3, 4], [5, 6], [7, 8, 9]]
    rids = [cb.add_request(p, max_new=5) for p in prompts]
    cb.run_until_done()
    for p, rid in zip(prompts, rids):
        assert cb.finished[rid] == replica.generate(p, max_new_tokens=5)


def test_batcher_midflight_admission(replica):
    cb = ContinuousBatcher(replica, max_slots=2)
    r1 = cb.add_request([1, 2, 3], max_new=6)
    for _ in range(4):
        cb.step()
    r2 = cb.add_request([4, 5], max_new=4)
    cb.run_until_done()
    assert cb.finished[r1] == replica.generate([1, 2, 3], max_new_tokens=6)
    assert cb.finished[r2] == replica.generate([4, 5], max_new_tokens=4)


def test_batcher_throttles_at_capacity(replica):
    cb = ContinuousBatcher(replica, max_slots=2)
    cb.add_request([1], max_new=4)
    cb.add_request([2], max_new=4)
    with pytest.raises(RuntimeError):
        cb.add_request([3], max_new=4)   # DP-level throttling boundary


def test_sampling_modes():
    logits = jnp.array([[0.0, 5.0, 1.0]])
    assert int(sample_token(logits)[0]) == 1            # greedy
    rng = jax.random.PRNGKey(0)
    # near-uniform logits: with 64 independent rows the chance of a single
    # repeated token is astronomically small, so this asserts per-row
    # sampling rather than seed luck (a peaked distribution can legitimately
    # emit 64 identical tokens)
    soft = jnp.array([[0.0, 1.0, 0.5]])
    t = sample_token(jnp.tile(soft, (64, 1)), rng, temperature=1.0)
    assert len(set(np.asarray(t).tolist())) > 1          # stochastic per row
    tk = sample_token(jnp.tile(logits, (16, 1)), rng, temperature=1.0,
                      top_k=1)
    assert set(np.asarray(tk).tolist()) == {1}           # top-1 == greedy
