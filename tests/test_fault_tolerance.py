"""Fault-tolerance behaviour (paper §3.4 / §5.4)."""
import pytest

from repro.core import Cluster, Function, ScalingConfig
from repro.simcore import Environment


def make_cluster(seed=2, **kw):
    env = Environment(seed=seed)
    kw.setdefault("n_workers", 8)
    kw.setdefault("enable_ha_sim", True)
    cl = Cluster(env, **kw)
    cl.start()
    return env, cl


def test_cp_failover_recovers_in_milliseconds():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    inv = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    assert not inv.failed
    t_fail = env.now
    cl.fail_control_plane_leader()
    env.run(until=t_fail + 1.0)
    ev = [t for t, k, _ in cl.collector.events if k == "leader-elected"]
    assert ev, "no leader elected after failure"
    # C10: detect + elect + fetch + DP sync ~ 10 ms
    assert ev[0] - t_fail < 0.05
    new_leader = cl.control_plane_leader()
    assert new_leader is not None and new_leader.cp_id != 0


def test_cp_failover_preserves_functions_and_rebuilds_sandboxes():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    inv = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    cl.fail_control_plane_leader()
    env.run(until=6.0)
    leader = cl.control_plane_leader()
    # Function records restored from the persistent store
    assert "f" in leader.functions
    # Sandbox state reconstructed FROM WORKER NODES (it was never persisted)
    assert leader.functions["f"].ready_count >= 1
    # post-recovery: no downscale for one autoscaling window (§3.4.1)
    assert leader.no_downscale_until > env.now

    warm = cl.invoke("f", exec_time=0.01)
    env.run(until=10.0)
    assert not warm.failed and not warm.cold


def test_leadership_loss_midboot_releases_placer_capacity():
    """Regression: losing leadership after the worker booted used to leak
    placer capacity and leave a CREATING sandbox in FunctionState.sandboxes
    (the early-return in _create_sandbox skipped cleanup)."""
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    old = cl.control_plane_leader()
    cl.invoke("f", exec_time=0.01)
    env.run(until=env.now + 0.02)     # placed, worker still booting
    st = old.functions["f"]
    assert st.creating == 1
    assert any(n.cpu_used > 0 for n in old.placer.nodes.values())
    cl.fail_control_plane_leader()
    env.run(until=env.now + 1.0)      # boot completes after leadership loss
    assert all(n.cpu_used == 0 and n.mem_used == 0
               for n in old.placer.nodes.values())
    assert st.sandboxes == {}         # no CREATING orphan left behind
    assert st.creating == 0


def test_stale_endpoint_self_heals_after_one_failure():
    """A sandbox killed behind the control plane's back costs one failed
    request: the DP evicts the endpoint, reports it, and the CP reconciles
    capacity + replacement — not an endless failure stream."""
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(stable_window=300,
                                                    scale_to_zero_grace=300)))
    first = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    assert not first.failed
    leader = cl.control_plane_leader()
    sb = next(iter(leader.functions["f"].sandboxes.values()))
    # kill the sandbox on the worker without telling CP or DPs
    cl.workers[sb.worker_id].sandboxes.pop(sb.sandbox_id)
    bad = cl.invoke("f", exec_time=0.01)
    env.run(until=10.0)
    assert bad.failed and "gone" in bad.failure_reason
    # endpoint evicted everywhere; CP forgot the sandbox and freed capacity
    assert all(sb.sandbox_id not in dp.tables["f"].endpoints
               for dp in cl.data_planes if "f" in dp.tables)
    assert sb.sandbox_id not in leader.functions["f"].sandboxes
    # traffic recovers on the replacement sandbox
    later = cl.invoke("f", exec_time=0.01)
    env.run(until=25.0)
    assert not later.failed


def test_hedged_dispatch_heals_dead_sandbox():
    """Regression: hedged dispatch used to deliver a failed attempt's
    exception as the request RESULT (any_of swallows child failure), never
    reporting the dead endpoint. Now the dead side is healed and the
    surviving attempt serves the request."""
    env, cl = make_cluster(hedge_after=0.1)
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(target_concurrency=1,
                                                    stable_window=300,
                                                    scale_to_zero_grace=300)))
    warm = [cl.invoke("f", exec_time=1.0) for _ in range(2)]
    env.run(until=10.0)
    leader = cl.control_plane_leader()
    sbs = list(leader.functions["f"].sandboxes.values())
    assert len(sbs) >= 2
    dead = sbs[0]
    cl.workers[dead.worker_id].sandboxes.pop(dead.sandbox_id)
    invs = [cl.invoke("f", exec_time=0.05) for _ in range(4)]
    env.run(until=20.0)
    # the dead sandbox is reconciled out of CP state and all DP caches
    assert dead.sandbox_id not in leader.functions["f"].sandboxes
    assert all(dead.sandbox_id not in dp.tables["f"].endpoints
               for dp in cl.data_planes if "f" in dp.tables)
    # at most the first dispatch onto the dead endpoint fails; no result may
    # ever be an exception object (the old any_of-swallowing bug)
    assert sum(1 for i in invs if i.failed) <= 1
    assert all(not isinstance(i.result, BaseException) for i in invs)
    late = cl.invoke("f", exec_time=0.05)
    env.run(until=30.0)
    assert not late.failed


def test_warm_traffic_survives_cp_outage():
    """Warm invocations need no control plane (paper §3.4.1)."""
    env, cl = make_cluster(n_control_planes=1)   # no standby -> no recovery
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(scale_to_zero_grace=600,
                                                    stable_window=600)))
    inv = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    cl.fail_control_plane_leader()
    env.run(until=6.0)
    warm = cl.invoke("f", exec_time=0.01)
    env.run(until=12.0)
    assert not warm.failed


def test_dp_failure_drops_inflight_and_recovers():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    warm0 = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    long_inv = cl.invoke("f", exec_time=30.0)
    env.run(until=6.0)
    owner_dp = [dp for dp in cl.data_planes
                if long_inv.inv_id in dp.inflight_requests][0]
    cl.fail_data_plane(owner_dp.dp_id)
    env.run(until=7.0)
    assert long_inv.failed            # in-flight requests die with the DP
    env.run(until=20.0)               # systemd restart + resync + LB reload
    ev = {k: t for t, k, _ in cl.collector.events}
    assert "dp-recovered" in ev
    after = cl.invoke("f", exec_time=0.01)
    env.run(until=30.0)
    assert not after.failed


def test_worker_eviction_and_rescheduling():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(stable_window=120,
                                                    scale_to_zero_grace=120)))
    inv = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    leader = cl.control_plane_leader()
    wid = next(iter(leader.functions["f"].sandboxes.values())).worker_id
    cl.fail_worker_daemon(wid)
    # sustain some traffic so the autoscaler keeps the function hot
    def traffic(env):
        while env.now < 20.0:
            cl.invoke("f", exec_time=0.05)
            yield env.timeout(0.5)
    env.process(traffic(env), name="traffic")
    env.run(until=25.0)
    evs = [d for t, k, d in cl.collector.events if k == "worker-evicted"]
    assert wid in evs                 # heartbeat timeout -> eviction
    st = leader.functions["f"]
    assert st.ready_count >= 1        # replacement sandbox elsewhere
    assert all(sb.worker_id != wid for sb in st.sandboxes.values())


def test_multi_component_failures_keep_cluster_operational():
    env, cl = make_cluster(n_workers=6)
    cl.register_sync(Function(name="f", image_url="i", port=80))
    inv = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    cl.fail_control_plane_leader()
    cl.fail_data_plane(0)
    for wid in range(3):
        cl.fail_worker_daemon(wid)
    env.run(until=30.0)
    late = cl.invoke("f", exec_time=0.01)
    env.run(until=45.0)
    assert not late.failed            # 1 CP + DPs + workers still suffice


def test_filestore_recovery_semantics(tmp_path):
    """Durable records survive a crash; sandbox state intentionally doesn't."""
    from repro.core.persistence import FileStore
    from repro.core.abstractions import Function as Fn

    path = str(tmp_path / "wal.log")
    st = FileStore(path)
    st.write("function/a", Fn(name="a", image_url="i", port=80).persisted_record())
    st.write("function/b", Fn(name="b", image_url="i", port=81).persisted_record())
    st.write("function/a", None)      # deregister -> tombstone
    st.close()

    st2 = FileStore(path)             # replay after "crash"
    assert st2.read("function/a") is None
    fb = Fn.from_record(st2.read("function/b"))
    assert fb.name == "b" and fb.port == 81
    st2.close()


def test_filestore_torn_tail_write(tmp_path):
    from repro.core.persistence import FileStore
    path = str(tmp_path / "wal.log")
    st = FileStore(path)
    st.write("k1", b"v1")
    st.write("k2", b"v2")
    st.close()
    with open(path, "ab") as fh:      # simulate a torn write at crash
        fh.write(b"\x07\x00garbage")
    st2 = FileStore(path)
    assert st2.read("k1") == b"v1"
    assert st2.read("k2") == b"v2"
    st2.close()


def test_dp_recovery_snapshot_order():
    """Regression for the snapshot block in Cluster._recover_data_plane:
    the functions/endpoints the recovered DP is handed iterate insertion-
    ordered CP dicts, and that insertion order must be reproducible — two
    identical runs must rebuild byte-identical tables (keys *in order*) and
    the identical event stream."""
    def run_once():
        env, cl = make_cluster(seed=11, n_workers=6)
        for i in range(5):
            cl.register_sync(Function(name=f"f{i}", image_url="i", port=80))
        for i in range(5):
            cl.invoke(f"f{i}", exec_time=0.01)
        env.run(until=5.0)
        dp = cl.data_planes[0]
        cl.fail_data_plane(dp.dp_id)
        env.run(until=25.0)          # systemd restart + resync + LB reload
        ev = {k for _, k, _ in cl.collector.events}
        assert "dp-recovered" in ev
        return (list(dp.tables.keys()),
                [(fn, list(tbl.endpoints.keys()))
                 for fn, tbl in dp.tables.items()],
                list(cl.collector.events))

    assert run_once() == run_once()
