"""Fault-tolerance behaviour (paper §3.4 / §5.4)."""
import pytest

from repro.core import Cluster, Function, ScalingConfig
from repro.simcore import Environment


def make_cluster(seed=2, **kw):
    env = Environment(seed=seed)
    kw.setdefault("n_workers", 8)
    kw.setdefault("enable_ha_sim", True)
    cl = Cluster(env, **kw)
    cl.start()
    return env, cl


def test_cp_failover_recovers_in_milliseconds():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    inv = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    assert not inv.failed
    t_fail = env.now
    cl.fail_control_plane_leader()
    env.run(until=t_fail + 1.0)
    ev = [t for t, k, _ in cl.collector.events if k == "leader-elected"]
    assert ev, "no leader elected after failure"
    # C10: detect + elect + fetch + DP sync ~ 10 ms
    assert ev[0] - t_fail < 0.05
    new_leader = cl.control_plane_leader()
    assert new_leader is not None and new_leader.cp_id != 0


def test_cp_failover_preserves_functions_and_rebuilds_sandboxes():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    inv = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    cl.fail_control_plane_leader()
    env.run(until=6.0)
    leader = cl.control_plane_leader()
    # Function records restored from the persistent store
    assert "f" in leader.functions
    # Sandbox state reconstructed FROM WORKER NODES (it was never persisted)
    assert leader.functions["f"].ready_count >= 1
    # post-recovery: no downscale for one autoscaling window (§3.4.1)
    assert leader.no_downscale_until > env.now

    warm = cl.invoke("f", exec_time=0.01)
    env.run(until=10.0)
    assert not warm.failed and not warm.cold


def test_leadership_loss_midboot_releases_placer_capacity():
    """Regression: losing leadership after the worker booted used to leak
    placer capacity and leave a CREATING sandbox in FunctionState.sandboxes
    (the early-return in _create_sandbox skipped cleanup)."""
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    old = cl.control_plane_leader()
    cl.invoke("f", exec_time=0.01)
    env.run(until=env.now + 0.02)     # placed, worker still booting
    st = old.functions["f"]
    assert st.creating == 1
    assert any(n.cpu_used > 0 for n in old.placer.nodes.values())
    cl.fail_control_plane_leader()
    env.run(until=env.now + 1.0)      # boot completes after leadership loss
    assert all(n.cpu_used == 0 and n.mem_used == 0
               for n in old.placer.nodes.values())
    assert st.sandboxes == {}         # no CREATING orphan left behind
    assert st.creating == 0


def test_stale_endpoint_self_heals_after_one_failure():
    """A sandbox killed behind the control plane's back costs one failed
    request: the DP evicts the endpoint, reports it, and the CP reconciles
    capacity + replacement — not an endless failure stream."""
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(stable_window=300,
                                                    scale_to_zero_grace=300)))
    first = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    assert not first.failed
    leader = cl.control_plane_leader()
    sb = next(iter(leader.functions["f"].sandboxes.values()))
    # kill the sandbox on the worker without telling CP or DPs
    cl.workers[sb.worker_id].sandboxes.pop(sb.sandbox_id)
    bad = cl.invoke("f", exec_time=0.01)
    env.run(until=10.0)
    assert bad.failed and "gone" in bad.failure_reason
    # endpoint evicted everywhere; CP forgot the sandbox and freed capacity
    assert all(sb.sandbox_id not in dp.tables["f"].endpoints
               for dp in cl.data_planes if "f" in dp.tables)
    assert sb.sandbox_id not in leader.functions["f"].sandboxes
    # traffic recovers on the replacement sandbox
    later = cl.invoke("f", exec_time=0.01)
    env.run(until=25.0)
    assert not later.failed


def test_hedged_dispatch_heals_dead_sandbox():
    """Regression: hedged dispatch used to deliver a failed attempt's
    exception as the request RESULT (any_of swallows child failure), never
    reporting the dead endpoint. Now the dead side is healed and the
    surviving attempt serves the request."""
    env, cl = make_cluster(hedge_after=0.1)
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(target_concurrency=1,
                                                    stable_window=300,
                                                    scale_to_zero_grace=300)))
    warm = [cl.invoke("f", exec_time=1.0) for _ in range(2)]
    env.run(until=10.0)
    leader = cl.control_plane_leader()
    sbs = list(leader.functions["f"].sandboxes.values())
    assert len(sbs) >= 2
    dead = sbs[0]
    cl.workers[dead.worker_id].sandboxes.pop(dead.sandbox_id)
    invs = [cl.invoke("f", exec_time=0.05) for _ in range(4)]
    env.run(until=20.0)
    # the dead sandbox is reconciled out of CP state and all DP caches
    assert dead.sandbox_id not in leader.functions["f"].sandboxes
    assert all(dead.sandbox_id not in dp.tables["f"].endpoints
               for dp in cl.data_planes if "f" in dp.tables)
    # at most the first dispatch onto the dead endpoint fails; no result may
    # ever be an exception object (the old any_of-swallowing bug)
    assert sum(1 for i in invs if i.failed) <= 1
    assert all(not isinstance(i.result, BaseException) for i in invs)
    late = cl.invoke("f", exec_time=0.05)
    env.run(until=30.0)
    assert not late.failed


def test_warm_traffic_survives_cp_outage():
    """Warm invocations need no control plane (paper §3.4.1)."""
    env, cl = make_cluster(n_control_planes=1)   # no standby -> no recovery
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(scale_to_zero_grace=600,
                                                    stable_window=600)))
    inv = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    cl.fail_control_plane_leader()
    env.run(until=6.0)
    warm = cl.invoke("f", exec_time=0.01)
    env.run(until=12.0)
    assert not warm.failed


def test_dp_failure_drops_inflight_and_recovers():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    warm0 = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    long_inv = cl.invoke("f", exec_time=30.0)
    env.run(until=6.0)
    owner_dp = [dp for dp in cl.data_planes
                if long_inv.inv_id in dp.inflight_requests][0]
    cl.fail_data_plane(owner_dp.dp_id)
    env.run(until=7.0)
    assert long_inv.failed            # in-flight requests die with the DP
    env.run(until=20.0)               # systemd restart + resync + LB reload
    ev = {k: t for t, k, _ in cl.collector.events}
    assert "dp-recovered" in ev
    after = cl.invoke("f", exec_time=0.01)
    env.run(until=30.0)
    assert not after.failed


def test_worker_eviction_and_rescheduling():
    env, cl = make_cluster()
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(stable_window=120,
                                                    scale_to_zero_grace=120)))
    inv = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    leader = cl.control_plane_leader()
    wid = next(iter(leader.functions["f"].sandboxes.values())).worker_id
    cl.fail_worker_daemon(wid)
    # sustain some traffic so the autoscaler keeps the function hot
    def traffic(env):
        while env.now < 20.0:
            cl.invoke("f", exec_time=0.05)
            yield env.timeout(0.5)
    env.process(traffic(env), name="traffic")
    env.run(until=25.0)
    evs = [d for t, k, d in cl.collector.events if k == "worker-evicted"]
    assert wid in evs                 # heartbeat timeout -> eviction
    st = leader.functions["f"]
    assert st.ready_count >= 1        # replacement sandbox elsewhere
    assert all(sb.worker_id != wid for sb in st.sandboxes.values())


def test_multi_component_failures_keep_cluster_operational():
    env, cl = make_cluster(n_workers=6)
    cl.register_sync(Function(name="f", image_url="i", port=80))
    inv = cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    cl.fail_control_plane_leader()
    cl.fail_data_plane(0)
    for wid in range(3):
        cl.fail_worker_daemon(wid)
    env.run(until=30.0)
    late = cl.invoke("f", exec_time=0.01)
    env.run(until=45.0)
    assert not late.failed            # 1 CP + DPs + workers still suffice


def test_filestore_recovery_semantics(tmp_path):
    """Durable records survive a crash; sandbox state intentionally doesn't."""
    from repro.core.persistence import FileStore
    from repro.core.abstractions import Function as Fn

    path = str(tmp_path / "wal.log")
    st = FileStore(path)
    st.write("function/a", Fn(name="a", image_url="i", port=80).persisted_record())
    st.write("function/b", Fn(name="b", image_url="i", port=81).persisted_record())
    st.write("function/a", None)      # deregister -> tombstone
    st.close()

    st2 = FileStore(path)             # replay after "crash"
    assert st2.read("function/a") is None
    fb = Fn.from_record(st2.read("function/b"))
    assert fb.name == "b" and fb.port == 81
    st2.close()


def test_filestore_torn_tail_write(tmp_path):
    from repro.core.persistence import FileStore
    path = str(tmp_path / "wal.log")
    st = FileStore(path)
    st.write("k1", b"v1")
    st.write("k2", b"v2")
    st.close()
    with open(path, "ab") as fh:      # simulate a torn write at crash
        fh.write(b"\x07\x00garbage")
    st2 = FileStore(path)
    assert st2.read("k1") == b"v1"
    assert st2.read("k2") == b"v2"
    st2.close()


# -- failover fault-injection matrix ------------------------------------------
# Leader killed in the middle of every multi-step choreography the CP runs
# (split handoff, rebalance quiesce, scale-down teardown), plus compound
# failures (DP crash during CP recovery) and the deposed leader racing the
# new leader's replay. Every scenario must end *converged*: indirection
# table ↔ shard maps ↔ slices agree, no sandbox is adopted twice, CP state
# matches what the workers actually run, and the durable overrides match
# the table. All run with cp_shards=4 so the incremental per-shard recovery
# path (the PR 8 default) is what is being stressed.

LONG_SCALING = dict(stable_window=300, scale_to_zero_grace=300)


def make_sharded(seed=2, **kw):
    kw.setdefault("cp_shards", 4)
    kw.setdefault("n_workers", 16)
    return make_cluster(seed=seed, **kw)


def assert_converged(cl, leader):
    """Post-failover convergence invariants (quiesced cluster: callers run
    past boot/teardown transients first)."""
    # 1. indirection table ↔ per-shard function maps ↔ slices
    owned = {}
    for shard in leader.shards:
        for n in shard.functions:
            owned.setdefault(n, []).append(shard.shard_id)
    for n, st in leader.functions.items():
        ids = leader._fn_shard_ids(n)
        assert sorted(owned.get(n, [])) == sorted(ids), \
            f"{n}: shard maps {owned.get(n)} vs table {ids}"
        if st.slices is None:
            assert len(ids) == 1
        else:
            assert set(st.slices) == set(ids)
            # 2. every slice-owned sandbox exists globally; none owned twice
            seen = set()
            for sl in st.slices.values():
                assert sl.sandbox_ids <= set(st.sandboxes), \
                    f"{n}: slice {sl.shard_id} owns unknown sandboxes"
                assert not (sl.sandbox_ids & seen), \
                    f"{n}: sandbox adopted into two slices"
                seen |= sl.sandbox_ids
    # 3. CP sandbox state matches the workers (no phantom or double-adopted
    # replicas — sandbox ids are globally unique, so each may appear under
    # exactly one function)
    seen_sids = set()
    for n, st in leader.functions.items():
        for sid, sb in st.sandboxes.items():
            assert sid not in seen_sids, f"sandbox {sid} adopted twice"
            seen_sids.add(sid)
            w = cl.workers[sb.worker_id]
            if w.daemon_alive:
                assert sid in w.sandboxes, \
                    f"{n}: CP believes in sandbox {sid} the worker lost"
    # 4. placer accounting: used capacity == what the adopted sandboxes
    # plus in-flight creations actually hold
    expected = {}
    for st in leader.functions.values():
        cpu = st.function.scaling.cpu_req_millis
        for sb in st.sandboxes.values():
            expected[sb.worker_id] = expected.get(sb.worker_id, 0) + cpu
    inflight = sum(st.creating for st in leader.functions.values())
    inflight += sum(sl.creating for st in leader.functions.values()
                    if st.slices for sl in st.slices.values())
    if inflight == 0:
        for wid, node in leader.placer.nodes.items():
            assert node.cpu_used == expected.get(wid, 0), \
                f"worker {wid}: placer says {node.cpu_used}, " \
                f"sandboxes account for {expected.get(wid, 0)}"
    # 5. durable shardmap overrides match the live table
    for key, rec in cl.store.peek_prefix("shardmap/").items():
        name = key.split("/", 1)[1]
        if rec is None or name not in leader.functions:
            continue
        text = rec.decode()
        want = (tuple(int(x) for x in text.split(","))
                if "," in text else int(text))
        assert leader.fn_shard_table[name] == want, \
            f"{name}: table {leader.fn_shard_table[name]} vs durable {want}"


@pytest.mark.parametrize("kill_at,survives", [
    # inside the quiesce hold: the handoff aborts at its leadership check —
    # nothing published, nothing persisted, replay rebuilds unsplit
    (1e-6, False),
    # after publish, mid-persist: the override write was initiated while
    # still leader, so it commits durably — replay must KEEP the split and
    # re-adopt the pushed sandboxes into slices
    (2e-4, True),
])
def test_leader_killed_mid_split_handoff(kill_at, survives):
    """The split handoff (quiesce subshard locks → slice → publish →
    persist) dies with the leader partway through; whichever side of the
    durable write the kill lands on, the new leader must rebuild a
    consistent view from the records that DID persist."""
    env, cl = make_sharded(cp_fn_split_enabled=True,
                           cp_rebalance_period=1e9)
    for n in ("f", "g"):
        cl.register_sync(Function(name=n, image_url="i", port=80,
                                  scaling=ScalingConfig(**LONG_SCALING)))
    invs = [cl.invoke("f", exec_time=60.0) for _ in range(4)]
    env.run(until=5.0)
    assert all(not i.failed for i in invs)
    leader = cl.control_plane_leader()
    home = leader._fn_shard_id("f")
    members = (home, (home + 1) % 4)
    env.process(leader._split_function("f", members), name="split")
    env.run(until=env.now + kill_at)
    cl.fail_control_plane_leader()
    env.run(until=env.now + 5.0)
    new_leader = cl.control_plane_leader()
    assert new_leader is not None and new_leader is not leader
    assert len([1 for _, k, _ in cl.collector.events
                if k == "cp-shard-recovered"]) == 4
    st = new_leader.functions["f"]
    if survives:
        assert st.slices is not None and set(st.slices) == set(members)
        # every pushed-back sandbox adopted into exactly one slice
        assert set().union(*(sl.sandbox_ids for sl in st.slices.values())) \
            == set(st.sandboxes)
    else:
        assert st.slices is None
    assert_converged(cl, new_leader)
    late = [cl.invoke(n, exec_time=0.01) for n in ("f", "g")]
    env.run(until=env.now + 10.0)
    assert all(not i.failed for i in late)
    assert_converged(cl, new_leader)


@pytest.mark.parametrize("kill_at,survives", [(1e-6, False), (2e-4, True)])
def test_leader_killed_mid_rebalance_quiesce(kill_at, survives):
    """Same, for the whole-function migration handoff: the quiesce grabs
    both shards' scale locks, then publishes and persists. A kill inside
    the quiesce hold (before the cross-shard hop completes) aborts the
    move at the leadership check — replay lands the function back on its
    hash home. A kill after the move, while the shardmap override's fsync
    is in flight, cannot retract the write: the migration survives into
    the next epoch."""
    env, cl = make_sharded(cp_rebalance_enabled=True,
                           cp_rebalance_period=1e9)
    for i in range(6):
        cl.register_sync(Function(name=f"f{i}", image_url="i", port=80,
                                  scaling=ScalingConfig(**LONG_SCALING)))
    invs = [cl.invoke(f"f{i}", exec_time=60.0) for i in range(6)]
    env.run(until=5.0)
    assert all(not i.failed for i in invs)
    leader = cl.control_plane_leader()
    name = "f0"
    src = leader._fn_shard_id(name)
    dst = (src + 1) % 4
    env.process(leader._migrate_functions(leader.shards[src],
                                          leader.shards[dst], [name]),
                name="mig")
    env.run(until=env.now + kill_at)
    cl.fail_control_plane_leader()
    env.run(until=env.now + 5.0)
    new_leader = cl.control_plane_leader()
    assert new_leader is not None and new_leader is not leader
    expected = dst if survives else src
    assert new_leader._fn_shard_id(name) == expected
    assert_converged(cl, new_leader)
    late = [cl.invoke(f"f{i}", exec_time=0.01) for i in range(6)]
    env.run(until=env.now + 10.0)
    assert all(not i.failed for i in late)
    assert_converged(cl, new_leader)


def test_leader_killed_mid_scale_down_teardown():
    """Teardowns in flight when the leader dies: the half-dismantled
    sandboxes are NOT in the workers' pushed lists (kill_sandbox pops
    before yielding), so the new leader must neither adopt them nor leak
    their placer capacity."""
    env, cl = make_sharded()
    cl.register_sync(Function(
        name="f", image_url="i", port=80,
        scaling=ScalingConfig(stable_window=1.0, panic_window=1.0,
                              scale_to_zero_grace=0.2)))
    invs = [cl.invoke("f", exec_time=0.05) for _ in range(8)]
    env.run(until=3.0)
    assert all(not i.failed for i in invs)
    leader = cl.control_plane_leader()
    st = leader.functions["f"]
    n_before = len(st.sandboxes)
    assert n_before >= 1
    # drive the scale-down, then kill the instant teardowns are in flight
    deadline = env.now + 30.0
    while env.now < deadline and len(st.sandboxes) == n_before:
        env.run(until=env.now + 0.05)
    cl.fail_control_plane_leader()
    env.run(until=env.now + 5.0)
    new_leader = cl.control_plane_leader()
    assert new_leader is not None and new_leader is not leader
    assert_converged(cl, new_leader)
    late = cl.invoke("f", exec_time=0.01)
    env.run(until=env.now + 10.0)
    assert not late.failed
    assert_converged(cl, new_leader)


def test_dp_crash_during_cp_recovery():
    """A data plane dies while the new leader is still replaying shards:
    the DP resync and the per-shard admissions interleave, and both sides
    must converge (DP tables rebuilt, endpoints re-added exactly once)."""
    env, cl = make_sharded()
    for i in range(4):
        cl.register_sync(Function(name=f"f{i}", image_url="i", port=80,
                                  scaling=ScalingConfig(**LONG_SCALING)))
    invs = [cl.invoke(f"f{i}", exec_time=60.0) for i in range(4)]
    env.run(until=5.0)
    assert all(not i.failed for i in invs)
    cl.fail_control_plane_leader()
    env.run(until=env.now + 0.003)      # mid-recovery (replay in flight)
    cl.fail_data_plane(0)
    env.run(until=env.now + 30.0)       # CP recovery + DP restart/resync
    new_leader = cl.control_plane_leader()
    assert new_leader is not None
    kinds = {k for _, k, _ in cl.collector.events}
    assert "cp-recovered" in kinds and "dp-recovered" in kinds
    assert_converged(cl, new_leader)
    # recovered DP serves traffic from rebuilt tables
    late = [cl.invoke(f"f{i}", exec_time=0.01) for i in range(4)]
    env.run(until=env.now + 10.0)
    assert all(not i.failed for i in late)
    dp = cl.data_planes[0]
    for i in range(4):
        got = sorted(dp.tables[f"f{i}"].endpoints)
        want = sorted(new_leader.functions[f"f{i}"].sandboxes)
        assert got == want


def test_deposed_leader_racing_replay_cannot_double_place():
    """The deposed leader still has creations mid-boot when the new leader
    replays worker state: those boots complete AFTER the depose and must be
    dropped by the leadership check — never adopted by the new leader (the
    worker never got them), never counted twice, never leaking capacity."""
    env, cl = make_sharded()
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(**LONG_SCALING)))
    warm = cl.invoke("f", exec_time=60.0)
    env.run(until=5.0)
    assert not warm.failed
    old = cl.control_plane_leader()
    # put a creation in flight (firecracker boot ~40 ms), then depose
    cl.invoke("f", exec_time=60.0)
    cl.invoke("f", exec_time=60.0)
    env.run(until=env.now + 0.01)
    assert sum(st.creating for st in old.functions.values()) >= 1
    cl.fail_control_plane_leader()
    env.run(until=env.now + 5.0)
    new_leader = cl.control_plane_leader()
    assert new_leader is not None and new_leader is not old
    # the old leader's orphaned boots were dropped, not leaked
    assert all(st.creating == 0 for st in old.functions.values())
    assert_converged(cl, new_leader)
    late = cl.invoke("f", exec_time=0.01)
    env.run(until=env.now + 10.0)
    assert not late.failed
    assert_converged(cl, new_leader)


def test_dp_recovery_snapshot_order():
    """Regression for the snapshot block in Cluster._recover_data_plane:
    the functions/endpoints the recovered DP is handed iterate insertion-
    ordered CP dicts, and that insertion order must be reproducible — two
    identical runs must rebuild byte-identical tables (keys *in order*) and
    the identical event stream."""
    def run_once():
        env, cl = make_cluster(seed=11, n_workers=6)
        for i in range(5):
            cl.register_sync(Function(name=f"f{i}", image_url="i", port=80))
        for i in range(5):
            cl.invoke(f"f{i}", exec_time=0.01)
        env.run(until=5.0)
        dp = cl.data_planes[0]
        cl.fail_data_plane(dp.dp_id)
        env.run(until=25.0)          # systemd restart + resync + LB reload
        ev = {k for _, k, _ in cl.collector.events}
        assert "dp-recovered" in ev
        return (list(dp.tables.keys()),
                [(fn, list(tbl.endpoints.keys()))
                 for fn, tbl in dp.tables.items()],
                list(cl.collector.events))

    assert run_once() == run_once()
